"""AWAPart in the LM framework: workload-aware MoE expert placement.

Routes a drifting request workload through an MoE layer, observes expert
co-activation, and migrates experts between expert-parallel ranks exactly the
way the paper migrates triples between shards — cutting all-to-all dispatch
bytes (the "distributed joins" of a TPU pod).

    PYTHONPATH=src python examples/adaptive_moe.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import placement
from repro.models import moe

E, RANKS, TOPK = 64, 16, 8   # olmoe-1b-7b geometry
cfg = ArchConfig(arch_id="olmoe-demo", family="moe", n_layers=1, d_model=64,
                 n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                 n_experts=E, top_k=TOPK, moe_dispatch="rank",
                 param_dtype="float32", compute_dtype="float32")
params, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

# a workload with topical structure: each request activates experts from one
# of 8 latent topics (plus noise) — the LM analogue of query feature sets
topics = rng.permutation(E).reshape(8, 8)
def sample_routing(n_requests, noise=0.1):
    out = np.empty((n_requests, TOPK), np.int64)
    for i in range(n_requests):
        t = topics[rng.integers(8)]
        picks = list(rng.permutation(t)[:TOPK])
        for j in range(TOPK):
            if rng.random() < noise:
                picks[j] = int(rng.integers(E))
        out[i] = picks
    return out

expert_to_rank = np.repeat(np.arange(RANKS), E // RANKS).astype(np.int32)
print("serving with identity placement...")
for round_i in range(3):
    routing = sample_routing(1024)
    before = placement.avg_distinct_ranks(routing, expert_to_rank, RANKS)
    new_map, report = placement.plan_expert_placement(
        routing, E, RANKS, old_expert_to_rank=expert_to_rank,
        expert_bytes=3 * cfg.d_model * cfg.d_ff * 4)
    if report.accepted:
        params = placement.apply_expert_placement(params, new_map)
        expert_to_rank = new_map
    print(f"round {round_i}: ranks/token {report.ranks_before:.2f} -> "
          f"{report.ranks_after:.2f} "
          f"(all-to-all bytes {report.bytes_saved_frac*100:+.0f}%), "
          f"migrated {report.moved_experts} experts "
          f"({report.migration_bytes/1e6:.1f} MB), "
          f"accepted={report.accepted}")

# the placed model computes the identical function (single-copy migration,
# like triple swaps): verify against a fresh un-permuted reference
ref_params, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
y_ref, _ = moe.moe_apply_dense(ref_params, x, cfg)
y_new, _ = moe.moe_apply_dense(params, x, cfg)
print(f"\nfunction preserved after migrations: "
      f"max diff = {float(jnp.abs(y_ref - y_new).max()):.2e}")
