"""Quickstart: partition a knowledge graph, query it, adapt to the workload.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.adaptive import AWAPartController
from repro.core.features import FeatureSpace
from repro.graph import lubm
from repro.query import engine, rewrite

# 1. a small LUBM knowledge graph (2 universities, ~300k triples)
ds = lubm.load(2, seed=0)
print(f"knowledge graph: {ds.store.n_triples} triples, "
      f"{len(ds.queries)} benchmark queries")

# 2. workload-aware initial partition over 4 shards
space = FeatureSpace(ds.store, type_predicate=ds.dictionary.lookup("rdf:type"))
ctrl = AWAPartController(space, n_shards=4)
base = ds.base_workload()               # LUBM Q1..Q14
space.track_workload(base)
state = ctrl.initial_partition(base)
sharded = engine.ShardedStore(ds.store, space, state)
print(f"shards: {sharded.shard_sizes()} (imbalance {state.imbalance():.2f})")

# 3. run a query — federated across shards
q9 = ds.queries["Q9"]
bindings, stats = engine.execute(q9, sharded)
print(f"\nQ9 -> {stats.rows} rows, {stats.distributed_joins} distributed "
      f"joins, {stats.bytes_shipped / 1e3:.1f} KB shipped")
print("\nfederated rewrite of Q9:")
print(rewrite.federated_sparql(q9, space, state, ds.dictionary))

# 4. the workload changes: 10 new queries arrive -> adapt
new_queries = ds.workload([f"EQ{i}" for i in range(1, 11)])
times0, _ = engine.run_workload(new_queries, sharded)

def measure(cand):
    sh = engine.ShardedStore(ds.store, space, cand)
    return engine.workload_average_time(list(ctrl.workload.values()), sh)

state2, report = ctrl.adapt(new_queries, measure=measure)
print(f"\nadaptation: accepted={report.accepted}, "
      f"distributed joins {report.dj_before:.0f} -> {report.dj_after:.0f}, "
      f"{report.plan.summary()}")

sharded2 = engine.ShardedStore(ds.store, space, state2)
times1, _ = engine.run_workload(new_queries, sharded2)
avg0 = np.mean(list(times0.values())) * 1e3
avg1 = np.mean(list(times1.values())) * 1e3
print(f"new-query avg runtime: {avg0:.1f} ms -> {avg1:.1f} ms "
      f"({(1 - avg1 / avg0) * 100:+.1f}%)")
