"""Quickstart: partition a knowledge graph, query it, adapt to the workload.

Everything goes through the public ``repro.api`` surface: a ``Partitioner``
strategy (hash / wawpart / awapart, interchangeable), the ``KGService``
session loop with a pluggable ``Executor`` backend (numpy reference / jax
batched), and the ``PartitionedKG`` facade whose shard views and cached
query plans update incrementally when the partition adapts.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.api import HashPartitioner, JaxExecutor, KGService
from repro.graph import lubm
from repro.query import rewrite

# 1. a small LUBM knowledge graph (2 universities, ~300k triples)
ds = lubm.load(2, seed=0)
print(f"knowledge graph: {ds.store.n_triples} triples, "
      f"{len(ds.queries)} benchmark queries")

# 2. workload-aware adaptive partition over 4 shards (default strategy)
svc = KGService.from_dataset(ds, n_shards=4)
base = ds.base_workload()               # LUBM Q1..Q14
kg = svc.bootstrap(base)
print(f"shards: {kg.shard_sizes()} (imbalance {kg.imbalance():.2f}, "
      f"strategy={svc.partitioner.name})")

# 3. run a query — planned once per (query, store), federated across shards,
#    runtime recorded by the service
q9 = ds.queries["Q9"]
bindings, stats = svc.query(q9)
print(f"\nQ9 -> {stats.rows} rows, {stats.distributed_joins} distributed "
      f"joins, {stats.bytes_shipped / 1e3:.1f} KB shipped")
print("\nits QueryPlan IR:")
print(kg.plan(q9).explain())
print("\nfederated rewrite of Q9:")
print(rewrite.federated_sparql(q9, svc.space, kg.state, ds.dictionary,
                               replicas=kg.replicas))

# 4. the workload changes: 10 new queries arrive -> adapt incrementally
new_queries = ds.workload([f"EQ{i}" for i in range(1, 11)])
times0, _ = svc.run_workload(new_queries)

report = svc.adapt(new_queries)
print(f"\nadaptation: accepted={report.accepted}, "
      f"{report.n_clusters} query clusters, distributed joins "
      f"{report.dj_before:.0f} -> {report.dj_after:.0f}, "
      f"{report.plan.summary()}")

times1, _ = svc.run_workload(new_queries)   # same facade, views updated
avg0 = np.mean(list(times0.values())) * 1e3
avg1 = np.mean(list(times1.values())) * 1e3
print(f"new-query avg runtime: {avg0:.1f} ms -> {avg1:.1f} ms "
      f"({(1 - avg1 / avg0) * 100:+.1f}%)")

# 5. strategies are pluggable: same service loop, hash baseline
hash_svc = KGService.from_dataset(ds, n_shards=4,
                                  partitioner=HashPartitioner())
hash_svc.bootstrap()
t_hash = hash_svc.workload_average_time(new_queries) * 1e3
print(f"hash-partition baseline on the new queries: {t_hash:.1f} ms")

# 6. executors are pluggable too: the jax backend runs a whole workload
#    window as one dispatched batch (same bindings and stats as numpy)
window = ds.extended_workload()
t0 = time.perf_counter()
per_query = [svc.query(q) for q in window]            # numpy, one at a time
wall_np = time.perf_counter() - t0
svc.executor = JaxExecutor()
svc.query_batch(window)                               # warm up jax dispatch
t0 = time.perf_counter()
batched = svc.query_batch(window)                     # jax, one batch
wall_jx = time.perf_counter() - t0
assert all(a[1].rows == b[1].rows for a, b in zip(per_query, batched))
svc.executor = JaxExecutor(pallas=True)               # "jax-pallas": probes
pallas = svc.query_batch(window)                      # via the Pallas join
assert all(a[1].rows == b[1].rows                     # kernel family
           for a, b in zip(per_query, pallas))        # (docs/kernels.md)
print(f"\nworkload window x{len(window)}: numpy per-query {wall_np*1e3:.0f} "
      f"ms -> jax batch {wall_jx*1e3:.0f} ms "
      f"({wall_np / max(wall_jx, 1e-9):.1f}x)")
