"""Train a reduced LM for a few hundred steps with the full production stack:
prefetching data pipeline, AdamW+cosine, async checkpointing, failure
recovery, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m --steps 200
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--reduced", "--steps", "200", "--batch", "8",
                "--seq", "128", "--ckpt-every", "50",
                "--inject-failure-at", "120"] + sys.argv[1:]
    train.main()
