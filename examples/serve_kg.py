"""End-to-end driver (the paper's kind): serve a partitioned knowledge graph
with batched queries while the workload drifts, adapting online.

Simulates the Fig.-6 deployment through ``repro.api``: queries arrive in
batches with a drifting mix and each batch executes as ONE backend batch
(``svc.query_batch`` — a single dispatched batch on the jax executor); the
``KGService`` monitors per-query runtimes (TM) and triggers the Fig.-5
adaptation when the average degrades past the threshold, applying the
migration to the live shard views as an incremental delta.

    PYTHONPATH=src python examples/serve_kg.py [--batches 12] [--executor jax]
"""
import argparse
import time

import numpy as np

from repro.api import AWAPartitioner, KGService
from repro.core.adaptive import AdaptConfig
from repro.graph import lubm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=3)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--batches", type=int, default=12)
    ap.add_argument("--queries-per-batch", type=int, default=24)
    ap.add_argument("--executor", default="jax",
                choices=["numpy", "jax", "jax-pallas"])
    ap.add_argument("--migration-budget", type=int, default=None,
                    help="bytes of migration traffic applied per batch "
                         "(default: atomic commit inside the adapt round)")
    ap.add_argument("--replica-budget", type=int, default=None,
                    help="bytes of hot-feature read replicas the adaptation "
                         "may pin onto remote readers' shards")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    t0 = time.time()
    ds = lubm.load(args.universities, 0)
    svc = KGService.from_dataset(
        ds, args.shards,
        AWAPartitioner(AdaptConfig(adapt_threshold=1.10)),
        executor=args.executor,
        migration_budget=args.migration_budget,
        replica_budget=args.replica_budget)
    base = ds.base_workload()
    svc.bootstrap(base)
    print(f"[{time.time()-t0:5.1f}s] serving {ds.store.n_triples} triples on "
          f"{args.shards} shards (executor={svc.executor.name})")
    svc.reset_baseline()      # no reference yet: first trigger adapts
    adaptations = 0

    for batch_i in range(args.batches):
        # workload drift: batches 0-3 base-only; 4+ shift to the EQ mix
        drift = min(max((batch_i - 3) / 4, 0.0), 0.9)
        pool_base = [q.name for q in base]
        pool_new = [f"EQ{i}" for i in range(1, 11)]
        names = [pool_new[rng.integers(len(pool_new))] if rng.random() < drift
                 else pool_base[rng.integers(len(pool_base))]
                 for _ in range(args.queries_per_batch)]
        batch_queries = [ds.queries[n] for n in names]

        t_batch = time.perf_counter()
        svc.query_batch(batch_queries)      # one dispatched backend batch
        wall = time.perf_counter() - t_batch
        avg_ms = svc.avg_execution_time() * 1e3

        marker = ""
        if svc.session is not None:     # chunked drain in flight: one chunk
            sess = svc.session          # was applied ahead of this batch
            marker = (f"  .. migrating {sess.applied}/{sess.n_chunks} chunks"
                      f" ({sess.bytes_applied / 1e6:.2f} MB)")
        if batch_i >= 1:
            # should_adapt() is False while a drain is in flight, so no
            # caller-side special case is needed to avoid a mid-drain round
            report = svc.maybe_adapt()
            if report is not None and report.accepted:
                adaptations += 1
                marker = (f"  << ADAPTED: dj {report.dj_before:.0f}->"
                          f"{report.dj_after:.0f}, {report.plan.summary()}")
        print(f"[batch {batch_i:2d}] drift={drift:.1f} "
              f"avg={avg_ms:6.1f} ms wall={wall:5.2f}s{marker}")

    print(f"\nserved {args.batches * args.queries_per_batch} queries, "
          f"{adaptations} adaptation(s), final shards: "
          f"{svc.kg.shard_sizes()} "
          f"({svc.kg.view_rebuilds} shard-view rebuilds, "
          f"{len(svc.kg.replicas.replicated())} replicated features, "
          f"{svc.kg.result_hits} result-cache hits)")


if __name__ == "__main__":
    main()
