"""Training-loop throughput on CPU (reduced configs): tokens/sec + loss slope."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.launch.train import build
from repro.data.pipeline import Prefetcher


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for arch in ("smollm-360m", "olmoe-1b-7b", "rwkv6-3b"):
        cfg, mesh, ctx, params, opt_state, stream, step_fn = build(
            arch, reduced=True, batch=4, seq=64, steps=30)
        pf = Prefetcher(stream)
        losses = []
        t0 = None
        for i in range(12):
            _, batch_np = next(pf)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if i == 1:
                t0 = time.perf_counter()   # skip compile step
        pf.close()
        dt = (time.perf_counter() - t0) / 10
        toks = 4 * 64
        rows.append((f"train/{arch}_step_us", dt * 1e6,
                     f"tok/s={toks / dt:.0f}_loss_{losses[0]:.2f}->"
                     f"{losses[-1]:.2f}"))
    return rows
