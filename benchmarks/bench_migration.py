"""Migration-engine benchmark: atomic commit vs chunked MigrationSession.

Reproduces the adaptation latency cliff and its fix. Both modes run the
identical LUBM workload-composition round (14 base queries partition the
graph, EQ1..EQ10 arrive, the round is accepted); the difference is how the
accepted ``MigrationPlan`` reaches the shards:

* **atomic** — the whole plan commits inside ``adapt()``; the first serving
  window after the round stalls behind the full modeled migration traffic
  (the spike window).
* **chunked** — a ``MigrationSession`` drains the plan one bounded chunk per
  ``query_batch`` window (hottest workload features first), so every window
  pays at most ``budget`` bytes of traffic while serving the consistent
  hybrid layout.

Per window we record the average modeled time per query *including* the
migration stall that window's queries wait behind (stop-the-world commits
block the whole window; chunked drains block it for at most one budget-sized
chunk); ``results/exp_migration.csv`` holds the series and the summary
asserts the chunked drain's worst window stays strictly below the atomic
spike window.

  PYTHONPATH=src python benchmarks/bench_migration.py            # LUBM(3)/8
  PYTHONPATH=src python benchmarks/bench_migration.py --dry-run  # LUBM(1)/4
  PYTHONPATH=src python -m benchmarks.run --only migration       # harness row
"""
from __future__ import annotations

import argparse
import os
from typing import List, Optional, Tuple

import numpy as np

from repro.api import KGService
from repro.core import migration
from repro.graph import lubm
from repro.query import exec as qexec

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "3"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
BUDGET = int(os.environ.get("REPRO_BENCH_MIG_BUDGET", str(1 << 20)))
CSV_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "exp_migration.csv")


def _serve_round(ds, shards: int, budget: Optional[int],
                 tail_windows: int = 2) -> Tuple[object, List[dict]]:
    """One adaptation round + serving windows until the migration is fully
    drained (plus ``tail_windows`` steady-state windows). Returns the
    AdaptReport and one row per window."""
    svc = KGService.from_dataset(ds, shards, migration_budget=budget)
    svc.bootstrap(ds.base_workload())
    window = ds.extended_workload()
    net = svc.net or qexec.NetworkModel()

    svc.query_batch(window)                      # fill the TM (baseline obs)
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted, "benchmark needs an accepted round"
    session = svc.session                        # None in atomic mode

    rows: List[dict] = []
    remaining = tail_windows
    w = 0
    while True:
        applied0 = session.applied if session else 0
        results = svc.query_batch(window)        # (chunk stall +) serve
        applied1 = session.applied if session else 0
        if budget is None and w == 0:            # atomic: the spike window
            mig_s = migration.migration_seconds(report.plan, net)
            chunks = 1
            bytes_w = report.plan.bytes
        else:
            stepped = session.chunks[applied0:applied1] if session else []
            mig_s = sum(migration.migration_seconds(c, net) for c in stepped)
            chunks = len(stepped)
            bytes_w = sum(c.bytes for c in stepped)
        q_avg = float(np.mean([st.modeled_time(net) for _, st in results]))
        # every query in the window is issued behind that window's migration
        # stall (stop-the-world for atomic, one bounded chunk for chunked),
        # so the stall adds to each query's latency — not amortized away
        rows.append(dict(
            mode="atomic" if budget is None else "chunked",
            window=w, avg_query_ms=q_avg * 1e3,
            migration_ms=mig_s * 1e3,
            window_avg_ms=(q_avg + mig_s) * 1e3,
            epoch=svc.kg.epoch, chunks=chunks, bytes=bytes_w))
        w += 1
        if svc.session is None:
            if remaining == 0:
                break
            remaining -= 1
    return report, rows


def bench(scale: int, shards: int, budget: int,
          csv_path: Optional[str]) -> List[Tuple[str, float, str]]:
    ds = lubm.load(scale, 0)
    report_a, rows_a = _serve_round(ds, shards, budget=None)
    report_c, rows_c = _serve_round(ds, shards, budget=budget)
    assert report_c.plan.bytes == report_a.plan.bytes, \
        "modes must drain the identical accepted plan"
    rows = rows_a + rows_c

    if csv_path:
        cols = ["mode", "window", "avg_query_ms", "migration_ms",
                "window_avg_ms", "epoch", "chunks", "bytes"]
        with open(csv_path, "w") as fh:
            fh.write(",".join(cols) + "\n")
            for r in rows:
                fh.write(",".join(f"{r[c]:.4f}" if isinstance(r[c], float)
                                  else str(r[c]) for c in cols) + "\n")

    spike = max(r["window_avg_ms"] for r in rows_a)
    worst_chunked = max(r["window_avg_ms"] for r in rows_c)
    steady = rows_c[-1]["window_avg_ms"]
    n_chunks = sum(r["chunks"] for r in rows_c)
    # harness convention (benchmarks.run): values are microseconds
    out = [
        ("migration/atomic_spike_window", spike * 1e3,
         f"plan={report_a.plan.summary().replace(',', ';')}"),
        ("migration/chunked_worst_window", worst_chunked * 1e3,
         f"chunks={n_chunks}_budget={budget}B"),
        ("migration/chunked_steady_window", steady * 1e3,
         f"epochs={rows_c[-1]['epoch']}"),
        ("migration/spike_over_worst_ratio", spike / worst_chunked,
         "chunked_below_spike=" + str(worst_chunked < spike)),
    ]
    return out


def run() -> List[Tuple[str, float, str]]:
    """benchmarks.run harness entry point (writes the CSV as a side effect).
    Values follow the harness convention: microseconds, except the final
    spike/worst ratio row."""
    return bench(SCALE, SHARDS, BUDGET, CSV_PATH)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=SCALE)
    ap.add_argument("--shards", type=int, default=SHARDS)
    ap.add_argument("--budget", type=int, default=BUDGET,
                    help="migration bytes per serving window")
    ap.add_argument("--dry-run", action="store_true",
                    help="small smoke (LUBM(1)/4, no CSV written)")
    args = ap.parse_args()
    if args.dry_run:
        rows = bench(1, 4, 120_000, csv_path=None)
    else:
        rows = bench(args.scale, args.shards, args.budget, CSV_PATH)
    print("name,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    spike = next(v for n, v, _ in rows if n.endswith("atomic_spike_window"))
    worst = next(v for n, v, _ in rows if n.endswith("chunked_worst_window"))
    assert worst < spike, (
        f"chunked drain worst window ({worst:.0f} us) must stay strictly "
        f"below the atomic spike window ({spike:.0f} us)")
    print(f"OK: chunked worst window {worst:.0f} us < atomic spike "
          f"{spike:.0f} us")


if __name__ == "__main__":
    main()
