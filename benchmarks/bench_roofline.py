"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``results/dryrun2/*.json`` (falling back to ``results/dryrun``) and
emits one row per (arch × shape × mesh) with the three roofline terms and the
dominant bottleneck; also writes ``results/roofline.csv``.
"""
from __future__ import annotations

import glob
import json
import os
from pathlib import Path
from typing import List, Tuple

RESULTS = Path(os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun3"))
FALLBACKS = [Path("results/dryrun2"), Path("results/dryrun")]


def load_records():
    d = RESULTS
    for fb in FALLBACKS:
        if d.exists():
            break
        d = fb
    recs = []
    for f in sorted(glob.glob(str(d / "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:  # noqa: BLE001
            pass
    return recs


def run() -> List[Tuple[str, float, str]]:
    rows = []
    csv_lines = ["arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
                 "dominant,useful_flops_ratio,roofline_fraction"]
    for rec in load_records():
        name = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("skipped"):
            rows.append((f"roofline/{name}", 0.0,
                         "SKIP:" + rec["reason"][:50].replace(",", ";")))
            csv_lines.append(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                             f",,,skip,,")
            continue
        if "error" in rec:
            rows.append((f"roofline/{name}", 0.0, "ERROR"))
            continue
        r = rec["roofline"]
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append((
            f"roofline/{name}", bound * 1e6,
            f"dom={r['dominant']}_frac={r['roofline_fraction']:.3f}"
            f"_useful={r['useful_flops_ratio']:.2f}"))
        csv_lines.append(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},"
            f"{r['t_compute'] * 1e3:.3f},{r['t_memory'] * 1e3:.3f},"
            f"{r['t_collective'] * 1e3:.3f},{r['dominant']},"
            f"{r['useful_flops_ratio']:.3f},{r['roofline_fraction']:.4f}")
    out = Path("results/roofline.csv")
    out.parent.mkdir(exist_ok=True)
    out.write_text("\n".join(csv_lines) + "\n")
    return rows
