"""Live-write benchmark: serving under data drift, adaptive vs frozen.

Every mode serves the same windows of the same LUBM workload while a write
stream grows a *hot* feature set (hot-feature-growth drift): each window
inserts ``ratio`` new graduate students, every one carrying a triple of a
write-born ``bench:tag`` predicate (a feature the bootstrap partition never
saw — it is placed workload-blind on the least-loaded shard) plus a
``takesCourse GraduateCourse0`` row (growing the workload-tracked PO
feature Q1 reads). A drift query joining both rides the serving window, so
its matches — and the shipping cost of every row homed off its PPN — grow
linearly with the writes.

The sweep variable is the write ratio; the comparison inside each ratio is
``adaptive`` (``maybe_adapt`` after every window: write heat + query heat
feed the cost-aware round, accepted plans drain chunk-by-chunk under the
migration budget while serving continues) vs ``static`` (identical writes
and windows, never adapts — the post-bootstrap layout is frozen). Window
time is the average modeled query time plus the window's amortized
migration stall, so the adaptive mode pays for its own migrations.

``results/exp_writes.csv`` holds the per-window series; the summary asserts
that at the largest ratio the adaptive session's average post-drift window
time is strictly below the frozen baseline's.

  PYTHONPATH=src python benchmarks/bench_writes.py            # LUBM(3)/8
  PYTHONPATH=src python benchmarks/bench_writes.py --dry-run  # LUBM(1)/4
  PYTHONPATH=src python -m benchmarks.run --only writes       # harness row
"""
from __future__ import annotations

import argparse
import os
from typing import List, Optional, Tuple

import numpy as np

from repro.api import KGService
from repro.graph import lubm
from repro.query import exec as qexec
from repro.query.pattern import Query, var

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "3"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
MIG_BUDGET = int(os.environ.get("REPRO_BENCH_MIG_BUDGET", str(1 << 20)))
REPLICA_BUDGET = int(os.environ.get("REPRO_BENCH_REPLICA_BUDGET",
                                    str(1 << 20)))
RATIOS = (0, 100, 400)                 # new students inserted per window
WINDOWS = int(os.environ.get("REPRO_BENCH_WINDOWS", "10"))
CSV_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "exp_writes.csv")


def _canon(b):
    if not b:
        return []
    keys = sorted(b)
    return sorted(map(tuple, np.stack([b[k] for k in keys],
                                      axis=1).tolist()))


def _drift_setup(ds):
    """The drift vocabulary and query: new students tagged with a write-born
    predicate, joined against the course they all take."""
    d = ds.dictionary
    tag = d.encode("bench:tag")
    hub = d.encode("bench:hub")
    take = d.lookup("ub:takesCourse")
    X, Y = var(0), var(1)
    drift_q = Query(name="W1", shape="star", frequency=4.0,
                    patterns=((X, tag, hub), (X, take, Y)))
    return tag, hub, take, drift_q


def _drift_rows(svc, ds, tag, hub, take, n):
    """``n`` fresh students: one write-born-feature row + one row growing
    the workload-tracked PO(takesCourse, GraduateCourse0) feature each.
    Subjects come from ``svc.fresh_ids`` — entity ids live past the
    dictionary, so encoding invented terms would collide with real
    entities."""
    rows = []
    for s in svc.fresh_ids(n).tolist():
        rows.append([s, tag, hub])
        rows.append([s, take, ds.named.grad_course0])
    return rows


def _serve(ds, shards, ratio, windows, adaptive, mig_budget,
           replica_budget) -> List[dict]:
    tag, hub, take, drift_q = _drift_setup(ds)
    svc = KGService.from_dataset(ds, shards, migration_budget=mig_budget,
                                 replica_budget=replica_budget)
    svc.bootstrap(ds.base_workload())
    net = svc.net or qexec.NetworkModel()
    window = ds.workload(["Q1"] + [f"EQ{i}" for i in range(1, 11)],
                         {"Q1": 4.0})
    if ratio:                      # the drift query needs the drifting data
        window = window + [drift_q]

    rows, written, accepted = [], 0, 0
    for w in range(windows):
        if ratio:
            report = svc.insert(_drift_rows(svc, ds, tag, hub, take, ratio))
            assert report.effective
            written += ratio
        sess, stalled = svc.session, 0
        applied0 = sess.bytes_applied if sess else 0
        results = svc.query_batch(window)
        if sess is not None:
            stalled = sess.bytes_applied - applied0
        stats = [st for _, st in results]
        avg_ms = float(np.mean([st.modeled_time(net)
                                for st in stats])) * 1e3
        stall_ms = stalled / net.bandwidth_Bps * 1e3
        w1 = next((len(_canon(b)) for q, (b, _) in zip(window, results)
                   if q.name == "W1"), 0)
        rows.append(dict(
            ratio=ratio, mode="adaptive" if adaptive else "static",
            window=w, epoch=svc.kg.epoch, avg_query_ms=avg_ms,
            window_ms=avg_ms + stall_ms / max(len(window), 1),
            bytes_shipped=sum(st.bytes_shipped for st in stats),
            w1_rows=w1, store_triples=svc.kg.store.n_triples,
            replicated_features=len(svc.kg.replicas.replicated()),
            adapt_accepted=0))
        if adaptive:
            report = svc.maybe_adapt(window)
            if report is not None and report.accepted:
                accepted += 1
                rows[-1]["adapt_accepted"] = 1
    svc.drain()
    if ratio:
        assert svc.write_log.n_inserted == 2 * written
        if adaptive:
            assert accepted >= 1, \
                "adaptive mode never accepted a round under drift"
    return rows


def bench(scale, shards, ratios, windows, mig_budget, replica_budget,
          csv_path: Optional[str],
          perf_assert: bool = True) -> List[Tuple[str, float, str]]:
    ds = lubm.load(scale, 0)
    all_rows: List[dict] = []
    steady = {}                        # (ratio, mode) -> post-drift mean ms
    for ratio in sorted(set(ratios)):
        for adaptive in (False, True):
            series = _serve(ds, shards, ratio, windows, adaptive,
                            mig_budget, replica_budget)
            all_rows += series
            tail = series[len(series) // 2:]
            steady[(ratio, adaptive)] = float(
                np.mean([r["window_ms"] for r in tail]))

    if csv_path:
        cols = ["ratio", "mode", "window", "epoch", "avg_query_ms",
                "window_ms", "bytes_shipped", "w1_rows", "store_triples",
                "replicated_features", "adapt_accepted"]
        with open(csv_path, "w") as fh:
            fh.write(",".join(cols) + "\n")
            for r in all_rows:
                fh.write(",".join(f"{r[c]:.4f}" if isinstance(r[c], float)
                                  else str(r[c]) for c in cols) + "\n")

    out: List[Tuple[str, float, str]] = []
    for ratio in sorted(set(ratios)):
        stat, adap = steady[(ratio, False)], steady[(ratio, True)]
        out.append((f"writes/window_ms_static_r{ratio}", stat, ""))
        out.append((f"writes/window_ms_adaptive_r{ratio}", adap,
                    f"reduction={1 - adap / stat:.3f}"))
    top = max(r for r in ratios)
    out.append(("writes/top_ratio_adaptive_speedup",
                steady[(top, False)] / max(steady[(top, True)], 1e-12),
                f"ratio={top}_windows={windows}"))
    if perf_assert:
        assert steady[(top, True)] < steady[(top, False)], (
            f"adaptive must beat the frozen layout under drift: "
            f"{steady[(top, True)]:.3f} ms vs {steady[(top, False)]:.3f} ms")
    return out


def run() -> List[Tuple[str, float, str]]:
    """benchmarks.run harness entry point (writes the CSV as a side effect).
    Harness convention: values are window milliseconds, plus a final
    speedup ratio row."""
    return bench(SCALE, SHARDS, RATIOS, WINDOWS, MIG_BUDGET,
                 REPLICA_BUDGET, CSV_PATH)


def _dry_run() -> None:
    """Mechanics smoke (LUBM(1)/4, no CSV, no perf assertion): drift writes
    land, the drift query's matches grow window over window, adaptation
    runs concurrently, and all executors agree on the final mutated graph."""
    ds = lubm.load(1, seed=0)
    tag, hub, take, drift_q = _drift_setup(ds)
    svc = KGService.from_dataset(ds, 4, migration_budget=120_000,
                                 replica_budget=256_000)
    svc.bootstrap(ds.base_workload())
    window = ds.workload(["Q1"] + [f"EQ{i}" for i in range(1, 11)])
    grown = []
    for w in range(4):
        rep = svc.insert(_drift_rows(svc, ds, tag, hub, take, 64))
        assert rep.effective and rep.n_inserted == 128
        results = svc.query_batch(window + [drift_q])
        grown.append(len(_canon(results[-1][0])))
        svc.maybe_adapt(window + [drift_q])
    svc.drain()
    assert grown == [64, 128, 192, 256], grown
    assert svc.write_log.n_inserted == 4 * 128
    plans = [svc.kg.plan(q) for q in window + [drift_q]]
    ref = qexec.NumpyExecutor().run_batch(plans, svc.kg)
    for name in ("jax", "jax-pallas"):
        got = qexec.get_executor(name).run_batch(plans, svc.kg)
        for (rb, rs), (gb, gs) in zip(ref, got):
            assert _canon(rb) == _canon(gb), name
            for f in qexec.ExecStats.COMPARABLE:
                assert getattr(rs, f) == getattr(gs, f), (name, f)
    print(f"OK: drift query grew {grown[0]} -> {grown[-1]} rows over "
          f"{len(grown)} windows, {svc.write_log.n_inserted} triples "
          f"written, final epoch {svc.kg.epoch}, executors identical")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=SCALE)
    ap.add_argument("--shards", type=int, default=SHARDS)
    ap.add_argument("--ratios", default=",".join(map(str, RATIOS)),
                    help="comma-separated students inserted per window "
                         "(0 = read-only control)")
    ap.add_argument("--windows", type=int, default=WINDOWS)
    ap.add_argument("--migration-budget", type=int, default=MIG_BUDGET)
    ap.add_argument("--replica-budget", type=int, default=REPLICA_BUDGET)
    ap.add_argument("--dry-run", action="store_true",
                    help="small mechanics smoke (LUBM(1)/4, no CSV)")
    args = ap.parse_args()
    if args.dry_run:
        _dry_run()
        return
    ratios = tuple(int(r) for r in args.ratios.split(","))
    rows = bench(args.scale, args.shards, ratios, args.windows,
                 args.migration_budget, args.replica_budget, CSV_PATH)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    top = max(ratios)
    speedup = next(v for n, v, _ in rows if n.endswith("speedup"))
    print(f"OK: adaptive serves drifted windows {speedup:.2f}x faster than "
          f"the frozen layout at ratio {top}")


if __name__ == "__main__":
    main()
