"""Streaming-admission benchmark: open-loop arrival-rate sweep, pipelined
windows vs the synchronous serving discipline.

Clients replay an open-loop arrival process (they fire at ``rate_qps``
regardless of completions — queueing delay lands in the tail the moment
the system saturates) of the extended LUBM workload, with write batches
admitted mid-stream and an accepted adaptation round's migration draining
concurrently. Both modes run the *identical* admission script over
identical stores and must produce byte-identical bindings; the only
difference is the accounting discipline:

* ``sync``       — ``pipeline=False``: every stall (write fanout, the
  per-window migration chunk, plan builds) is head-of-line, exactly the
  synchronous ``query_batch`` loop's behaviour.
* ``pipelined``  — ``pipeline=True``: window N+1's plans are pre-staged
  and the drainer's chunks retire while window N executes, so stalls
  hide behind execution time and idle gaps.

``results/exp_streaming.csv`` holds the per-window p50/p95/p99 series per
``(mode, rate)``; the summary asserts the pipelined discipline beats the
synchronous one on p95 at the highest arrival rate.

  PYTHONPATH=src python benchmarks/bench_streaming.py            # LUBM(3)/8
  PYTHONPATH=src python benchmarks/bench_streaming.py --dry-run  # LUBM(1)/4
  PYTHONPATH=src python -m benchmarks.run --only streaming       # harness row
"""
from __future__ import annotations

import argparse
import csv
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import write as kgwrite
from repro.api import KGService, WriteBatch
from repro.graph import lubm
from repro.graph.triples import TripleStore
from repro.stream import interleave, open_loop_arrivals, replay

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "3"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
MIG_BUDGET = int(os.environ.get("REPRO_BENCH_MIG_BUDGET", str(1 << 20)))
RATES = (50.0, 200.0, 800.0)           # open-loop arrival rates (queries/s)
REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "6"))
WRITE_EVERY = 24                       # one write batch per workload pass
WRITE_ROWS = 64
CSV_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "exp_streaming.csv")


def _canon(b):
    if not b:
        return []
    keys = sorted(b)
    return sorted(map(tuple, np.stack([b[k] for k in keys],
                                      axis=1).tolist()))


def _fresh_service(ds, shards) -> KGService:
    """A service over a COPY of the (memoized) dataset's store: the write
    path mutates stores in place, and every mode/rate replay must start
    from the identical graph."""
    store = TripleStore(ds.store.triples.copy(), ds.store.dictionary)
    return KGService(store, shards, migration_budget=MIG_BUDGET,
                     type_predicate=ds.dictionary.lookup("rdf:type"))


def _script(ds, rate_qps: float, repeats: int):
    """One admission script per rate, identical across modes: ``repeats``
    open-loop passes of the extended workload with a write batch heading
    each pass. Subjects are pre-minted from the pristine store (both
    replays apply identical batches in identical admission order, so the
    ids stay fresh for both)."""
    queries = ds.extended_workload() * repeats
    arrivals = open_loop_arrivals(len(queries), rate_qps)
    rng = np.random.default_rng(7)
    take = ds.dictionary.lookup("ub:takesCourse")
    fresh = kgwrite.fresh_entity_ids(ds.store, repeats * WRITE_ROWS)
    writes = []
    for k in range(repeats):
        s = fresh[k * WRITE_ROWS:(k + 1) * WRITE_ROWS].astype(np.int32)
        o = np.where(rng.random(WRITE_ROWS) < 0.5,
                     ds.named.grad_course0,
                     s.astype(np.int64)).astype(np.int32)
        rows = np.stack([s, np.full(WRITE_ROWS, take, np.int32), o], axis=1)
        writes.append((k * WRITE_EVERY, rows))
    return queries, arrivals, writes


def _serve(ds, shards, rate_qps, repeats, pipeline) -> Tuple[object, List]:
    """One replay: bootstrap, accept an adaptation round (its migration
    drains mid-stream), then stream the admission script. Returns the
    stream and its results."""
    svc = _fresh_service(ds, shards)
    svc.bootstrap(ds.base_workload())
    svc.query_batch(ds.extended_workload())
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted and svc.session is not None, \
        "the sweep needs a migration in flight"
    queries, arrivals, writes = _script(ds, rate_qps, repeats)
    events = interleave(
        queries, arrivals,
        [(pos, WriteBatch(inserts=rows.copy())) for pos, rows in writes])
    stream = svc.stream(pipeline=pipeline)
    replay(stream, events)
    assert svc.session is None, "the stream must finish the drain"
    assert svc.write_log.n_inserted > 0
    return stream, stream.poll()


def bench(scale, shards, rates, repeats, csv_path: Optional[str],
          perf_assert: bool = True) -> List[Tuple[str, float, str]]:
    ds = lubm.load(scale, 0)
    all_rows: List[dict] = []
    p95: Dict[Tuple[float, str], float] = {}
    for rate in sorted(set(rates)):
        per_mode = {}
        for mode, pipeline in (("sync", False), ("pipelined", True)):
            stream, results = _serve(ds, shards, rate, repeats, pipeline)
            per_mode[mode] = results
            s = stream.recorder.summary()
            p95[(rate, mode)] = s["p95"]
            all_rows += stream.recorder.window_rows(mode=mode,
                                                    rate_qps=rate)
        # byte-identical across disciplines, query by query
        for a, b in zip(per_mode["sync"], per_mode["pipelined"]):
            assert a.query.name == b.query.name
            assert _canon(a.bindings) == _canon(b.bindings), \
                (rate, a.seq, a.query.name)

    if csv_path:
        with open(csv_path, "w", newline="") as fh:
            writer = csv.DictWriter(fh, fieldnames=list(all_rows[0]))
            writer.writeheader()
            writer.writerows(all_rows)

    out: List[Tuple[str, float, str]] = []
    for rate in sorted(set(rates)):
        sync, pipe = p95[(rate, "sync")], p95[(rate, "pipelined")]
        out.append((f"streaming/p95_ms_sync_r{rate:g}", sync * 1e3, ""))
        out.append((f"streaming/p95_ms_pipelined_r{rate:g}", pipe * 1e3,
                    f"reduction={1 - pipe / max(sync, 1e-12):.3f}"))
    top = max(rates)
    out.append(("streaming/top_rate_p95_speedup",
                p95[(top, "sync")] / max(p95[(top, "pipelined")], 1e-12),
                f"rate={top:g}_repeats={repeats}"))
    if perf_assert:
        assert p95[(top, "pipelined")] < p95[(top, "sync")], (
            f"pipelined windows must beat the synchronous discipline on "
            f"p95 at {top:g} qps: {p95[(top, 'pipelined')] * 1e3:.3f} ms "
            f"vs {p95[(top, 'sync')] * 1e3:.3f} ms")
    return out


def run() -> List[Tuple[str, float, str]]:
    """benchmarks.run harness entry point (writes the CSV as a side
    effect). Harness convention: values are p95 milliseconds per
    ``(mode, rate)``, plus a final speedup ratio row."""
    return bench(SCALE, SHARDS, RATES, REPEATS, CSV_PATH)


def _dry_run() -> None:
    """Mechanics smoke (LUBM(1)/4, no CSV, no perf assertion): both
    disciplines replay the same script with writes and a migration in
    flight, bindings byte-identical, tails recorded per window/shard."""
    ds = lubm.load(1, seed=0)
    streams = {}
    for mode, pipeline in (("sync", False), ("pipelined", True)):
        stream, results = _serve(ds, 4, 200.0, 2, pipeline)
        streams[mode] = (stream, results)
    (ss, rs), (sp, rp) = streams["sync"], streams["pipelined"]
    assert len(rs) == len(rp) == len(ds.extended_workload()) * 2
    for a, b in zip(rs, rp):
        assert _canon(a.bindings) == _canon(b.bindings), a.query.name
    assert sp.now <= ss.now and len(sp.recorder) == len(ss.recorder)
    hidden = sum(w["hidden_s"] for w in sp.window_log)
    assert hidden > 0, "pipelined run hid no stall time"
    summary = sp.recorder.summary()
    assert summary["p50"] <= summary["p95"] <= summary["p99"]
    print(f"OK: {len(rp)} streamed queries byte-identical across "
          f"disciplines, {sp.n_windows} windows, "
          f"{hidden * 1e3:.1f} ms of stalls hidden, pipelined makespan "
          f"{sp.now:.3f}s vs sync {ss.now:.3f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=SCALE)
    ap.add_argument("--shards", type=int, default=SHARDS)
    ap.add_argument("--rates", default=",".join(f"{r:g}" for r in RATES),
                    help="comma-separated open-loop arrival rates (qps)")
    ap.add_argument("--repeats", type=int, default=REPEATS,
                    help="workload passes per replay")
    ap.add_argument("--dry-run", action="store_true",
                    help="small mechanics smoke (LUBM(1)/4, no CSV)")
    args = ap.parse_args()
    if args.dry_run:
        _dry_run()
        return
    rates = tuple(float(r) for r in args.rates.split(","))
    rows = bench(args.scale, args.shards, rates, args.repeats, CSV_PATH)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    speedup = next(v for n, v, _ in rows if n.endswith("speedup"))
    print(f"OK: pipelined windows serve a {speedup:.2f}x lower p95 than "
          f"the synchronous discipline at {max(rates):g} qps")


if __name__ == "__main__":
    main()
