"""Experiment 1 (paper Figs. 7/8/9): workload-composition change.

14 base queries partition the graph; 10 new queries (EQ1..EQ10) arrive; the
adaptive partition must cut the new queries' runtime sharply (paper: 56s ->
21s, 63%) while leaving old queries roughly unchanged (except <= 1 regression,
Q9 in the paper).

Orchestrated through ``repro.api``: the adaptation round evaluates candidate
cuts as incremental deltas on the live ``PartitionedKG`` — no full
``ShardedStore`` re-materialization per candidate — and the workload-window
execution is timed under both ``Executor`` backends (numpy per-query vs the
batched jax path).
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import numpy as np

from repro.api import JaxExecutor, KGService, NumpyExecutor
from repro.graph import lubm

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "10"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    ds = lubm.load(SCALE, 0)
    svc = KGService.from_dataset(ds, SHARDS)
    kg = svc.bootstrap(ds.base_workload())
    setup_s = time.perf_counter() - t0

    extended = ds.extended_workload()
    times0, stats0 = svc.run_workload(extended)
    rebuilds0 = kg.view_rebuilds

    t1 = time.perf_counter()
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    adapt_s = time.perf_counter() - t1
    times1, stats1 = svc.run_workload(extended)

    new_q = [f"EQ{i}" for i in range(1, 11)]
    old_q = [f"Q{i}" for i in range(1, 15)]
    avg = lambda t, qs: float(np.mean([t[q] for q in qs]))

    rows = []
    # Fig. 7: per-query runtimes initial vs adaptive
    regressions = sum(times1[q] > 1.2 * times0[q] + 1e-3 for q in old_q)
    for q in extended:
        rows.append((f"fig7/{q.name}_initial", times0[q.name] * 1e6,
                     f"dj={stats0[q.name].distributed_joins}"))
        rows.append((f"fig7/{q.name}_adaptive", times1[q.name] * 1e6,
                     f"dj={stats1[q.name].distributed_joins}"))
    # Fig. 8: average of all 24
    rows.append(("fig8/all24_initial", avg(times0, list(times0)) * 1e6, ""))
    rows.append(("fig8/all24_adaptive", avg(times1, list(times1)) * 1e6,
                 f"improvement={(1 - avg(times1, list(times1)) / avg(times0, list(times0))) * 100:.1f}%"))
    # Fig. 9: average of the 10 new queries (paper: 63% improvement)
    imp_new = (1 - avg(times1, new_q) / avg(times0, new_q)) * 100
    rows.append(("fig9/new10_initial", avg(times0, new_q) * 1e6, ""))
    rows.append(("fig9/new10_adaptive", avg(times1, new_q) * 1e6,
                 f"improvement={imp_new:.1f}%_paper=63%"))
    rows.append(("exp1/old14_regressions", regressions,
                 "paper_allows<=1(Q9)"))
    rows.append(("exp1/adaptation_time", adapt_s * 1e6,
                 report.plan.summary().replace(",", ";")))
    rows.append(("exp1/adapt_view_rebuilds", kg.view_rebuilds - rebuilds0,
                 f"shards={SHARDS}_incremental_deltas"))
    rows.append(("exp1/setup_time", setup_s * 1e6,
                 f"triples={ds.store.n_triples}"))
    rows.append(("exp1/dj_total_initial",
                 sum(s.distributed_joins for s in stats0.values()), ""))
    rows.append(("exp1/dj_total_adaptive",
                 sum(s.distributed_joins for s in stats1.values()),
                 f"accepted={report.accepted}"))

    # workload-window execution wall time under every probe backend. On
    # this CPU container auto dispatch serves the host probe tier for BOTH
    # "jax" and "jax-pallas" (kernels/oracle engage on TPU only), so those
    # two rows are a parity check; the forced jitted-jnp window ("jax_jit",
    # probe_kernel=True — the PR 2 device path) is the baseline the
    # jax-pallas dispatch policy must beat by refusing per-join device
    # round trips. Plans come from the facade cache — one per (query,
    # store).
    plans = [kg.plan(q) for q in extended]
    walls = {}
    for name, ex in (("numpy", NumpyExecutor()),
                     ("jax", JaxExecutor()),
                     ("jax_jit", JaxExecutor(probe_kernel=True)),
                     ("jax-pallas", JaxExecutor(pallas=True))):
        ex.run_batch(plans, kg)                 # warm-up (jax dispatch/compile)
        walls[name] = min(_timed(ex, plans, kg) for _ in range(3))
    rows.append(("exp1/window_wall_numpy", walls["numpy"] * 1e6,
                 f"queries={len(extended)}_per-query"))
    rows.append(("exp1/window_wall_jax", walls["jax"] * 1e6,
                 f"batched_speedup={walls['numpy'] / walls['jax']:.2f}x"))
    rows.append(("exp1/window_wall_jax_jit", walls["jax_jit"] * 1e6,
                 "forced_jitted_jnp_probe"))
    rows.append(("exp1/window_wall_jax_pallas", walls["jax-pallas"] * 1e6,
                 f"vs_jitted_jnp={walls['jax_jit'] / walls['jax-pallas']:.2f}x"
                 f"_vs_jax_auto={walls['jax'] / walls['jax-pallas']:.2f}x"
                 "_cpu_auto_serves_host_tier"))
    return rows


def _timed(ex, plans, kg) -> float:
    t0 = time.perf_counter()
    ex.run_batch(plans, kg)
    return time.perf_counter() - t0
