"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only exp1,roofline]
  REPRO_BENCH_SCALE=3 ... python -m benchmarks.run     (faster KG benches)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ("clustering", "exp1", "exp2", "migration", "replication",
           "writes", "streaming", "drift", "moe_placement", "kernels",
           "train", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    only = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failures = 0
    for bench in BENCHES:
        if bench not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.bench_{bench}",
                             fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
            print(f"_meta/{bench}_wall_s,{(time.time() - t0) * 1e6:.0f},",
                  flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"_meta/{bench}_FAILED,0,", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
