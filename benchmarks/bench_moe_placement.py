"""AWAPart expert placement: all-to-all bytes saved under clustered routing.

The framework-side reproduction of the paper's core claim: workload-aware
placement of keyed data (experts <-> features) reduces cross-partition
traffic (all-to-all bytes <-> distributed joins).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import placement


def _workload(rng, e, t, k, locality: float):
    """Routing with tunable topic locality: 1.0 = perfectly clustered."""
    n_topics = e // k
    topics = rng.permutation(e).reshape(n_topics, k)
    req_topic = rng.integers(0, n_topics, t)
    routing = np.empty((t, k), dtype=np.int64)
    for i, ti in enumerate(req_topic):
        inside = topics[ti]
        n_in = int(round(locality * k))
        pick = list(rng.permutation(inside)[:n_in])
        while len(pick) < k:
            c = int(rng.integers(0, e))
            if c not in pick:
                pick.append(c)
        routing[i] = pick
    return routing


def run() -> List[Tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    for arch, e, ranks in (("olmoe", 64, 16), ("qwen3moe", 128, 16)):
        for loc in (0.9, 0.5):
            routing = _workload(rng, e, 2048, 8, loc)
            t0 = time.perf_counter()
            e2r, rep = placement.plan_expert_placement(routing, e, ranks)
            plan_us = (time.perf_counter() - t0) * 1e6
            rows.append((
                f"moe_place/{arch}_loc{int(loc * 100)}", plan_us,
                f"ranks/token_{rep.ranks_before:.2f}->{rep.ranks_after:.2f}"
                f"_bytes_saved={rep.bytes_saved_frac * 100:.0f}%"
                f"_accepted={rep.accepted}"))
    # vocab placement
    v = 65536
    counts = 1.0 / (np.arange(v) + 100.0) ** 0.9
    t0 = time.perf_counter()
    perm = placement.vocab_permutation(counts, 16)
    plan_us = (time.perf_counter() - t0) * 1e6
    before = placement.shard_gather_imbalance(
        counts, np.arange(v, dtype=np.int32), 16)
    after = placement.shard_gather_imbalance(counts, perm, 16)
    rows.append(("vocab_place/65536x16", plan_us,
                 f"gather_imbalance_{before:.2f}->{after:.3f}"))
    return rows
