"""Kernel microbenches.

On this CPU container the Pallas kernels execute in interpret mode (Python
per-op), so wall-times compare the *reference jnp paths* (which XLA:CPU
compiles) and validate kernels at small shapes; the kernels' TPU performance
story is carried by the roofline analysis, not CPU timings.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref as fref
from repro.kernels.jaccard import kernel as jkernel
from repro.kernels.jaccard import ref as jref
from repro.kernels.mamba2_ssd import kernel as skernel
from repro.kernels.mamba2_ssd import ref as sref
from repro.kernels.rwkv6_wkv import kernel as wkernel
from repro.kernels.rwkv6_wkv import ref as wref


def _time(fn, n=3):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []

    # jaccard: jnp oracle vs pallas-interpret (correctness-checked timing)
    bm = jnp.asarray(rng.integers(0, 2 ** 32, (256, 32), dtype=np.uint32))
    f_ref = jax.jit(lambda a: jref.jaccard_distance(a, a))
    rows.append(("kern/jaccard256_jnp_us", _time(lambda: f_ref(bm)), ""))
    rows.append(("kern/jaccard256_pallas_interp_us", _time(
        lambda: jkernel.jaccard_distance_pallas(bm, bm, interpret=True),
        n=1), "interpret-mode"))

    # flash attention reference path (jit) at a prefill-ish tile
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    f_attn = jax.jit(lambda q, k, v: fref.attention(q, k, v, causal=True))
    rows.append(("kern/attn512_gqa_jnp_us", _time(lambda: f_attn(q, k, v)),
                 "b1_s512_h8_kv2_d64"))

    # wkv: scan vs chunked kernel (interpret) at small shape
    b, s, h, hd = 1, 128, 2, 32
    r = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(b, s, h, hd)) - 2)),
                    jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    f_wkv = jax.jit(lambda *a: wref.wkv(*a))
    rows.append(("kern/wkv128_scan_us", _time(
        lambda: f_wkv(r, kk, vv, w, u, s0)), ""))

    # ssd: scan vs chunked kernel at small shape
    x = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    cmat = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 256, 2))) * 0.1 + 1e-3,
                     jnp.float32)
    a = jnp.asarray([-1.0, -2.0], jnp.float32)
    d = jnp.asarray([1.0, 1.0], jnp.float32)
    ss0 = jnp.zeros((1, 2, 16, 32), jnp.float32)
    f_ssd = jax.jit(lambda: sref.ssd(x[:, :, 0], bmat, cmat, dt[:, :, 0],
                                     a[0], d[0], ss0[:, 0]))
    rows.append(("kern/ssd256_scan_us", _time(f_ssd), "per-head"))
    return rows
