"""Kernel microbenches.

On this CPU container the Pallas kernels execute in interpret mode (Python
per-op), so wall-times compare the *reference jnp paths* (which XLA:CPU
compiles) and validate kernels at small shapes; the kernels' TPU performance
story is carried by the roofline analysis, not CPU timings.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref as fref
from repro.kernels.jaccard import kernel as jkernel
from repro.kernels.jaccard import ref as jref
from repro.kernels.join import ops as join_ops
from repro.kernels.mamba2_ssd import kernel as skernel
from repro.kernels.mamba2_ssd import ref as sref
from repro.kernels.rwkv6_wkv import kernel as wkernel
from repro.kernels.rwkv6_wkv import ref as wref


def _time(fn, n=3):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _join_fixture(rng, nl: int, nr: int):
    """Probe/build key columns with a 50% hit rate (executor-shaped)."""
    lcs = [rng.integers(0, 2**31 - 1, nl).astype(np.int64) for _ in range(2)]
    rcs = [rng.integers(0, 2**31 - 1, nr).astype(np.int64) for _ in range(2)]
    n = min(nl, nr) // 2
    for c in range(2):
        rcs[c][:n] = lcs[c][:n]
    return lcs, rcs


def join_rows(rng, *, dry_run: bool = False) -> List[Tuple[str, float, str]]:
    """Join-kernel rows: the jitted-jnp oracle (the ``JaxExecutor``
    baseline probe) vs the Pallas word-pair path (interpret on CPU).
    ``--dry-run`` validates the kernel at a tiny shape and skips timings."""
    rows: List[Tuple[str, float, str]] = []
    nl, nr = (64, 64) if dry_run else (4096, 4096)
    lcs, rcs = _join_fixture(rng, nl, nr)
    ref = join_ops.hash_probe_oracle(lcs, rcs)
    got = join_ops.hash_probe(lcs, rcs, use_kernel=True, interpret=True)
    for a, b, name in zip(ref, got, ("order", "lo", "counts")):
        assert np.array_equal(a, b), f"join kernel mismatch: {name}"
    if dry_run:
        rows.append(("kern/join_dry_run_ok", 1.0,
                     f"nl={nl}_nr={nr}_matches={int(ref[2].sum())}"))
        return rows
    t_oracle = _time(lambda: join_ops.hash_probe_oracle(lcs, rcs))
    rows.append((f"kern/join{nl}_probe_jnp_us", t_oracle,
                 "jitted_oracle_2col"))
    rows.append((f"kern/join{nl}_probe_pallas_interp_us", _time(
        lambda: join_ops.hash_probe(lcs, rcs, use_kernel=True,
                                    interpret=True), n=1), "interpret-mode"))
    cols = np.stack(lcs, axis=1)
    rows.append((f"kern/join{nl}_pack_jnp_us", _time(
        lambda: join_ops.pack_keys(cols, use_kernel=False)), ""))
    rows.append((f"kern/join{nl}_pack_pallas_interp_us", _time(
        lambda: join_ops.pack_keys(cols, use_kernel=True, interpret=True),
        n=1), "interpret-mode"))
    return rows


def _staged_host(lcs, rcs):
    """The pre-fusion composite: three granular host ops in sequence."""
    order, lo, counts = join_ops.hash_probe_numpy(lcs, rcs)
    li, pos = join_ops.expand_pairs_numpy(lo, counts)
    return li, order[pos]


def _staged_oracle(lcs, rcs):
    """The staged device tier: every op round-trips host<->device on its
    own, materializing each intermediate on the host between stages."""
    order, lo, counts = join_ops.hash_probe_oracle(lcs, rcs)
    li, pos = join_ops.expand_pairs(lo, counts, use_kernel=False)
    return li, order[pos]


def _lubm_shapes():
    """Record the key columns of every fused-pipeline call in one LUBM(3)/8
    extended-workload window — the acceptance join shapes. Returns the
    captured ``(lcs, rcs)`` pairs (non-empty sides, largest work first) and
    the raw call count."""
    from repro.api import JaxExecutor, KGService
    from repro.graph import lubm

    ds = lubm.load(3, 0)
    svc = KGService.from_dataset(ds, 8)
    kg = svc.bootstrap(ds.base_workload())
    plans = [kg.plan(q) for q in ds.extended_workload()]
    captured = []
    real = join_ops.hash_join_pipeline

    def recording(lcs, rcs, **kw):
        captured.append(([np.asarray(c) for c in lcs],
                         [np.asarray(c) for c in rcs]))
        return real(lcs, rcs, **kw)

    join_ops.hash_join_pipeline = recording
    try:
        JaxExecutor().run_batch(plans, kg)
    finally:
        join_ops.hash_join_pipeline = real
    live = [s for s in captured if len(s[0][0]) and len(s[1][0])]
    live.sort(key=lambda s: len(s[0][0]) * len(s[1][0]), reverse=True)
    return live, len(captured)


def pipeline_rows(rng, *, dry_run: bool = False,
                  ) -> List[Tuple[str, float, str]]:
    """Fused ``hash_join_pipeline`` vs the staged composite it replaced.

    ``--dry-run`` pins all three tiers bit-identical (plus the expand
    kernel alone) at a tiny shape; the full run captures the real LUBM(3)/8
    join shapes, pins oracle parity on every one and pallas-interpret
    parity on the smallest, then times fused-vs-staged on the host and
    device tiers and reports the structural host-transfer counts (fused
    strictly below staged, per the dispatch docs)."""
    rows: List[Tuple[str, float, str]] = []
    if dry_run:
        lcs, rcs = _join_fixture(rng, 64, 48)
        ref_li, ref_ri = _staged_host(lcs, rcs)
        order, lo, counts = join_ops.hash_probe_numpy(lcs, rcs)
        li_k, pos_k = join_ops.expand_pairs(lo, counts, use_kernel=True,
                                            interpret=True)
        li_n, pos_n = join_ops.expand_pairs_numpy(lo, counts)
        assert np.array_equal(li_k, li_n) and np.array_equal(pos_k, pos_n), \
            "expand kernel mismatch"
        for mode, kw in (("numpy", {}), ("oracle", {}),
                         ("pallas", dict(use_kernel=True, interpret=True))):
            li, ri, total = join_ops.hash_join_pipeline(lcs, rcs, mode=mode,
                                                        **kw)
            assert total == len(ref_li), f"fused {mode} total mismatch"
            assert (np.array_equal(li, ref_li)
                    and np.array_equal(ri, ref_ri)), f"fused {mode} mismatch"
        rows.append(("kern/pipeline_dry_run_ok", 1.0,
                     f"modes=3_total={len(ref_li)}"))
        return rows

    # expand microbench at the probe fixture shape
    nl = 4096
    lcs, rcs = _join_fixture(rng, nl, nl)
    _, lo, counts = join_ops.hash_probe_numpy(lcs, rcs)
    total = int(counts.sum())
    rows.append((f"kern/expand{nl}_numpy_us", _time(
        lambda: join_ops.expand_pairs_numpy(lo, counts)), f"total={total}"))
    rows.append((f"kern/expand{nl}_jnp_us", _time(
        lambda: join_ops.expand_pairs(lo, counts, use_kernel=False)),
        "jitted_searchsorted"))
    rows.append((f"kern/expand{nl}_pallas_interp_us", _time(
        lambda: join_ops.expand_pairs(lo, counts, use_kernel=True,
                                      interpret=True), n=1),
        "interpret-mode"))

    shapes, n_calls = _lubm_shapes()
    big = shapes[:6]                    # timing set: the heaviest joins
    rows.append(("kern/pipeline_lubm3_shapes", float(len(big)),
                 f"of_{n_calls}_window_calls_max_nl="
                 f"{max(len(l[0]) for l, _ in big)}"))

    # parity: fused == staged on every timed shape (device oracle tier),
    # and pallas-interpret pinned on the smallest real shapes (interpret
    # runs the grid in Python, so the big shapes stay on the cheap tiers)
    refs = []
    for l, r in big:
        ref = _staged_host(l, r)
        got = join_ops.hash_join_pipeline(l, r, mode="oracle")
        assert (np.array_equal(got[0], ref[0])
                and np.array_equal(got[1], ref[1])), \
            "fused oracle mismatch on LUBM shape"
        refs.append(ref)
    for l, r in shapes[-2:]:
        ref = _staged_host(l, r)
        got = join_ops.hash_join_pipeline(l, r, mode="pallas",
                                          use_kernel=True, interpret=True)
        assert (np.array_equal(got[0], ref[0])
                and np.array_equal(got[1], ref[1])), \
            "fused pallas-interpret mismatch on LUBM shape"

    t_staged = sum(_time(lambda l=l, r=r: _staged_host(l, r))
                   for l, r in big)
    t_fused = sum(_time(lambda l=l, r=r: join_ops.hash_join_pipeline(
        l, r, mode="numpy")) for l, r in big)
    rows.append(("kern/pipeline_staged_host_us", t_staged,
                 "probe+expand+gather_numpy"))
    rows.append(("kern/pipeline_fused_host_us", t_fused,
                 f"speedup_vs_staged={t_staged / t_fused:.2f}x"))
    t_staged_o = sum(_time(lambda l=l, r=r: _staged_oracle(l, r))
                     for l, r in big)
    t_fused_o = sum(_time(lambda l=l, r=r: join_ops.hash_join_pipeline(
        l, r, mode="oracle")) for l, r in big)
    rows.append(("kern/pipeline_staged_jnp_us", t_staged_o,
                 "per-stage_round_trips"))
    rows.append(("kern/pipeline_fused_jnp_us", t_fused_o,
                 f"speedup_vs_staged={t_staged_o / t_fused_o:.2f}x"
                 "_device_resident"))

    # structural host-transfer accounting: fused strictly below staged
    with join_ops.track_transfers() as tf_f:
        for l, r in big:
            join_ops.hash_join_pipeline(l, r, mode="oracle")
    with join_ops.track_transfers() as tf_s:
        for l, r in big:
            _staged_oracle(l, r)
    assert tf_f.total < tf_s.total, \
        "fused pipeline must cross the boundary strictly less than staged"
    rows.append(("kern/pipeline_fused_transfers", float(tf_f.total),
                 f"h2d={tf_f.h2d}_d2h={tf_f.d2h}_staged={tf_s.total}"
                 f"(h2d={tf_s.h2d}_d2h={tf_s.d2h})"))
    l, r = big[0]
    with join_ops.track_transfers() as t_p:
        order, lo, counts = join_ops.hash_probe_oracle(l, r)
    with join_ops.track_transfers() as t_e:
        _, pos = join_ops.expand_pairs(lo, counts, use_kernel=False)
    with join_ops.track_transfers() as t_g:
        order[pos]
    for name, t in (("probe", t_p), ("expand", t_e), ("gather", t_g)):
        rows.append((f"kern/pipeline_staged_{name}_transfers",
                     float(t.total), f"h2d={t.h2d}_d2h={t.d2h}"))

    # kernel tier at a small shape: fused keeps word pairs device-resident
    lcs, rcs = _join_fixture(rng, 64, 48)
    with join_ops.track_transfers() as kf:
        join_ops.hash_join_pipeline(lcs, rcs, mode="pallas",
                                    use_kernel=True, interpret=True)
    with join_ops.track_transfers() as ks:
        order, lo, counts = join_ops.hash_probe(lcs, rcs, use_kernel=True,
                                                interpret=True)
        _, pos = join_ops.expand_pairs(lo, counts, use_kernel=True,
                                       interpret=True)
        join_ops.gather_rows(order, pos, use_kernel=True, interpret=True,
                             bounded_by_len=True)
    assert kf.total < ks.total, \
        "fused kernel tier must cross the boundary strictly less than staged"
    rows.append(("kern/pipeline_pallas_transfers", float(kf.total),
                 f"h2d={kf.h2d}_d2h={kf.d2h}_staged={ks.total}"
                 f"(h2d={ks.h2d}_d2h={ks.d2h})"))
    return rows


def run(*, dry_run: bool = False) -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    if dry_run:
        return join_rows(rng, dry_run=True) + pipeline_rows(rng,
                                                            dry_run=True)
    rows = join_rows(rng)
    rows += pipeline_rows(rng)

    # jaccard: jnp oracle vs pallas-interpret (correctness-checked timing)
    bm = jnp.asarray(rng.integers(0, 2 ** 32, (256, 32), dtype=np.uint32))
    f_ref = jax.jit(lambda a: jref.jaccard_distance(a, a))
    rows.append(("kern/jaccard256_jnp_us", _time(lambda: f_ref(bm)), ""))
    rows.append(("kern/jaccard256_pallas_interp_us", _time(
        lambda: jkernel.jaccard_distance_pallas(bm, bm, interpret=True),
        n=1), "interpret-mode"))

    # flash attention reference path (jit) at a prefill-ish tile
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    f_attn = jax.jit(lambda q, k, v: fref.attention(q, k, v, causal=True))
    rows.append(("kern/attn512_gqa_jnp_us", _time(lambda: f_attn(q, k, v)),
                 "b1_s512_h8_kv2_d64"))

    # wkv: scan vs chunked kernel (interpret) at small shape
    b, s, h, hd = 1, 128, 2, 32
    r = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(b, s, h, hd)) - 2)),
                    jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    f_wkv = jax.jit(lambda *a: wref.wkv(*a))
    rows.append(("kern/wkv128_scan_us", _time(
        lambda: f_wkv(r, kk, vv, w, u, s0)), ""))

    # ssd: scan vs chunked kernel at small shape
    x = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    cmat = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 256, 2))) * 0.1 + 1e-3,
                     jnp.float32)
    a = jnp.asarray([-1.0, -2.0], jnp.float32)
    d = jnp.asarray([1.0, 1.0], jnp.float32)
    ss0 = jnp.zeros((1, 2, 16, 32), jnp.float32)
    f_ssd = jax.jit(lambda: sref.ssd(x[:, :, 0], bmat, cmat, dt[:, :, 0],
                                     a[0], d[0], ss0[:, 0]))
    rows.append(("kern/ssd256_scan_us", _time(f_ssd), "per-head"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="validate the join kernel at a tiny shape and exit")
    ap.add_argument("--csv", default=None,
                    help="also write the rows to this CSV path "
                         "(e.g. results/exp_kernels.csv)")
    args = ap.parse_args()
    rows = run(dry_run=args.dry_run)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write("name,us_per_call,derived\n")
            for name, us, derived in rows:
                fh.write(f"{name},{us:.1f},{derived}\n")


if __name__ == "__main__":
    main()
