"""Kernel microbenches.

On this CPU container the Pallas kernels execute in interpret mode (Python
per-op), so wall-times compare the *reference jnp paths* (which XLA:CPU
compiles) and validate kernels at small shapes; the kernels' TPU performance
story is carried by the roofline analysis, not CPU timings.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref as fref
from repro.kernels.jaccard import kernel as jkernel
from repro.kernels.jaccard import ref as jref
from repro.kernels.join import ops as join_ops
from repro.kernels.mamba2_ssd import kernel as skernel
from repro.kernels.mamba2_ssd import ref as sref
from repro.kernels.rwkv6_wkv import kernel as wkernel
from repro.kernels.rwkv6_wkv import ref as wref


def _time(fn, n=3):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _join_fixture(rng, nl: int, nr: int):
    """Probe/build key columns with a 50% hit rate (executor-shaped)."""
    lcs = [rng.integers(0, 2**31 - 1, nl).astype(np.int64) for _ in range(2)]
    rcs = [rng.integers(0, 2**31 - 1, nr).astype(np.int64) for _ in range(2)]
    n = min(nl, nr) // 2
    for c in range(2):
        rcs[c][:n] = lcs[c][:n]
    return lcs, rcs


def join_rows(rng, *, dry_run: bool = False) -> List[Tuple[str, float, str]]:
    """Join-kernel rows: the jitted-jnp oracle (the ``JaxExecutor``
    baseline probe) vs the Pallas word-pair path (interpret on CPU).
    ``--dry-run`` validates the kernel at a tiny shape and skips timings."""
    rows: List[Tuple[str, float, str]] = []
    nl, nr = (64, 64) if dry_run else (4096, 4096)
    lcs, rcs = _join_fixture(rng, nl, nr)
    ref = join_ops.hash_probe_oracle(lcs, rcs)
    got = join_ops.hash_probe(lcs, rcs, use_kernel=True, interpret=True)
    for a, b, name in zip(ref, got, ("order", "lo", "counts")):
        assert np.array_equal(a, b), f"join kernel mismatch: {name}"
    if dry_run:
        rows.append(("kern/join_dry_run_ok", 1.0,
                     f"nl={nl}_nr={nr}_matches={int(ref[2].sum())}"))
        return rows
    t_oracle = _time(lambda: join_ops.hash_probe_oracle(lcs, rcs))
    rows.append((f"kern/join{nl}_probe_jnp_us", t_oracle,
                 "jitted_oracle_2col"))
    rows.append((f"kern/join{nl}_probe_pallas_interp_us", _time(
        lambda: join_ops.hash_probe(lcs, rcs, use_kernel=True,
                                    interpret=True), n=1), "interpret-mode"))
    cols = np.stack(lcs, axis=1)
    rows.append((f"kern/join{nl}_pack_jnp_us", _time(
        lambda: join_ops.pack_keys(cols, use_kernel=False)), ""))
    rows.append((f"kern/join{nl}_pack_pallas_interp_us", _time(
        lambda: join_ops.pack_keys(cols, use_kernel=True, interpret=True),
        n=1), "interpret-mode"))
    return rows


def run(*, dry_run: bool = False) -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    if dry_run:
        return join_rows(rng, dry_run=True)
    rows = join_rows(rng)

    # jaccard: jnp oracle vs pallas-interpret (correctness-checked timing)
    bm = jnp.asarray(rng.integers(0, 2 ** 32, (256, 32), dtype=np.uint32))
    f_ref = jax.jit(lambda a: jref.jaccard_distance(a, a))
    rows.append(("kern/jaccard256_jnp_us", _time(lambda: f_ref(bm)), ""))
    rows.append(("kern/jaccard256_pallas_interp_us", _time(
        lambda: jkernel.jaccard_distance_pallas(bm, bm, interpret=True),
        n=1), "interpret-mode"))

    # flash attention reference path (jit) at a prefill-ish tile
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    f_attn = jax.jit(lambda q, k, v: fref.attention(q, k, v, causal=True))
    rows.append(("kern/attn512_gqa_jnp_us", _time(lambda: f_attn(q, k, v)),
                 "b1_s512_h8_kv2_d64"))

    # wkv: scan vs chunked kernel (interpret) at small shape
    b, s, h, hd = 1, 128, 2, 32
    r = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(b, s, h, hd)) - 2)),
                    jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    f_wkv = jax.jit(lambda *a: wref.wkv(*a))
    rows.append(("kern/wkv128_scan_us", _time(
        lambda: f_wkv(r, kk, vv, w, u, s0)), ""))

    # ssd: scan vs chunked kernel at small shape
    x = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    cmat = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 256, 2))) * 0.1 + 1e-3,
                     jnp.float32)
    a = jnp.asarray([-1.0, -2.0], jnp.float32)
    d = jnp.asarray([1.0, 1.0], jnp.float32)
    ss0 = jnp.zeros((1, 2, 16, 32), jnp.float32)
    f_ssd = jax.jit(lambda: sref.ssd(x[:, :, 0], bmat, cmat, dt[:, :, 0],
                                     a[0], d[0], ss0[:, 0]))
    rows.append(("kern/ssd256_scan_us", _time(f_ssd), "per-head"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="validate the join kernel at a tiny shape and exit")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(dry_run=args.dry_run):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
