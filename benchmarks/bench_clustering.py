"""Fig. 3/4: HAC over the 14 LUBM queries + clustering-path microbenches."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.core import hac
from repro.core.features import FeatureSpace
from repro.graph import lubm
from repro.kernels.jaccard import ops as jops


def _time(fn, n=5):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") \
            else out
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Tuple[str, float, str]]:
    ds = lubm.load(1, 0)
    space = FeatureSpace(ds.store,
                         type_predicate=ds.dictionary.lookup("rdf:type"))
    base = ds.base_workload()
    space.track_workload(base)
    bitmaps = space.workload_bitmaps(base)
    dist = np.asarray(jops.jaccard_distance(bitmaps))
    z = hac.hac_numpy(dist, "single")   # the paper's Fig.-3 dendrogram run
    labels = hac.cut(z, 0.75)

    rows = [
        ("fig3/hac_14queries_us", _time(
            lambda: hac.hac_numpy(dist, "single")),
         f"clusters@0.75={labels.max() + 1}"),
        ("fig3/jaccard_14x14_us", _time(
            lambda: jops.jaccard_distance(bitmaps, use_kernel=False)), ""),
    ]
    # larger clustering loads (the adaptation-path hot spot)
    rng = np.random.default_rng(0)
    for n in (128, 512):
        bm = rng.integers(0, 2 ** 32, size=(n, 32), dtype=np.uint32)
        rows.append((f"jaccard/{n}x{n}_jnp_us", _time(
            lambda bm=bm: jops.jaccard_distance(bm, use_kernel=False)), ""))
        d = np.asarray(jops.jaccard_distance(bm, use_kernel=False))
        rows.append((f"hac/{n}_numpy_us", _time(
            lambda d=d: hac.hac_numpy(d, "single"), n=2), ""))
        rows.append((f"hac/{n}_jax_us", _time(
            lambda d=d: hac.hac_jax(d.astype(np.float32), "single"), n=2),
            ""))
    return rows
