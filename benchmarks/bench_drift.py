"""Drift-reactivity benchmark: adaptive vs frozen layouts under workload
dynamics, across all partitioner strategies, on the WatDiv family.

Every arm replays the *same* seeded drift schedule (``repro.scenario``)
over a WatDiv graph: a flash crowd concentrating ~90% of traffic on one
previously-cold feature family, and a diurnal focus shift oscillating
between the retail and review mixes. The question is not which layout is
fastest in steady state but how each *reacts* when the mix moves:

* degradation **depth** — peak window time over the pre-drift baseline;
* **time-to-recover** — windows until back within ``RECOVER_MARGIN`` of
  the pre-drift level for that mix;
* **bytes per recovery** — migration + replica traffic spent getting back.

Modes: ``hash/frozen`` and ``wawpart/frozen`` serve their bootstrap
layouts unchanged; ``awapart/frozen`` adapts during the first (warm-up)
phase only — so it meets the first onset from the same well-tuned layout
as the adaptive arm — and ``awapart/adaptive`` runs the full Fig.-5 loop
(``maybe_adapt`` every window, accepted plans drained under the migration
budget, hot features promoted as read replicas). Baselines anchor to the
most recent same-mix phase, so recurring phases are judged like with
like (see ``repro.scenario.reactivity``).

``results/exp_drift.csv`` holds the per-window series for every
(scenario, mode); the summary asserts the paper's adaptivity claim under
drift: the adaptive arm recovers every onset to within
``RECOVER_MARGIN`` of its pre-drift window latency, while every frozen
arm misses at least one onset the adaptive arm recovers.

  PYTHONPATH=src python benchmarks/bench_drift.py             # WatDiv(1)/8
  PYTHONPATH=src python benchmarks/bench_drift.py --dry-run   # WatDiv(1)/4
  PYTHONPATH=src python -m benchmarks.run --only drift        # harness row
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.api import (KGService, HashPartitioner, WawPartitioner,
                       AWAPartitioner)
from repro.graph import watdiv
from repro.query import exec as qexec
from repro import scenario as drift

SCALE = int(os.environ.get("REPRO_BENCH_SCALE_WATDIV", "1"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
MIG_BUDGET = int(os.environ.get("REPRO_BENCH_MIG_BUDGET", str(1 << 20)))
REPLICA_BUDGET = int(os.environ.get("REPRO_BENCH_REPLICA_BUDGET",
                                    str(1 << 20)))
SCENARIOS = ("flash_crowd", "diurnal")
MODES = ("hash/frozen", "wawpart/frozen", "awapart/frozen",
         "awapart/adaptive")
RECOVER_MARGIN = 0.2                   # "within 20% of pre-drift latency"
SEED = 3
CSV_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "exp_drift.csv")

_FACTORIES = {"flash_crowd": drift.flash_crowd, "diurnal": drift.diurnal,
              "hot_set_churn": drift.hot_set_churn,
              "mixed_read_write": drift.mixed_read_write}


def _service(ds, mode: str, shards: int, mig_budget: Optional[int],
             replica_budget: int) -> KGService:
    strategy = mode.split("/")[0]
    if strategy == "hash":
        return KGService.from_dataset(ds, shards,
                                      partitioner=HashPartitioner())
    if strategy == "wawpart":
        return KGService.from_dataset(ds, shards,
                                      partitioner=WawPartitioner())
    return KGService.from_dataset(ds, shards, partitioner=AWAPartitioner(),
                                  migration_budget=mig_budget,
                                  replica_budget=replica_budget)


def _replay(ds, scenario_name: str, mode: str, shards: int,
            mig_budget: Optional[int], replica_budget: int,
            seed: int) -> drift.ReactivityReport:
    scn = _FACTORIES[scenario_name](ds, seed=seed)
    svc = _service(ds, mode, shards, mig_budget, replica_budget)
    svc.bootstrap(scn.bootstrap_workload(ds))
    return drift.run_scenario(
        svc, scn, ds, adapt=mode.endswith("adaptive"), mode=mode,
        margin=RECOVER_MARGIN,
        warmup_phases=1 if mode.startswith("awapart") else 0)


def _write_csv(reports: List[drift.ReactivityReport], path: str) -> None:
    """Per-window series for every (scenario, mode) arm. ``mix_id`` is a
    small per-scenario index standing in for the window's mix identity
    (recurring phases share it) — ``make_table.py`` re-derives the
    same-mix recovery baselines from it without importing repro."""
    cols = ["scenario", "mode", "window", "phase", "onset", "mix_id",
            "n_queries", "write_rows", "avg_ms", "stall_bytes", "window_ms",
            "bytes_shipped", "epoch", "adapted"]
    with open(path, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for rep in reports:
            mix_ids: Dict[str, int] = {}
            for w in rep.windows:
                mid = mix_ids.setdefault(w.mix_key, len(mix_ids))
                fh.write(",".join(map(str, [
                    rep.scenario, rep.mode, w.index, w.phase, int(w.onset),
                    mid, w.n_queries, w.write_rows, f"{w.avg_ms:.4f}",
                    w.stall_bytes, f"{w.window_ms:.4f}", w.bytes_shipped,
                    w.epoch, int(w.adapted)])) + "\n")


def bench(scale: int, shards: int, scenarios, modes, mig_budget,
          replica_budget, seed: int, csv_path: Optional[str],
          perf_assert: bool = True) -> List[Tuple[str, float, str]]:
    ds = watdiv.load(scale, seed=0)
    reports: List[drift.ReactivityReport] = []
    by_arm: Dict[Tuple[str, str], drift.ReactivityReport] = {}
    for scenario in scenarios:
        for mode in modes:
            rep = _replay(ds, scenario, mode, shards, mig_budget,
                          replica_budget, seed)
            reports.append(rep)
            by_arm[(scenario, mode)] = rep
    if csv_path:
        _write_csv(reports, csv_path)

    out: List[Tuple[str, float, str]] = []
    for scenario in scenarios:
        for mode in modes:
            rep = by_arm[(scenario, mode)]
            s = rep.summary()
            out.append((f"drift/{scenario}_{mode.replace('/', '_')}",
                        s["worst_depth"],
                        f"recovered={int(s['recovered'])}/{int(s['onsets'])}"
                        f"_ttr={int(s['max_ttr'])}"
                        f"_bytes={int(s['bytes_spent'])}"))
    if perf_assert:
        for scenario in scenarios:
            adaptive = by_arm.get((scenario, "awapart/adaptive"))
            if adaptive is None:
                continue
            assert all(r.recovered for r in adaptive.recoveries), (
                f"{scenario}: adaptive arm failed to recover within "
                f"{RECOVER_MARGIN:.0%} of the pre-drift window latency: "
                f"{adaptive.recoveries}")
            won = [r.onset for r in adaptive.recoveries if r.recovered]
            onsets = sorted(won) + [len(adaptive.windows)]
            spans = {a: (a, b) for a, b in zip(onsets, onsets[1:])}

            def _span_mean(rep, onset):
                a, b = spans[onset]
                return float(np.mean([w.window_ms
                                      for w in rep.windows[a:b]]))
            # the like-for-like frozen arm (same warmed-up layout, never
            # reacts) must miss an onset the adaptive arm recovers
            if "awapart/frozen" in modes:
                frozen = by_arm[(scenario, "awapart/frozen")]
                missed = [r.onset for r in frozen.recoveries
                          if not r.recovered and r.onset in won]
                assert missed, (
                    f"{scenario}/awapart/frozen: frozen layout recovered "
                    f"every onset the adaptive arm did — drift too easy "
                    f"to measure reactivity: {frozen.recoveries}")
                # ... and on those spans the adaptive arm is absolutely
                # faster even while paying for its own migrations
                for onset in missed:
                    assert _span_mean(adaptive, onset) < \
                        _span_mean(frozen, onset), (scenario, onset)
            # workload-blind / never-adapting strategies: whatever their
            # recovery bookkeeping says, their drifted spans must not beat
            # the adaptive arm's absolute window latency
            for mode in modes:
                if mode.startswith("awapart"):
                    continue
                frozen = by_arm[(scenario, mode)]
                worse = [o for o in won
                         if _span_mean(frozen, o) > _span_mean(adaptive, o)]
                assert worse, (
                    f"{scenario}/{mode}: static layout served every "
                    f"drifted span faster than the adaptive arm")
    return out


def run() -> List[Tuple[str, float, str]]:
    """benchmarks.run harness entry point (writes the CSV as a side
    effect). Harness convention: values are degradation depths (peak over
    pre-drift baseline); recovery/ttr/bytes ride in the derived column."""
    return bench(SCALE, SHARDS, SCENARIOS, MODES, MIG_BUDGET,
                 REPLICA_BUDGET, SEED, CSV_PATH)


def _canon(b):
    if not b:
        return []
    keys = sorted(b)
    return sorted(map(tuple, np.stack([b[k] for k in keys],
                                      axis=1).tolist()))


def _dry_run() -> None:
    """Mechanics smoke (WatDiv(1)/4, short flash crowd, no CSV, no perf
    assertion): schedule is deterministic, both arms replay it end to end,
    reactivity telemetry is populated, and all executors agree bindings on
    the drifted workload."""
    ds = watdiv.load(1, seed=0)
    scn = drift.flash_crowd(ds, warm=2, spike=2, cool=1,
                            queries_per_window=6, seed=SEED)
    windows = scn.schedule(ds)
    again = scn.schedule(ds)
    assert [[q.name for q in w.queries] for w in windows] == \
           [[q.name for q in w.queries] for w in again], "schedule drifts"
    reports = {}
    for mode in ("awapart/adaptive", "awapart/frozen"):
        svc = _service(ds, mode, 4, MIG_BUDGET, REPLICA_BUDGET)
        svc.bootstrap(scn.bootstrap_workload(ds))
        rep = drift.run_scenario(svc, scn, ds,
                                 adapt=mode.endswith("adaptive"), mode=mode,
                                 margin=RECOVER_MARGIN, warmup_phases=1)
        assert len(rep.windows) == len(windows)
        assert [r.onset for r in rep.recoveries] == \
               [w.index for w in windows if w.onset]
        assert all(r.baseline_ms > 0 for r in rep.recoveries)
        reports[mode] = rep
    svc = _service(ds, "awapart/adaptive", 4, MIG_BUDGET, REPLICA_BUDGET)
    svc.bootstrap(scn.bootstrap_workload(ds))
    svc.drain()
    probe = windows[-1].queries
    plans = [svc.kg.plan(q) for q in probe]
    ref = qexec.NumpyExecutor().run_batch(plans, svc.kg)
    for name in ("jax", "jax-pallas"):
        got = qexec.get_executor(name).run_batch(plans, svc.kg)
        for (rb, rs), (gb, gs) in zip(ref, got):
            assert _canon(rb) == _canon(gb), name
            for f in qexec.ExecStats.COMPARABLE:
                assert getattr(rs, f) == getattr(gs, f), (name, f)
    ad = reports["awapart/adaptive"].summary()
    print(f"OK: {len(windows)} windows x 2 arms replayed, "
          f"{int(ad['onsets'])} onsets, adaptive recovered "
          f"{int(ad['recovered'])}, executors identical")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=SCALE)
    ap.add_argument("--shards", type=int, default=SHARDS)
    ap.add_argument("--scenarios", default=",".join(SCENARIOS),
                    help="comma-separated subset of: "
                         + ",".join(_FACTORIES))
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--migration-budget", type=int, default=MIG_BUDGET)
    ap.add_argument("--replica-budget", type=int, default=REPLICA_BUDGET)
    ap.add_argument("--dry-run", action="store_true",
                    help="small mechanics smoke (WatDiv(1)/4, no CSV)")
    args = ap.parse_args()
    if args.dry_run:
        _dry_run()
        return
    scenarios = tuple(args.scenarios.split(","))
    # the acceptance assertion targets the two canonical drift scenarios;
    # extra scenarios ride along measured but un-asserted
    rows = bench(args.scale, args.shards, scenarios, MODES,
                 args.migration_budget, args.replica_budget, args.seed,
                 CSV_PATH,
                 perf_assert=set(("flash_crowd", "diurnal")) <= set(scenarios))
    print("name,depth,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    print(f"OK: {len(scenarios)} scenarios x {len(MODES)} modes -> "
          f"{os.path.normpath(CSV_PATH)}")


if __name__ == "__main__":
    main()
