"""Read-replication benchmark: shipped bytes and window time vs replica
budget.

Every mode runs the identical LUBM workload-composition round (14 base
queries partition the graph, EQ1..EQ10 arrive, the round is accepted and
drained as a chunked ``MigrationSession``); the sweep variable is the
``replica_budget`` — how many bytes of hot-feature read copies the round
may pin onto the shards that read them remotely (``repro.replicate``).
Budget 0 is the primary-only baseline.

Per serving window we record the workload's total shipped bytes and the
average modeled query time; during every drain, bindings are additionally
checked byte-identical across all three executors (numpy / jax /
jax-pallas) at every served epoch — replication must never change results,
only where reads are served. ``results/exp_replication.csv`` holds the
series; the summary asserts that a nonzero budget strictly reduces the
steady-state bytes shipped per window vs budget 0.

  PYTHONPATH=src python benchmarks/bench_replication.py            # LUBM(3)/8
  PYTHONPATH=src python benchmarks/bench_replication.py --dry-run  # LUBM(1)/4
  PYTHONPATH=src python -m benchmarks.run --only replication       # harness row
"""
from __future__ import annotations

import argparse
import os
from typing import List, Optional, Tuple

import numpy as np

from repro.api import KGService
from repro.graph import lubm
from repro.query import exec as qexec

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "3"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))
MIG_BUDGET = int(os.environ.get("REPRO_BENCH_MIG_BUDGET", str(1 << 20)))
BUDGETS = (0, 1 << 18, 1 << 20, 1 << 22)
CSV_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "exp_replication.csv")

_EXECUTORS = ("numpy", "jax", "jax-pallas")


def _canon(b):
    if not b:
        return []
    keys = sorted(b)
    return sorted(map(tuple, np.stack([b[k] for k in keys],
                                      axis=1).tolist()))


def _check_executors_identical(kg, window) -> None:
    """Bindings (and comparable stats) byte-identical across all three
    executors at the facade's current epoch."""
    plans = [kg.plan(q) for q in window]
    ref = qexec.NumpyExecutor().run_batch(plans, kg)
    for name in _EXECUTORS[1:]:
        got = qexec.get_executor(name).run_batch(plans, kg)
        for q, (rb, rs), (gb, gs) in zip(window, ref, got):
            assert _canon(rb) == _canon(gb), (q.name, name, kg.epoch)
            for f in qexec.ExecStats.COMPARABLE:
                assert getattr(rs, f) == getattr(gs, f), \
                    (q.name, name, f, kg.epoch)


def _window_row(kg, window, net, budget: int, w: int) -> dict:
    """Serve one window on the numpy reference and record its federation."""
    plans = [kg.plan(q) for q in window]
    results = qexec.NumpyExecutor().run_batch(plans, kg)
    stats = [st for _, st in results]
    return dict(
        budget=budget, window=w, epoch=kg.epoch,
        bytes_shipped=sum(st.bytes_shipped for st in stats),
        rows_shipped=sum(st.rows_shipped for st in stats),
        avg_query_ms=float(np.mean([st.modeled_time(net)
                                    for st in stats])) * 1e3,
        replicated_features=len(kg.replicas.replicated()),
        replica_bytes=kg.replicas.replica_bytes(kg.state.feature_sizes))


def _serve_round(ds, shards: int, budget: int, mig_budget: int,
                 check_epochs: bool) -> List[dict]:
    """Bootstrap, fill the TM, run the accepted round, drain the session
    chunk by chunk (recording a row — and cross-checking executors — at
    every served epoch), then record the steady-state window."""
    svc = KGService.from_dataset(ds, shards, migration_budget=mig_budget,
                                 replica_budget=budget or None)
    svc.bootstrap(ds.base_workload())
    window = ds.extended_workload()
    net = svc.net or qexec.NetworkModel()
    svc.query_batch(window)                      # fill the TM (baseline obs)
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted, "benchmark needs an accepted round"
    if budget:
        assert report.replicas is not None and report.plan.replica_adds, \
            "nonzero replica budget must promote at least one copy"

    rows = []
    w = 0
    while True:                                  # every epoch incl. pre-drain
        rows.append(_window_row(svc.kg, window, net, budget, w))
        if check_epochs:
            _check_executors_identical(svc.kg, window)
        w += 1
        if svc.step() is None:
            break
    rows.append(_window_row(svc.kg, window, net, budget, w))   # steady state
    return rows


def bench(scale: int, shards: int, budgets, mig_budget: int,
          csv_path: Optional[str],
          check_epochs: bool = True) -> List[Tuple[str, float, str]]:
    ds = lubm.load(scale, 0)
    budgets = sorted(set(budgets) | {0})     # the 0 baseline is the yardstick
    if budgets == [0]:
        raise SystemExit("need at least one nonzero --budgets entry to "
                         "compare against the 0 baseline")
    rows: List[dict] = []
    steady = {}
    for budget in budgets:
        series = _serve_round(ds, shards, budget, mig_budget, check_epochs)
        rows += series
        steady[budget] = series[-1]

    if csv_path:
        cols = ["budget", "window", "epoch", "bytes_shipped", "rows_shipped",
                "avg_query_ms", "replicated_features", "replica_bytes"]
        with open(csv_path, "w") as fh:
            fh.write(",".join(cols) + "\n")
            for r in rows:
                fh.write(",".join(f"{r[c]:.4f}" if isinstance(r[c], float)
                                  else str(r[c]) for c in cols) + "\n")

    base = steady[0]
    out: List[Tuple[str, float, str]] = [
        ("replication/bytes_per_window_budget0", float(base["bytes_shipped"]),
         f"avg_query_us={base['avg_query_ms'] * 1e3:.0f}")]
    for budget in budgets:
        if budget == 0:
            continue
        r = steady[budget]
        out.append((
            f"replication/bytes_per_window_budget{budget}",
            float(r["bytes_shipped"]),
            f"reduction={1 - r['bytes_shipped'] / base['bytes_shipped']:.3f}"
            f"_replicas={r['replicated_features']}"
            f"_avg_query_us={r['avg_query_ms'] * 1e3:.0f}"))
    best = min(steady[b]["bytes_shipped"] for b in budgets if b)
    out.append(("replication/best_bytes_reduction_ratio",
                base["bytes_shipped"] / max(best, 1),
                "replicated_below_baseline="
                + str(best < base["bytes_shipped"])))
    return out


def run() -> List[Tuple[str, float, str]]:
    """benchmarks.run harness entry point (writes the CSV as a side effect).
    Harness convention: values are bytes, except the final ratio row."""
    return bench(SCALE, SHARDS, BUDGETS, MIG_BUDGET, CSV_PATH)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=SCALE)
    ap.add_argument("--shards", type=int, default=SHARDS)
    ap.add_argument("--budgets", default=",".join(map(str, BUDGETS)),
                    help="comma-separated replica budgets (bytes); 0 = "
                         "primary-only baseline")
    ap.add_argument("--migration-budget", type=int, default=MIG_BUDGET)
    ap.add_argument("--dry-run", action="store_true",
                    help="small smoke (LUBM(1)/4, no CSV written)")
    args = ap.parse_args()
    if args.dry_run:
        rows = bench(1, 4, (0, 256_000), 120_000, csv_path=None)
    else:
        budgets = tuple(int(b) for b in args.budgets.split(","))
        rows = bench(args.scale, args.shards, budgets,
                     args.migration_budget, CSV_PATH)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    base = next(v for n, v, _ in rows if n.endswith("budget0"))
    best = min(v for n, v, _ in rows
               if "budget" in n and not n.endswith("budget0"))
    assert best < base, (
        f"a nonzero replica budget must strictly reduce bytes shipped per "
        f"window ({best:.0f} vs baseline {base:.0f})")
    print(f"OK: replicated window ships {best:.0f} B < primary-only "
          f"{base:.0f} B ({1 - best / base:.1%} less)")


if __name__ == "__main__":
    main()
