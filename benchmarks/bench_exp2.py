"""Experiment 2 (paper Figs. 10/11): query-frequency change.

Same query set, but Q1's share of the workload rises to 50%; the adaptive
partition should improve the frequency-weighted average (paper: ~17%).
Runs through the ``repro.api`` service facade.
"""
from __future__ import annotations

import os
from typing import List, Tuple

from repro.graph import lubm
from repro.launch.serve import build_system, experiment2

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "10"))
SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "8"))


def run() -> List[Tuple[str, float, str]]:
    ds, svc = build_system(SCALE, SHARDS)
    out = experiment2(ds, svc, hot_query="Q1", hot_share=0.5,
                      verbose=False)
    imp = (1 - out["t_adaptive"] / max(out["t_initial"], 1e-12)) * 100
    return [
        ("fig10-11/biased_initial", out["t_initial"] * 1e6, "Q1@50%"),
        ("fig10-11/biased_adaptive", out["t_adaptive"] * 1e6,
         f"improvement={imp:.1f}%_paper=17%"),
        ("exp2/migration", out["report"].plan.n_triples,
         f"accepted={out['report'].accepted}"),
    ]
