#!/usr/bin/env bash
# Tier-1 gate + end-to-end smoke of the public repro.api surface.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: examples/quickstart.py (KGService + all strategies) =="
python examples/quickstart.py

echo "CI OK"
