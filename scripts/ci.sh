#!/usr/bin/env bash
# Tier-1 gate + end-to-end smoke of the public repro.api surface.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: examples/quickstart.py (KGService + all strategies) =="
python examples/quickstart.py

echo "== smoke: query_batch on LUBM(1) under both executors =="
python - <<'EOF'
from repro.api import KGService
from repro.graph import lubm

ds = lubm.load(1, seed=0)
window = ds.extended_workload()
rows = {}
for name in ("numpy", "jax"):
    svc = KGService.from_dataset(ds, n_shards=4, executor=name)
    kg = svc.bootstrap(ds.base_workload())
    results = svc.query_batch(window)
    assert len(results) == len(window)
    assert kg.plan_builds == len(window), kg.plan_builds
    rows[name] = [st.rows for _, st in results]
    print(f"[ci] query_batch x{len(window)} executor={name}: "
          f"{sum(rows[name])} total rows")
assert rows["numpy"] == rows["jax"], "executor backends disagree"
EOF

echo "== deprecation: no in-repo caller of the shimmed engine entry points =="
# the shims live in src/repro/query/engine.py and are exercised (with
# pytest.warns) only by tests/test_executors.py
hits=$(grep -rnE \
  "engine\.(execute|run_workload|workload_average_time|profile_query|stats_from_profile)\(|from repro\.query\.engine import .*(execute|run_workload|workload_average_time|profile_query|stats_from_profile)" \
  src examples benchmarks tests --include='*.py' \
  | grep -v "src/repro/query/engine.py" \
  | grep -v "tests/test_executors.py" || true)
if [ -n "$hits" ]; then
  echo "deprecated engine entry points still used in-repo:"
  echo "$hits"
  exit 1
fi

echo "CI OK"
