#!/usr/bin/env bash
# Tier-1 gate + end-to-end smoke of the public repro.api surface.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# CI runs the whole suite at full property-test profiles; the default
# developer `pytest -x -q` skips @slow tests and runs reduced profiles
export REPRO_FULL_TESTS=1

echo "== tier-1: pytest (full profiles, slow tests included) =="
python -m pytest -x -q

echo "== smoke: examples/quickstart.py (KGService + all strategies) =="
python examples/quickstart.py

echo "== smoke: query_batch on LUBM(1) under every executor backend =="
python - <<'EOF'
from repro.api import KGService
from repro.graph import lubm

ds = lubm.load(1, seed=0)
window = ds.extended_workload()
rows = {}
for name in ("numpy", "jax", "jax-pallas"):
    svc = KGService.from_dataset(ds, n_shards=4, executor=name)
    kg = svc.bootstrap(ds.base_workload())
    results = svc.query_batch(window)
    assert len(results) == len(window)
    assert kg.plan_builds == len(window), kg.plan_builds
    rows[name] = [st.rows for _, st in results]
    print(f"[ci] query_batch x{len(window)} executor={name}: "
          f"{sum(rows[name])} total rows")
assert rows["numpy"] == rows["jax"] == rows["jax-pallas"], \
    "executor backends disagree"
EOF

echo "== smoke: fused join pipeline forced (pallas-interpret) == numpy =="
python - <<'EOF'
import numpy as np
from repro.api import JaxExecutor, KGService
from repro.graph import lubm
import repro.query.exec as qexec

def canon(b):
    return sorted(map(tuple, np.stack(
        [b[k] for k in sorted(b)], axis=1).tolist())) if b else []

ds = lubm.load(1, seed=0)
window = ds.extended_workload()
ref_svc = KGService.from_dataset(ds, n_shards=4, executor="numpy")
ref_svc.bootstrap(ds.base_workload())
ref = ref_svc.query_batch(window)

# probe_kernel=True under pallas forces every fused-pipeline stage through
# the Pallas kernels (interpret mode on this CPU container)
svc = KGService.from_dataset(
    ds, n_shards=4, executor=JaxExecutor(pallas=True, probe_kernel=True))
svc.bootstrap(ds.base_workload())
got = svc.query_batch(window)
assert [canon(b) for b, _ in got] == [canon(b) for b, _ in ref], \
    "fused pipeline bindings diverge from the numpy reference"
for (_, st), (_, rst) in zip(got, ref):
    for f in qexec.ExecStats.COMPARABLE:
        assert getattr(st, f) == getattr(rst, f), (f, st, rst)
exp = sum(st.expanded_rows for _, st in got)
print(f"[ci] fused pipeline (forced kernels, interpret) == numpy: "
      f"{len(window)} queries byte-identical, {exp} expanded rows")
EOF

echo "== smoke: throttled migration drain on LUBM(1) =="
python - <<'EOF'
import numpy as np
from repro.api import KGService
from repro.graph import lubm

def canon(b):
    return sorted(map(tuple, np.stack(
        [b[k] for k in sorted(b)], axis=1).tolist())) if b else []

ds = lubm.load(1, seed=0)
svc = KGService.from_dataset(ds, n_shards=4, migration_budget=120_000)
svc.bootstrap(ds.base_workload())
window = ds.extended_workload()
# bindings are layout-invariant: the pre-adapt results are the reference
ref = {q.name: canon(b)
       for q, (b, _) in zip(window, svc.query_batch(window))}
report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
assert report.accepted, "cost-aware guard rejected the smoke round"
sess = svc.session
assert sess is not None and sess.n_chunks >= 3, \
    f"expected a >=3-step drain, got {sess and sess.n_chunks}"
steps = 0
while svc.session is not None:                # query between every chunk
    for q, (b, _) in zip(window, svc.query_batch(window)):
        assert canon(b) == ref[q.name], (q.name, svc.kg.epoch)
    steps += 1
assert steps >= 3, steps
assert np.array_equal(svc.kg.state.feature_to_shard,
                      sess.target.feature_to_shard)
print(f"[ci] throttled migration: {sess.n_chunks} chunks drained over "
      f"{steps} serving windows, {sess.bytes_applied} B, "
      f"final epoch {svc.kg.epoch}")
EOF

echo "== smoke: replicated serving (LUBM(1), replica_budget>0, all executors) =="
python - <<'EOF'
import numpy as np
from repro.api import KGService
from repro.graph import lubm
from repro.query import exec as qexec

def canon(b):
    return sorted(map(tuple, np.stack(
        [b[k] for k in sorted(b)], axis=1).tolist())) if b else []

ds = lubm.load(1, seed=0)
window = ds.extended_workload()

svc0 = KGService.from_dataset(ds, n_shards=4)          # primary-only twin
svc0.bootstrap(ds.base_workload())
svc0.query_batch(window)
rep0 = svc0.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
assert rep0.accepted
bytes0 = sum(st.bytes_shipped for _, st in svc0.query_batch(window))

svc = KGService.from_dataset(ds, n_shards=4, migration_budget=120_000,
                             replica_budget=256_000)
svc.bootstrap(ds.base_workload())
svc.query_batch(window)
report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
assert report.accepted and report.plan.replica_adds, \
    "replica smoke needs an accepted round with promotions"
while svc.session is not None:                         # drain while serving
    assert not svc.should_adapt()                      # mid-drain guard
    svc.query_batch(window)
kg = svc.kg
assert kg.replicas.has_replicas and kg.replicas == report.replicas
plans = [kg.plan(q) for q in window]
ref = qexec.NumpyExecutor().run_batch(plans, kg)
for name in ("jax", "jax-pallas"):
    got = qexec.get_executor(name).run_batch(plans, kg)
    for q, (rb, rs), (gb, gs) in zip(window, ref, got):
        assert canon(rb) == canon(gb), (q.name, name)
        for f in qexec.ExecStats.COMPARABLE:
            assert getattr(rs, f) == getattr(gs, f), (q.name, name, f)
bytes1 = sum(st.bytes_shipped for st in (s for _, s in ref))
assert bytes1 < bytes0, (bytes1, bytes0)
print(f"[ci] replicated serving: {len(kg.replicas.replicated())} features "
      f"replicated, {bytes1} B shipped/window < {bytes0} B primary-only, "
      f"executors byte-identical")
EOF

echo "== smoke: mixed read/write serving (LUBM(1), writes mid-drain, all executors) =="
python - <<'EOF'
import numpy as np
from repro import write as kgwrite
from repro.api import KGService
from repro.graph import lubm
from repro.query import exec as qexec

def canon(b):
    return sorted(map(tuple, np.stack(
        [b[k] for k in sorted(b)], axis=1).tolist())) if b else []

ds = lubm.load(1, seed=0)
window = ds.extended_workload()
svc = KGService.from_dataset(ds, n_shards=4, migration_budget=120_000,
                             replica_budget=256_000)
svc.bootstrap(ds.base_workload())
svc.query_batch(window)
report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
assert report.accepted and svc.session is not None
rng = np.random.default_rng(0)
t = ds.store.triples
windows = 0
while svc.session is not None:       # writes land between every chunk
    rows = t[rng.integers(0, len(t), 48)].copy()
    rows[:, 0] = svc.fresh_ids(len(rows)).astype(np.int32)
    rep = svc.insert(rows)
    assert rep.effective and rep.n_inserted == 48
    svc.delete(rows[:16])
    svc.query_batch(window)
    windows += 1
assert windows >= 2 and svc.write_log.n_inserted > svc.write_log.n_deleted
kg = svc.kg
twin = kgwrite.rebuild_from_scratch(kg)
plans = [kg.plan(q) for q in window]
ref = qexec.NumpyExecutor().run_batch(
    [twin.plan(q) for q in window], twin)
for name in ("numpy", "jax", "jax-pallas"):
    got = qexec.get_executor(name).run_batch(plans, kg)
    for q, (rb, rs), (gb, gs) in zip(window, ref, got):
        assert canon(rb) == canon(gb), (q.name, name)
        for f in qexec.ExecStats.COMPARABLE:
            assert getattr(rs, f) == getattr(gs, f), (q.name, name, f)
print(f"[ci] mixed read/write serving: {svc.write_log.n_inserted} inserts/"
      f"{svc.write_log.n_deleted} deletes over {windows} drain windows, "
      f"epoch {kg.epoch}, all executors == rebuild-from-scratch twin")
EOF

echo "== smoke: streaming admission == query_batch (LUBM(1), all executors) =="
python - <<'EOF'
import numpy as np
from repro.api import KGService, WriteBatch
from repro.graph import lubm
from repro.graph.triples import TripleStore

def canon(b):
    return sorted(map(tuple, np.stack(
        [b[k] for k in sorted(b)], axis=1).tolist())) if b else []

ds = lubm.load(1, seed=0)
window = ds.extended_workload()
# each twin gets its own store copy: the write path mutates in place
def build(executor):
    svc = KGService(TripleStore(ds.store.triples.copy(), ds.store.dictionary),
                    4, executor=executor, migration_budget=120_000,
                    type_predicate=ds.dictionary.lookup("rdf:type"))
    svc.bootstrap(ds.base_workload())
    svc.query_batch(window)
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted and svc.session is not None
    return svc

rng = np.random.default_rng(0)
t = ds.store.triples
batches = []                         # identical writes for every replay
for w in range(3):
    rows = t[rng.integers(0, len(t), 32)].copy()
    rows[:, 0] = (1 << 22) + np.arange(w * 32, (w + 1) * 32, dtype=np.int32)
    batches.append(rows)

per_exec = {}
for name in ("numpy", "jax", "jax-pallas"):
    # synchronous baseline: write, then one query_batch per admission window
    svc = build(name)
    sync = []
    for rows in batches:
        svc.write(WriteBatch(inserts=rows.copy()))
        sync += [canon(b) for b, _ in svc.query_batch(window)]
    # streamed replay of the same admission order, migration in flight
    svc = build(name)
    stream = svc.stream(pipeline=True, max_window=len(window))
    at = 0.0
    for rows in batches:
        stream.submit_write(WriteBatch(inserts=rows.copy()), at=at)
        for q in window:
            stream.submit(q, at=at)
        at += 0.25
    stream.run_until_idle()
    got = [canon(r.bindings) for r in stream.poll()]
    assert got == sync, f"stream != query_batch under executor {name}"
    assert svc.session is None and svc.write_log.n_inserted == 96
    per_exec[name] = got
    s = stream.stats()
    assert s["latency"]["n"] == len(window) * 3
    assert s["latency"]["p50"] <= s["latency"]["p95"] <= s["latency"]["p99"]
    print(f"[ci] streaming executor={name}: {len(got)} queries over "
          f"{stream.n_windows} windows byte-identical to query_batch, "
          f"p95={s['latency']['p95'] * 1e3:.2f} ms")
assert per_exec["numpy"] == per_exec["jax"] == per_exec["jax-pallas"], \
    "executor backends disagree on streamed results"
EOF

echo "== smoke: drift scenario replay (WatDiv flash crowd, adaptive vs frozen) =="
python - <<'EOF'
from repro import scenario as drift
from repro.api import AWAPartitioner, KGService
from repro.graph import watdiv

ds = watdiv.load(1, seed=0)
scn = drift.flash_crowd(ds, warm=2, spike=2, cool=1,
                        queries_per_window=6, seed=3)

def build(executor):
    svc = KGService.from_dataset(ds, n_shards=4,
                                 partitioner=AWAPartitioner(),
                                 executor=executor,
                                 migration_budget=1 << 20,
                                 replica_budget=1 << 20)
    svc.bootstrap(scn.bootstrap_workload(ds))
    return svc

reports = {}
for mode, adapt in (("adaptive", True), ("frozen", False)):
    per_exec = {}
    for name in ("numpy", "jax", "jax-pallas"):
        rep = drift.run_scenario(build(name), scn, ds, adapt=adapt,
                                 mode=f"awapart/{mode}", warmup_phases=1)
        # modeled costs derive from ExecStats, pinned identical across
        # executors — the whole telemetry series must match exactly
        per_exec[name] = [(w.window_ms, w.stall_bytes, w.epoch, w.adapted)
                          for w in rep.windows]
    assert per_exec["numpy"] == per_exec["jax"] == per_exec["jax-pallas"], \
        f"executors disagree on the {mode} replay"
    reports[mode] = rep

spike = next(i for i, w in enumerate(reports["adaptive"].windows) if w.onset)
assert any(w.adapted for w in reports["adaptive"].windows[spike:]), \
    "adaptive arm never reacted to the flash crowd"
assert not any(w.adapted for w in reports["frozen"].windows[2:]), \
    "frozen arm adapted after its warm-up phase"
a, f = reports["adaptive"].summary(), reports["frozen"].summary()
assert a["recovered"] >= f["recovered"]
print(f"[ci] drift smoke: {int(a['windows'])} windows, "
      f"adaptive recovered {int(a['recovered'])}/{int(a['onsets'])} "
      f"(frozen {int(f['recovered'])}), executors identical")
EOF

echo "== smoke: benchmarks/bench_drift.py --dry-run =="
python benchmarks/bench_drift.py --dry-run

echo "== smoke: benchmarks/bench_streaming.py --dry-run =="
python benchmarks/bench_streaming.py --dry-run

echo "== smoke: benchmarks/bench_writes.py --dry-run =="
python benchmarks/bench_writes.py --dry-run

echo "== smoke: benchmarks/bench_replication.py --dry-run =="
python benchmarks/bench_replication.py --dry-run

echo "== smoke: benchmarks/bench_migration.py --dry-run =="
python benchmarks/bench_migration.py --dry-run

echo "== smoke: benchmarks/bench_kernels.py --dry-run (join kernel) =="
python benchmarks/bench_kernels.py --dry-run

echo "== smoke: traced serve run (--trace/--metrics-csv, schema-validated) =="
python -m repro.launch.serve --universities 1 --shards 4 --experiment 1 \
    --migration-budget 120000 --trace /tmp/ci_trace.json \
    --metrics-csv /tmp/ci_metrics.csv
python - <<'EOF'
import json

raw = json.load(open("/tmp/ci_trace.json"))
events = raw["traceEvents"]
assert events and raw.get("displayTimeUnit") == "ms"
for ev in events:                 # Chrome trace-event schema (Perfetto)
    assert ev["ph"] in ("X", "M"), ev
    assert {"name", "ph", "pid", "tid"} <= set(ev), ev
    if ev["ph"] == "X":
        assert ev["dur"] >= 0 and ev["ts"] >= 0, ev
names = [ev["name"] for ev in events if ev["ph"] == "X"]
for needed in ("adapt.round", "migration.chunk", "window", "query",
               "plan", "scan", "join", "federate", "ship"):
    assert needed in names, f"missing {needed} spans in the trace"
n_rounds = names.count("adapt.round")
assert n_rounds >= 1, "no adaptation-round span recorded"
print(f"[ci] trace schema ok: {len(events)} events, {n_rounds} adaptation "
      f"round(s), {names.count('migration.chunk')} migration chunks, "
      f"{names.count('query')} query spans")
EOF
python results/make_table.py /tmp/ci_metrics.csv
python results/make_table.py /tmp/ci_metrics.csv --md > /dev/null

echo "== smoke: kernels.autotune --quick (empirical dispatch profile) =="
python -m repro.kernels.autotune --quick --out /tmp/ci_dispatch_profile.json
python - <<'EOF'
from repro.kernels import dispatch
from repro.kernels.autotune import PROBE_CAP, DispatchProfile

prof = DispatchProfile.load("/tmp/ci_dispatch_profile.json")
try:
    prof.install()
    got = dispatch.envelope(PROBE_CAP, 123)
    assert got == prof.envelopes[PROBE_CAP], (got, prof.envelopes)
finally:
    dispatch.clear_profile()
print(f"[ci] autotune profile round-trip: backend={prof.backend} "
      f"envelopes={prof.envelopes}")
EOF

echo "== docs drift guard: run every <!-- ci:run --> fenced snippet =="
python - <<'EOF'
import pathlib
import re
import subprocess
import sys

MARK = "<!-- ci:run -->"
# the fence must immediately follow its marker (whitespace only between),
# so the guard can never wander off and run some unrelated later fence
FENCE = re.compile(r"\s*```python\n(.*?)```", re.DOTALL)
ran = 0
for doc in sorted(pathlib.Path("docs").glob("*.md")):
    text = doc.read_text()
    for pos in (m.end() for m in re.finditer(re.escape(MARK), text)):
        fence = FENCE.match(text, pos)
        assert fence is not None, \
            f"{doc}: {MARK} not followed by a python fence"
        proc = subprocess.run([sys.executable, "-"],
                              input=fence.group(1), text=True)
        if proc.returncode != 0:
            sys.exit(f"[ci] snippet from {doc} FAILED — the doc has "
                     "drifted from the code")
        ran += 1
        print(f"[ci] docs snippet ok: {doc} (#{ran})")
assert ran >= 3, f"expected >=3 marked snippets across docs/, found {ran}"
EOF

echo "== deprecation: no in-repo caller of the shimmed engine entry points =="
# the shims live in src/repro/query/engine.py and are exercised (with
# pytest.warns) only by tests/test_executors.py
hits=$(grep -rnE \
  "engine\.(execute|run_workload|workload_average_time|profile_query|stats_from_profile)\(|from repro\.query\.engine import .*(execute|run_workload|workload_average_time|profile_query|stats_from_profile)" \
  src examples benchmarks tests --include='*.py' \
  | grep -v "src/repro/query/engine.py" \
  | grep -v "tests/test_executors.py" || true)
if [ -n "$hits" ]; then
  echo "deprecated engine entry points still used in-repo:"
  echo "$hits"
  exit 1
fi

echo "CI OK"
