"""repro.stream — the continuous-admission serving loop.

PR 7: the synchronous ``query_batch`` window becomes a stream. Queries
and write batches are admitted as they arrive (``StreamService.submit`` /
``submit_write`` / ``poll``), served in double-buffered windows through
the existing ``KGService.serve_window`` seam, with migration/replica
chunks and writes drained into the gaps between windows under the same
budgets — and every query's admission→completion latency lands in a
:class:`LatencyRecorder` (p50/p95/p99 per window and per shard), the
tail-latency currency adaptation quality is actually judged in.

Results are byte-identical to a synchronous ``query_batch`` over the same
admission order, at every epoch — the streaming loop changes *when* work
happens, never *what* it computes.

    stream = svc.stream(pipeline=True)           # or StreamService(svc)
    stream.submit(q1); stream.submit_write(batch); stream.submit(q2)
    stream.run_until_idle()                      # or pump() per window
    for r in stream.poll(): ...                  # StreamResult per query
    svc.stats()["latency"]                       # p50/p95/p99 aggregates

``repro.stream.replay`` drives recorded/synthetic arrival processes for
benchmarks (open-loop and Poisson); see ``benchmarks/bench_streaming.py``
and docs/api.md § "Streaming admission".
"""
from repro.stream.replay import (interleave, open_loop_arrivals,
                                 poisson_arrivals, replay)
from repro.stream.service import StreamEvent, StreamResult, StreamService
from repro.stream.telemetry import (LatencyRecorder, QueryLatency,
                                    percentile_summary)

__all__ = ["StreamService", "StreamEvent", "StreamResult",
           "LatencyRecorder", "QueryLatency", "percentile_summary",
           "open_loop_arrivals", "poisson_arrivals", "interleave",
           "replay"]
