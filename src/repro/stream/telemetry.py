"""Tail-latency telemetry for the streaming admission loop.

The paper scores adaptation by *window averages*; a serving system is
judged by per-query tails. :class:`LatencyRecorder` keeps one
:class:`QueryLatency` row per served query — admission, start and
completion on the stream's deterministic virtual clock, plus the window,
PPN shard and epoch it was served at — and aggregates them into
p50/p95/p99 summaries overall, per window and per shard. ``KGService``
surfaces the live stream's recorder through ``stats()``; benchmarks
export the per-window rows to ``results/`` CSVs.

All timestamps are seconds on the stream's modeled clock (the container
has no cluster fabric — see ``NetworkModel``), so every percentile here
is deterministic and comparable across runs, executors and machines.
"""
from __future__ import annotations

import csv
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

PERCENTILES = (50.0, 95.0, 99.0)


@dataclasses.dataclass
class QueryLatency:
    """One served query on the stream's virtual clock."""

    seq: int                 # admission sequence number
    name: str                # query name
    window: int              # serving window the query executed in
    shard: int               # PPN shard the plan ran at
    arrival_s: float         # admission timestamp
    start_s: float           # window start (after interleaved mutations)
    finish_s: float          # completion timestamp
    epoch: int               # facade epoch the query was served at
    cached: bool             # served from the epoch-valid result cache

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        """Time spent queued before its window started."""
        return self.start_s - self.arrival_s


def percentile_summary(values: Sequence[float]) -> Dict[str, float]:
    """``{n, mean, p50, p95, p99, max}`` over a latency sample (seconds)."""
    arr = np.asarray(list(values), dtype=np.float64)
    if arr.size == 0:
        return dict(n=0, mean=0.0, p50=0.0, p95=0.0, p99=0.0, max=0.0)
    p50, p95, p99 = np.percentile(arr, PERCENTILES).tolist()
    return dict(n=int(arr.size), mean=float(arr.mean()), p50=float(p50),
                p95=float(p95), p99=float(p99), max=float(arr.max()))


class LatencyRecorder:
    """Accumulates :class:`QueryLatency` rows and aggregates their tails.

    Every aggregate carries a nested ``"queue"`` percentile block over the
    rows' ``queue_s`` (time spent admitted-but-unstarted) beside the
    end-to-end latency percentiles — queueing pathologies would otherwise
    hide inside the admission→completion p99."""

    def __init__(self) -> None:
        self.records: List[QueryLatency] = []

    def record(self, rec: QueryLatency) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    # ------------------------------------------------------------------ #
    @staticmethod
    def empty_summary() -> Dict[str, object]:
        """The well-formed all-zero :meth:`summary` block — what
        ``KGService.stats()`` reports before any stream has recorded."""
        return LatencyRecorder._summarize([])

    @staticmethod
    def _summarize(recs: Sequence[QueryLatency]) -> Dict[str, object]:
        out: Dict[str, object] = percentile_summary(
            [r.latency_s for r in recs])
        out["queue"] = percentile_summary([r.queue_s for r in recs])
        return out

    def latencies(self) -> np.ndarray:
        return np.array([r.latency_s for r in self.records],
                        dtype=np.float64)

    def summary(self) -> Dict[str, object]:
        """Overall admission→completion percentile summary (seconds),
        with the queue-time percentiles under ``"queue"``."""
        return self._summarize(self.records)

    def _grouped(self, key) -> Dict[int, Dict[str, object]]:
        groups: Dict[int, List[QueryLatency]] = {}
        for r in self.records:
            groups.setdefault(key(r), []).append(r)
        return {k: self._summarize(v) for k, v in sorted(groups.items())}

    def per_window(self) -> Dict[int, Dict[str, float]]:
        """Percentile summary per serving window."""
        return self._grouped(lambda r: r.window)

    def per_shard(self) -> Dict[int, Dict[str, float]]:
        """Percentile summary per PPN shard — which shard serves the worst
        tails is exactly the signal a placement change should move."""
        return self._grouped(lambda r: r.shard)

    # ------------------------------------------------------------------ #
    def window_rows(self, **constants) -> List[Dict[str, object]]:
        """Per-window CSV rows (latencies in milliseconds), with any
        ``constants`` (e.g. ``mode=..., rate_qps=...``) prepended to every
        row — the shape ``benchmarks/bench_streaming.py`` writes to
        ``results/exp_streaming.csv``."""
        rows = []
        for window, s in self.per_window().items():
            row: Dict[str, object] = dict(constants)
            row.update(window=window, n=s["n"],
                       p50_ms=round(s["p50"] * 1e3, 3),
                       p95_ms=round(s["p95"] * 1e3, 3),
                       p99_ms=round(s["p99"] * 1e3, 3),
                       mean_ms=round(s["mean"] * 1e3, 3),
                       max_ms=round(s["max"] * 1e3, 3),
                       # queue-time tails ride after the latency columns
                       # (existing consumers index by the header prefix)
                       queue_p50_ms=round(s["queue"]["p50"] * 1e3, 3),
                       queue_p95_ms=round(s["queue"]["p95"] * 1e3, 3),
                       queue_p99_ms=round(s["queue"]["p99"] * 1e3, 3))
            rows.append(row)
        return rows

    def to_csv(self, path, **constants) -> int:
        """Write :meth:`window_rows` to ``path``; returns rows written."""
        rows = self.window_rows(**constants)
        if not rows:
            return 0
        with open(path, "w", newline="") as fh:
            writer = csv.DictWriter(fh, fieldnames=list(rows[0]))
            writer.writeheader()
            writer.writerows(rows)
        return len(rows)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        s = self.summary()
        return (f"LatencyRecorder(n={s['n']}, p50={s['p50'] * 1e3:.1f}ms, "
                f"p95={s['p95'] * 1e3:.1f}ms, p99={s['p99'] * 1e3:.1f}ms)")
