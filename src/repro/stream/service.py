"""StreamService — continuous admission over a ``KGService`` session.

The paper's Fig.-5 loop consumes closed TM windows; a serving system sees
queries (and writes) *arrive*. This module turns the synchronous
``query_batch`` loop into a streaming one without changing a single
result byte:

* **Admission queue** — ``submit()`` / ``submit_write()`` append events in
  arrival order (timestamps are clamped monotone; admission order IS
  submission order). ``poll()`` drains completed results.
* **Window pipeline** — ``pump()`` forms the next serving window from the
  queries that have arrived, executes it through the existing
  ``KGService.serve_window`` seam (cache check → one ``run_batch`` over
  the misses → TM observation), and — in ``pipeline=True`` mode —
  pre-stages the *next* window's plans while the current one executes
  (double buffering). A window never spans a write event: the write is
  applied first, exactly where synchronous admission would have applied
  it, so bindings stay byte-identical to ``query_batch`` over the same
  admission order at every epoch.
* **Background drainer** — pending write batches and migration/replica
  chunks are interleaved into the gaps between windows under the same
  ``bytes_budget`` discipline as the synchronous loop: one mandatory
  chunk per window (``query_batch`` parity), plus — pipelined only — as
  many extra chunks as fit inside the hidden-time budget, so an idle
  stream finishes its drain without ever stalling a query.

Time is the same *modeled* currency as everywhere else in this repo
(``NetworkModel`` — the container has no cluster fabric): queries execute
for real, the clock is deterministic. A window's service time is

    overhead  = write stalls + chunk stalls + plans built * net.plan_s
    exec_s    = sum of modeled query times over the cache misses
    finish    = t0 + max(0, overhead - hidden) + exec_s

where ``hidden`` is the pipelining credit — the previous window's
execution time plus any idle gap, during which the master planned ahead
and the drainer moved bytes. ``pipeline=False`` sets the credit to zero:
the same code path, the same results, the synchronous loop's head-of-line
stalls — which is what ``benchmarks/bench_streaming.py`` compares tails
against.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import write as kgwrite
from repro.core.migration import TRIPLE_BYTES
from repro.query import exec as qexec
from repro.query.pattern import Query

from repro.stream.telemetry import LatencyRecorder, QueryLatency

__all__ = ["StreamEvent", "StreamResult", "StreamService"]


@dataclasses.dataclass
class StreamEvent:
    """One admitted event: a query or a write batch."""

    seq: int
    arrival_s: float
    query: Optional[Query] = None
    batch: Optional[kgwrite.WriteBatch] = None

    @property
    def is_write(self) -> bool:
        return self.batch is not None


@dataclasses.dataclass
class StreamResult:
    """One completed query, with its latency record."""

    seq: int
    query: Query
    bindings: Dict[int, np.ndarray]
    stats: qexec.ExecStats
    latency: QueryLatency


class StreamService:
    """Continuous-admission serving loop over one :class:`KGService`.

    Parameters
    ----------
    svc : KGService
        The bootstrapped session to serve through (its executor, caches,
        migration session and write path are all reused as-is).
    pipeline : bool
        ``True`` (default): double-buffered windows — plan pre-staging and
        drainer stalls hide behind the previous window's execution time.
        ``False``: the synchronous loop's accounting (every stall is
        head-of-line). Results are byte-identical either way.
    max_window : int
        Cap on queries per serving window.
    hit_cost_s : float
        Modeled service time of an epoch-valid result-cache hit (a column
        memcpy — effectively free next to federated execution).
    net : NetworkModel, optional
        Clock cost model; defaults to the service's (or a default) model.
    """

    def __init__(self, svc, *, pipeline: bool = True, max_window: int = 64,
                 hit_cost_s: float = 0.0,
                 net: Optional[qexec.NetworkModel] = None):
        assert svc.kg is not None, "bootstrap() the service first"
        self.svc = svc
        self.net = net or svc.net or qexec.NetworkModel()
        self.pipeline = bool(pipeline)
        self.max_window = int(max_window)
        self.hit_cost_s = float(hit_cost_s)
        self.recorder = LatencyRecorder()
        svc._stream_recorder = self.recorder     # KGService.stats() surface

        self.now = 0.0                  # virtual clock (seconds)
        self.n_windows = 0
        self.window_log: List[Dict[str, float]] = []
        self._queue: Deque[StreamEvent] = deque()
        self._done: List[StreamResult] = []
        self._seq = 0
        self._last_arrival = 0.0
        self._credit = 0.0              # hidden-time budget for the drainer
        self._prestaged: set = set()    # query names planned ahead (telemetry)
        self.prestage_hits = 0          # prestaged plans that survived to use

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def _admit(self, ev_kwargs: dict, at: Optional[float]) -> int:
        arrival = self.now if at is None else float(at)
        arrival = max(arrival, self._last_arrival)   # clamp monotone
        self._last_arrival = arrival
        ev = StreamEvent(seq=self._seq, arrival_s=arrival, **ev_kwargs)
        self._seq += 1
        self._queue.append(ev)
        return ev.seq

    def submit(self, query: Query, at: Optional[float] = None) -> int:
        """Admit one query (at ``at`` seconds on the virtual clock, default
        now). Returns its admission sequence number."""
        return self._admit(dict(query=query), at)

    def submit_write(self, batch: kgwrite.WriteBatch,
                     at: Optional[float] = None) -> int:
        """Admit one write batch. It applies before any query admitted
        after it — exactly the synchronous admission-order semantics."""
        return self._admit(dict(batch=batch), at)

    def poll(self) -> List[StreamResult]:
        """Completed results since the last poll, in completion order."""
        out, self._done = self._done, []
        return out

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------ #
    # the serving loop
    # ------------------------------------------------------------------ #
    def pump(self) -> int:
        """Serve one window (or apply pending mutations): interleave the
        writes and migration chunks due before the next window, execute
        the window through ``serve_window``, pre-stage the next one.
        Returns the number of queries served (0 is still progress — a
        mutation-only pump or a clock advance)."""
        svc, kg, net = self.svc, self.svc.kg, self.net
        if not self._queue:
            return 0
        t0 = max(self.now, self._queue[0].arrival_s)
        # sync the span tracer's virtual-clock cursor to the admission
        # clock: spans emitted by the writes/chunks/window below lay out
        # from this window's start (monotone — never rewinds over spans)
        svc._tracer.advance_to(t0)
        idle = t0 - self.now
        avail = (self._credit + idle) if self.pipeline else 0.0
        overhead = 0.0
        wrote = 0

        # 1. writes admitted ahead of the window's queries land first — the
        #    same point in the admission order the synchronous loop applies
        #    them, so every later query sees the identical graph
        while self._queue and self._queue[0].is_write \
                and self._queue[0].arrival_s <= t0:
            ev = self._queue.popleft()
            report = svc.write(ev.batch)
            overhead += ((report.n_inserted + report.n_deleted)
                         * TRIPLE_BYTES + report.fanout_bytes) \
                / net.bandwidth_Bps
            wrote += 1

        # 2. the drainer: one mandatory bounded chunk (query_batch parity),
        #    then — pipelined only — as many extra chunks as fit entirely
        #    inside the hidden-time budget, so idle gaps finish the drain
        chunk_bytes = 0
        chunk = svc.step()
        if chunk is not None:
            overhead += chunk.bytes / net.bandwidth_Bps
            chunk_bytes += chunk.bytes
        if self.pipeline:
            while svc.session is not None:
                stall = svc.session.peek().bytes / net.bandwidth_Bps
                if overhead + stall > avail:
                    break
                chunk_bytes += svc.step().bytes
                overhead += stall

        # 3. window formation: ready queries in admission order; a window
        #    never spans a write event or an unarrived query
        window: List[StreamEvent] = []
        while self._queue and len(window) < self.max_window:
            ev = self._queue[0]
            if ev.is_write or ev.arrival_s > t0:
                break
            window.append(self._queue.popleft())

        if not window:       # mutation-only pump: charge the unhidden stall
            self.now = t0 + max(0.0, overhead - avail)
            svc._tracer.advance_to(self.now)
            if self.pipeline:
                self._credit = max(0.0, avail - overhead)
            return 0

        # 4. execute through the existing seam; plans built during the
        #    window (pre-stage misses, epoch-invalidated pre-stages) are
        #    master-side overhead at plan_s each
        builds0 = kg.plan_builds
        queries = [ev.query for ev in window]
        results, miss = svc.serve_window(queries)
        built = kg.plan_builds - builds0
        overhead += built * net.plan_s
        staged = sum(1 for ev in window if ev.query.name in self._prestaged)
        self.prestage_hits += max(0, staged - built)
        miss_set = set(miss)
        exec_s = sum(
            (results[i][1].modeled_time(net) if i in miss_set
             else self.hit_cost_s) for i in range(len(results)))

        hidden = min(overhead, avail)
        start = t0 + (overhead - hidden)
        finish = start + exec_s

        # 5. record + complete
        miss_seqs = {window[i].seq for i in miss}
        m = svc.metrics
        for ev, (bindings, stats) in zip(window, results):
            rec = QueryLatency(
                seq=ev.seq, name=ev.query.name, window=self.n_windows,
                shard=int(kg.plan(ev.query).ppn), arrival_s=ev.arrival_s,
                start_s=start, finish_s=finish, epoch=kg.epoch,
                cached=ev.seq not in miss_seqs)
            self.recorder.record(rec)
            m.histogram("query.queue_s").observe(rec.queue_s)
            m.histogram("query.latency_s").observe(rec.latency_s)
            self._done.append(StreamResult(ev.seq, ev.query, bindings,
                                           stats, rec))
        self.window_log.append(dict(
            window=self.n_windows, t0=t0, start=start, finish=finish,
            n=len(window), n_miss=len(miss), exec_s=exec_s,
            overhead_s=overhead, hidden_s=hidden, writes=wrote,
            chunk_bytes=chunk_bytes, epoch=kg.epoch))
        # the queue-vs-execute split: how much window time was spent
        # waiting (stalls that failed to hide) vs. executing
        m.counter("stream.windows").inc()
        m.counter("stream.queries").inc(len(window))
        m.counter("stream.exec_s_total").inc(exec_s)
        m.counter("stream.queue_s_total").inc(
            sum(start - ev.arrival_s for ev in window))
        m.counter("stream.overhead_s_total").inc(overhead)
        m.counter("stream.hidden_s_total").inc(hidden)
        self.n_windows += 1
        self.now = finish
        svc._tracer.advance_to(finish)
        # double buffering: the next window's stalls can hide behind this
        # window's execution — and behind nothing else
        self._credit = exec_s if self.pipeline else 0.0

        # 6. pre-stage window N+1: build plans for the queries already
        #    admitted behind this window, stopping at the first write event
        #    (it would invalidate them anyway). Runs on the master while
        #    the shards execute — its cost is the credit being consumed.
        self._prestaged = set()
        if self.pipeline:
            for ev in list(self._queue)[:self.max_window]:
                if ev.is_write:
                    break
                if ev.query is not None:
                    kg.plan(ev.query)
                    self._prestaged.add(ev.query.name)
        return len(window)

    def run_until_idle(self) -> LatencyRecorder:
        """Pump until the admission queue is empty. Returns the recorder."""
        while self._queue:
            self.pump()
        return self.recorder

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, object]:
        """The stream's own aggregates, merged over ``KGService.stats()``."""
        out = self.svc.stats()
        out.update(n_windows=self.n_windows, clock_s=self.now,
                   pending=self.pending, pipeline=self.pipeline,
                   latency=self.recorder.summary(),
                   latency_per_shard=self.recorder.per_shard())
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"StreamService(pipeline={self.pipeline}, "
                f"windows={self.n_windows}, pending={self.pending}, "
                f"clock={self.now:.3f}s)")
