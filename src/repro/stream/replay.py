"""Clocked replay drivers — feed a :class:`StreamService` a recorded or
synthetic arrival process.

Benchmarks don't have live clients, so they *replay*: an event list of
``(arrival_s, Query | WriteBatch)`` pairs is admitted in order onto the
stream's virtual clock and pumped to completion. Arrival processes:

* :func:`open_loop_arrivals` — the open-loop (uniform-spacing) process
  ``bench_streaming.py`` sweeps: clients fire at ``rate_qps`` regardless
  of completions, so queueing delay shows up in the tail the moment the
  system saturates (a closed loop would hide it).
* :func:`poisson_arrivals` — exponential gaps at the same mean rate, for
  burstier tails (seeded — everything stays deterministic).

``replay()`` is the loop: submit every event at its timestamp, run until
idle, return the stream's :class:`LatencyRecorder`.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro import write as kgwrite
from repro.query.pattern import Query

from repro.stream.service import StreamService
from repro.stream.telemetry import LatencyRecorder

__all__ = ["open_loop_arrivals", "poisson_arrivals", "interleave",
           "replay"]


def open_loop_arrivals(n: int, rate_qps: float,
                       start: float = 0.0) -> np.ndarray:
    """``n`` uniform open-loop arrival timestamps at ``rate_qps``."""
    assert rate_qps > 0, rate_qps
    return start + np.arange(n, dtype=np.float64) / float(rate_qps)


def poisson_arrivals(n: int, rate_qps: float, rng,
                     start: float = 0.0) -> np.ndarray:
    """``n`` Poisson-process arrivals (exponential gaps, mean rate
    ``rate_qps``), from a seeded ``numpy`` Generator."""
    assert rate_qps > 0, rate_qps
    gaps = rng.exponential(1.0 / float(rate_qps), size=n)
    return start + np.cumsum(gaps)


def interleave(queries: Sequence[Query], arrivals: np.ndarray,
               writes: Sequence[Tuple[int, kgwrite.WriteBatch]] = (),
               ) -> List[Tuple[float, object]]:
    """Build a replay event list: ``queries[i]`` at ``arrivals[i]``, with
    each write batch admitted *before* the query at its position (a
    ``(position, batch)`` pair; position == len(queries) appends at the
    end). Returns ``(arrival_s, payload)`` pairs in admission order."""
    assert len(queries) == len(arrivals)
    by_pos: dict = {}
    for pos, batch in writes:
        by_pos.setdefault(int(pos), []).append(batch)
    events: List[Tuple[float, object]] = []
    for i, (q, t) in enumerate(zip(queries, arrivals.tolist())):
        for batch in by_pos.get(i, ()):
            events.append((t, batch))
        events.append((t, q))
    tail = float(arrivals[-1]) if len(arrivals) else 0.0
    for batch in by_pos.get(len(queries), ()):
        events.append((tail, batch))
    return events


def replay(stream: StreamService,
           events: Iterable[Tuple[float, object]]) -> LatencyRecorder:
    """Admit every ``(arrival_s, Query | WriteBatch)`` event in order and
    pump the stream until idle. Returns the stream's recorder."""
    for at, payload in events:
        if isinstance(payload, kgwrite.WriteBatch):
            stream.submit_write(payload, at=at)
        else:
            stream.submit(payload, at=at)
    return stream.run_until_idle()
