"""Drift scenarios — seeded workload *dynamics* over a serving session.

A static benchmark asks "how fast is this layout for that workload"; a
drift scenario asks the production question: *when the workload changes
under you, how deep do you degrade and how fast do you come back*. This
module describes the change itself:

* a :class:`Phase` is a stretch of serving windows drawn from one weighted
  query mix (optionally with a write stream riding along);
* a :class:`DriftScenario` is a named phase sequence plus a seed;
* :meth:`DriftScenario.schedule` expands it into a fully *pre-computed*,
  deterministic list of :class:`Window` s — the admission stream. Same
  seed, same dataset ⇒ byte-identical schedule, so the synchronous loop,
  the streaming loop, and every (strategy × adaptive/frozen) arm replay
  exactly the same drift.

Factories cover the canonical dynamics from the TAPER/xDGP evaluations:
:func:`diurnal` (focus oscillates between query families),
:func:`flash_crowd` (sudden concentration on one hot feature),
:func:`hot_set_churn` (the hot query set slowly rotates), and
:func:`mixed_read_write` (a write burst mid-serving). They group queries
by the dataset's ``topics`` attribute (``graph.watdiv``) when present and
fall back to ``Query.shape`` families otherwise, so they run on any
``Dataset`` duck-typed source (``graph.lubm`` included).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.query.pattern import Query

__all__ = ["Phase", "Window", "DriftScenario", "diurnal", "flash_crowd",
           "hot_set_churn", "mixed_read_write", "hot_feature_writer"]

# a writer maps (rng, n_rows, alloc) -> (n, 3) int32 insert rows, where
# ``alloc(k)`` mints k fresh entity ids (disjoint from the live graph)
Writer = Callable[[np.random.Generator, int, Callable[[int], np.ndarray]],
                  np.ndarray]


@dataclasses.dataclass(frozen=True)
class Phase:
    """One homogeneous stretch of the scenario: ``windows`` serving windows
    sampled from the weighted query ``mix``, each optionally preceded by
    ``writes_per_window`` freshly generated insert rows."""

    name: str
    windows: int
    mix: Tuple[Tuple[str, float], ...]     # (query name, sampling weight)
    writes_per_window: int = 0


@dataclasses.dataclass
class Window:
    """One admission window of the expanded schedule. ``onset`` marks the
    first window of a new phase — the drift instants the reactivity
    metrics anchor on."""

    index: int
    phase: str
    onset: bool
    queries: List[Query]
    write_rows: Optional[np.ndarray] = None    # (n, 3) int32 inserts
    # canonical identity of the phase's mix: recurring phases (day0/day1)
    # share it, so recovery baselines can compare like with like
    mix_key: str = ""


@dataclasses.dataclass
class DriftScenario:
    name: str
    phases: Tuple[Phase, ...]
    queries_per_window: int = 12
    seed: int = 0
    writer: Optional[Writer] = None

    def bootstrap_workload(self, ds) -> List[Query]:
        """The pre-drift workload: the distinct queries of phase 0's mix —
        what a service would reasonably have been partitioned for before
        the scenario's dynamics hit it."""
        names = [n for n, w in self.phases[0].mix if w > 0]
        return ds.workload(sorted(set(names)))

    def schedule(self, ds) -> List[Window]:
        """Expand into the deterministic admission stream. Every sampling
        decision (query draws, write rows, fresh entity ids) comes from one
        generator seeded with ``self.seed``, computed up front against the
        *initial* store — so identical replays see identical events."""
        rng = np.random.default_rng(self.seed)
        next_free = int(ds.store.triples.max()) + 1

        def alloc(k: int) -> np.ndarray:
            nonlocal next_free
            ids = np.arange(next_free, next_free + k, dtype=np.int64)
            next_free += k
            assert next_free < np.iinfo(np.int32).max
            return ids

        windows: List[Window] = []
        for pi, phase in enumerate(self.phases):
            names = [n for n, _ in phase.mix]
            w = np.array([max(float(x), 0.0) for _, x in phase.mix])
            assert w.sum() > 0, f"phase {phase.name}: empty mix"
            p = w / w.sum()
            for wi in range(phase.windows):
                picked = rng.choice(len(names), size=self.queries_per_window,
                                    p=p)
                queries = [ds.queries[names[int(i)]] for i in picked]
                rows = None
                if phase.writes_per_window > 0:
                    assert self.writer is not None, \
                        f"phase {phase.name} writes but scenario has no writer"
                    rows = np.asarray(
                        self.writer(rng, phase.writes_per_window, alloc),
                        dtype=np.int32).reshape(-1, 3)
                windows.append(Window(
                    index=len(windows), phase=phase.name,
                    onset=(pi > 0 and wi == 0), queries=queries,
                    write_rows=rows,
                    mix_key=",".join(f"{n}:{x:g}" for n, x in phase.mix)))
        return windows


# --------------------------------------------------------------------------- #
# query-family grouping (dataset-agnostic)
# --------------------------------------------------------------------------- #

def _families(ds) -> Dict[str, List[str]]:
    """Focus families to drift between: the dataset's ``topics`` when it
    has them (``graph.watdiv``), else groups by ``Query.shape``."""
    topics = getattr(ds, "topics", None)
    if topics:
        return {k: list(v) for k, v in topics.items()}
    groups: Dict[str, List[str]] = {}
    for name, q in sorted(ds.queries.items()):
        groups.setdefault(q.shape or "other", []).append(name)
    return groups


def _mix(names: Sequence[str], weight: float = 1.0,
         ) -> Tuple[Tuple[str, float], ...]:
    return tuple((n, weight) for n in names)


def _two_families(ds) -> Tuple[List[str], List[str]]:
    fams = _families(ds)
    if "retail" in fams and "social" in fams:
        return fams["retail"], fams["social"]
    ordered = sorted(fams.items(), key=lambda kv: (-len(kv[1]), kv[0]))
    assert len(ordered) >= 2, "dataset has a single query family"
    return ordered[0][1], ordered[1][1]


# --------------------------------------------------------------------------- #
# scenario factories
# --------------------------------------------------------------------------- #

def diurnal(ds, *, cycles: int = 2, day_windows: int = 4,
            night_windows: int = 4, queries_per_window: int = 12,
            seed: int = 0,
            families: Optional[Tuple[str, str]] = None) -> DriftScenario:
    """Diurnal focus shift: traffic oscillates between two query families
    (WatDiv: the ``retail`` mix by day, the ``review`` mix by night). The
    service is bootstrapped for day; every nightfall is a drift onset."""
    fams = _families(ds)
    if families is not None:
        day, night = list(fams[families[0]]), list(fams[families[1]])
    elif "retail" in fams and "review" in fams:
        day, night = list(fams["retail"]), list(fams["review"])
    else:
        day, night = _two_families(ds)
    phases: List[Phase] = []
    for c in range(cycles):
        phases.append(Phase(f"day{c}", day_windows, _mix(day)))
        phases.append(Phase(f"night{c}", night_windows, _mix(night)))
    return DriftScenario(name="diurnal", phases=tuple(phases),
                         queries_per_window=queries_per_window, seed=seed)


def flash_crowd(ds, *, warm: int = 4, spike: int = 5, cool: int = 3,
                queries_per_window: int = 12, seed: int = 0,
                spike_on: Optional[Sequence[str]] = None) -> DriftScenario:
    """Flash crowd: a warm steady-state mix, then a sudden phase where ~90%
    of admitted queries concentrate on one previously-cold feature (WatDiv
    default: the ``social`` stars around ``likes product0``), then back."""
    day, night = _two_families(ds)
    crowd = list(spike_on) if spike_on else \
        [n for n in night if ds.queries[n].shape == "star"] or night[:1]
    warm_mix = _mix(day)
    spike_mix = tuple([(n, 9.0 * len(day) / len(crowd)) for n in crowd]
                      + list(_mix(day)))
    return DriftScenario(
        name="flash_crowd",
        phases=(Phase("warm", warm, warm_mix),
                Phase("spike", spike, spike_mix),
                Phase("cool", cool, warm_mix)),
        queries_per_window=queries_per_window, seed=seed)


def hot_set_churn(ds, *, steps: int = 4, windows_per_step: int = 3,
                  hot_size: int = 4, queries_per_window: int = 12,
                  seed: int = 0) -> DriftScenario:
    """Slow hot-set churn: the hot query subset rotates a little every few
    windows (weight 8:1 hot:cold) — drift as erosion, not as a cliff."""
    names = sorted(ds.queries)
    assert hot_size < len(names)
    phases = []
    for s in range(steps):
        start = (s * max(hot_size // 2, 1)) % len(names)
        hot = [names[(start + i) % len(names)] for i in range(hot_size)]
        mix = tuple((n, 8.0 if n in hot else 1.0) for n in names)
        phases.append(Phase(f"churn{s}", windows_per_step, mix))
    return DriftScenario(name="hot_set_churn", phases=tuple(phases),
                         queries_per_window=queries_per_window, seed=seed)


def mixed_read_write(ds, *, read_windows: int = 3, write_windows: int = 4,
                     cool_windows: int = 3, writes_per_window: int = 96,
                     queries_per_window: int = 12, seed: int = 0,
                     writer: Optional[Writer] = None) -> DriftScenario:
    """Mixed read/write phases: steady reads, then a write burst growing a
    hot feature under the same reads, then reads again. Writes ride the
    admission stream as ``repro.write`` batches (routed, fanned out, heat
    noted) — the data-drift half of the reactivity story."""
    day, night = _two_families(ds)
    mix = _mix(day + night)
    return DriftScenario(
        name="mixed_read_write",
        phases=(Phase("read0", read_windows, mix),
                Phase("burst", write_windows, mix,
                      writes_per_window=writes_per_window),
                Phase("read1", cool_windows, mix)),
        queries_per_window=queries_per_window, seed=seed,
        writer=writer or hot_feature_writer(ds))


def hot_feature_writer(ds) -> Writer:
    """Insert-row generator growing one workload-tracked hot feature:
    fresh users liking ``product0`` (WatDiv), fresh students taking
    ``GraduateCourse0`` (LUBM), else fresh subjects over sampled existing
    rows (any store)."""
    d = ds.dictionary
    named = getattr(ds, "named", None)
    if named is not None and hasattr(named, "product0"):
        t, cls = d.lookup("rdf:type"), d.lookup("wsdbm:User")
        likes = d.lookup("wsdbm:likes")
        nat, c0 = d.lookup("sorg:nationality"), named.country0
        hot = named.product0

        def rows(rng, n, alloc):
            s = alloc(n)
            return np.concatenate([
                np.stack([s, np.full(n, t), np.full(n, cls)], axis=1),
                np.stack([s, np.full(n, likes), np.full(n, hot)], axis=1),
                np.stack([s, np.full(n, nat), np.full(n, c0)], axis=1),
            ]).astype(np.int32)
        return rows
    if named is not None and hasattr(named, "grad_course0"):
        t, cls = d.lookup("rdf:type"), d.lookup("ub:GraduateStudent")
        take = d.lookup("ub:takesCourse")
        hot = named.grad_course0

        def rows(rng, n, alloc):
            s = alloc(n)
            return np.concatenate([
                np.stack([s, np.full(n, t), np.full(n, cls)], axis=1),
                np.stack([s, np.full(n, take), np.full(n, hot)], axis=1),
            ]).astype(np.int32)
        return rows

    base = ds.store.triples

    def rows(rng, n, alloc):
        picked = base[rng.integers(0, len(base), n)].astype(np.int64)
        picked[:, 0] = alloc(n)
        return picked.astype(np.int32)
    return rows
