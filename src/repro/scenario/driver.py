"""Scenario driver — replay a drift schedule and measure *reactivity*.

`repro.scenario.schedule` describes how a workload changes;
:func:`run_scenario` replays that change over a live :class:`KGService`
session and answers the production questions AWAPart's static benchmarks
cannot: when the mix shifts, **how deep** does the modeled window latency
degrade (:attr:`Recovery.depth`), **how many windows** until it is back
within ``margin`` of the pre-drift level (:attr:`Recovery.time_to_recover`),
and **how many migration+replica bytes** that recovery cost
(:attr:`Recovery.bytes_spent`). The same schedule replays over adaptive
(``maybe_adapt`` per window) and frozen (never adapt) services, so the
telemetry isolates what the Fig.-5 loop buys.

Accounting mirrors ``benchmarks/bench_writes.py``: per-window serving cost
is the mean modeled query time, and migration traffic applied during the
window stalls it at the network model's bandwidth, amortized over the
window's queries — degradation *and* the price of reacting to it land in
the same ``window_ms`` series the recovery metrics read.

:func:`stream_schedule` routes the identical schedule through
``svc.stream()`` (the ``repro.stream`` continuous-admission loop), which
the parity tests pin byte-identical to the synchronous replay.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.query import exec as qexec
from repro import write as kgwrite
from repro.scenario.schedule import DriftScenario, Window

__all__ = ["WindowRecord", "Recovery", "ReactivityReport", "reactivity",
           "run_scenario", "stream_schedule"]


@dataclasses.dataclass
class WindowRecord:
    """Telemetry for one served window of a scenario replay."""

    index: int
    phase: str
    onset: bool            # first window of a new phase (drift instant)
    n_queries: int
    write_rows: int        # insert rows applied ahead of the window
    avg_ms: float          # mean modeled query time, serving only
    stall_bytes: int       # migration+replica traffic applied this window
    window_ms: float       # avg_ms + amortized migration stall
    bytes_shipped: int     # intermediate-result shipping during serving
    epoch: int             # layout epoch after the window
    adapted: bool          # an adaptation round was accepted this window
    mix_key: str = ""      # mix identity (recurring phases share it)


@dataclasses.dataclass
class Recovery:
    """Reactivity metrics for one drift onset."""

    phase: str             # the phase whose arrival caused the drift
    onset: int             # window index of the onset
    baseline_ms: float     # mean window_ms of the pre-onset windows
    peak_ms: float         # worst window_ms from onset to recovery (or span end)
    depth: float           # peak_ms / baseline_ms — degradation depth
    recovered: bool        # came back within (1+margin)*baseline in-span
    time_to_recover: Optional[int]   # windows from onset until recovered
    bytes_spent: int       # migration+replica bytes from onset through recovery


@dataclasses.dataclass
class ReactivityReport:
    scenario: str
    mode: str                        # e.g. "awapart/adaptive", "hash/frozen"
    windows: List[WindowRecord]
    recoveries: List[Recovery]

    def summary(self) -> Dict[str, float]:
        rec = self.recoveries
        return {
            "windows": len(self.windows),
            "onsets": len(rec),
            "recovered": sum(r.recovered for r in rec),
            "worst_depth": max((r.depth for r in rec), default=1.0),
            "max_ttr": max((r.time_to_recover for r in rec
                            if r.time_to_recover is not None), default=0),
            "bytes_spent": sum(r.bytes_spent for r in rec),
        }


def reactivity(windows: Sequence[WindowRecord], *, margin: float = 0.2,
               baseline_windows: int = 3) -> List[Recovery]:
    """Reduce a window series to per-onset recovery metrics.

    For each onset, the baseline is the pre-drift level the arriving mix is
    expected to return to: the tail (last ``baseline_windows`` windows) of
    the most recent *earlier* phase serving the same mix when one exists —
    a recurring phase is judged against its own past, never against a mix
    with a different compute floor — else the tail of the windows
    immediately before the onset. The recovery point is the first window in
    the onset's span (up to the next onset) whose ``window_ms`` is back
    within ``(1 + margin) * baseline``. ``depth`` is the worst degradation
    seen before that point. ``bytes_spent`` sums the migration stalls from
    the onset through the recovery window — the traffic the layout paid to
    get back (the whole span when it never does)."""
    onsets = [w.index for w in windows if w.onset]
    spans = list(zip([0] + onsets, onsets + [len(windows)]))
    out: List[Recovery] = []
    for start, end in spans:
        if start not in onsets:
            continue
        key = windows[start].mix_key
        same = [(s, e) for s, e in spans if e <= start
                and key and windows[s].mix_key == key]
        if same:
            s, e = same[-1]
            pre = windows[max(s, e - baseline_windows):e]
        else:
            pre = windows[max(0, start - baseline_windows):start]
        assert pre, f"onset at window {start} has no pre-drift baseline"
        baseline = float(np.mean([w.window_ms for w in pre]))
        span = windows[start:end]
        limit = (1.0 + margin) * baseline
        at = next((i for i, w in enumerate(span) if w.window_ms <= limit),
                  None)
        upto = span if at is None else span[:at + 1]
        peak = max(w.window_ms for w in upto)
        out.append(Recovery(
            phase=span[0].phase, onset=start, baseline_ms=baseline,
            peak_ms=peak, depth=peak / baseline if baseline > 0 else 1.0,
            recovered=at is not None, time_to_recover=at,
            bytes_spent=sum(w.stall_bytes for w in upto)))
    return out


def _session_bytes(svc) -> Tuple[object, int]:
    sess = svc.session
    return sess, (sess.bytes_applied if sess is not None else 0)


def run_scenario(svc, scenario: DriftScenario, ds, *, adapt: bool,
                 mode: str = "", margin: float = 0.2,
                 baseline_windows: int = 3,
                 warmup_phases: int = 0) -> ReactivityReport:
    """Replay ``scenario`` over a bootstrapped service, synchronously:
    writes, then ``query_batch`` (which applies one migration chunk), then
    — in adaptive mode — ``maybe_adapt`` on the window's queries. Frozen
    mode serves the identical schedule without ever adapting; bindings are
    layout-invariant, so the two arms differ only in cost telemetry.

    ``warmup_phases`` lets a frozen arm adapt during the first N phases
    before freezing: both arms then face the first drift onset from the
    same well-tuned pre-drift layout, so the recovery metrics isolate
    *reactivity* rather than initial placement quality. (It is a no-op
    for non-adaptive strategies — ``maybe_adapt`` never fires without an
    adaptive controller.)"""
    assert svc.kg is not None, "bootstrap(scenario.bootstrap_workload(ds)) first"
    windows = scenario.schedule(ds)
    phase_index = {p.name: i for i, p in enumerate(scenario.phases)}
    net = svc.net or qexec.NetworkModel()
    records: List[WindowRecord] = []
    for w in windows:
        stall = 0
        if w.write_rows is not None:
            svc.write(kgwrite.WriteBatch(inserts=w.write_rows.copy()))
        # migration chunk applied by query_batch ahead of serving
        prev, b0 = _session_bytes(svc)
        results = svc.query_batch(w.queries)
        if prev is not None:
            stall += prev.bytes_applied - b0
        adapted = False
        if adapt or phase_index[w.phase] < warmup_phases:
            # an adaptation round first finishes any in-flight drain, then
            # (budget=None) commits the accepted plan atomically — both are
            # traffic this window pays for
            prev, b0 = _session_bytes(svc)
            report = svc.maybe_adapt(w.queries)
            if prev is not None:
                stall += prev.bytes_applied - b0
            if report is not None and report.accepted:
                adapted = True
                cur = svc.session
                stall += (report.plan.bytes if cur is None
                          else cur.bytes_applied)
        times = [stats.modeled_time(net) for _, stats in results]
        avg_ms = float(np.mean(times)) * 1e3
        stall_ms = stall / net.bandwidth_Bps / len(results) * 1e3
        records.append(WindowRecord(
            index=w.index, phase=w.phase, onset=w.onset,
            n_queries=len(w.queries),
            write_rows=0 if w.write_rows is None else len(w.write_rows),
            avg_ms=avg_ms, stall_bytes=int(stall),
            window_ms=avg_ms + stall_ms,
            bytes_shipped=int(sum(s.bytes_shipped for _, s in results)),
            epoch=svc.kg.epoch, adapted=adapted, mix_key=w.mix_key))
    return ReactivityReport(
        scenario=scenario.name, mode=mode, windows=records,
        recoveries=reactivity(records, margin=margin,
                              baseline_windows=baseline_windows))


def stream_schedule(svc, windows: Sequence[Window], *, gap_s: float = 1.0,
                    **stream_kwargs):
    """Admit a pre-computed schedule through the continuous-admission loop
    (``svc.stream()``): window *k*'s writes then queries arrive at
    ``k * gap_s``, preserving the synchronous replay's admission order.
    Returns ``(stream, results)`` with results in admission order — pinned
    byte-identical to the synchronous ``query_batch`` replay by
    ``tests/test_scenario.py``."""
    stream = svc.stream(**stream_kwargs)
    for k, w in enumerate(windows):
        at = k * gap_s
        if w.write_rows is not None:
            stream.submit_write(kgwrite.WriteBatch(inserts=w.write_rows.copy()),
                                at=at)
        for q in w.queries:
            stream.submit(q, at=at)
    stream.run_until_idle()
    return stream, stream.poll()
