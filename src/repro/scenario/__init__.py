"""repro.scenario — workload-drift scenarios and reactivity telemetry.

Describe workload *dynamics* (`DriftScenario`: diurnal shifts, flash
crowds, hot-set churn, mixed read/write phases) as deterministic seeded
schedules, replay them over a live ``KGService`` — synchronously or
through the ``repro.stream`` admission loop — and measure how the layout
reacts: degradation depth, time-to-recover, and migration+replica bytes
per recovery, adaptive vs frozen. See ``benchmarks/bench_drift.py`` for
the experiment harness and ``docs/api.md`` for a tour.
"""
from repro.scenario.schedule import (Phase, Window, DriftScenario, diurnal,
                                     flash_crowd, hot_set_churn,
                                     mixed_read_write, hot_feature_writer)
from repro.scenario.driver import (WindowRecord, Recovery, ReactivityReport,
                                   reactivity, run_scenario, stream_schedule)

__all__ = [
    "Phase", "Window", "DriftScenario", "diurnal", "flash_crowd",
    "hot_set_churn", "mixed_read_write", "hot_feature_writer",
    "WindowRecord", "Recovery", "ReactivityReport", "reactivity",
    "run_scenario", "stream_schedule",
]
