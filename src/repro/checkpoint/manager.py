"""Sharded checkpointing with async save and reshard-on-restore.

Design (tensorstore-free, multi-host-shaped):
  * every pytree leaf is saved as ``<flat-key>.npy`` under
    ``<dir>/step_<N>.tmp/`` then the directory is atomically renamed —
    a crash mid-save never corrupts the latest checkpoint;
  * on multi-host pods each process would write only its addressable shards
    (key suffixed by shard index); on this single-process container the
    fully-replicated gather path is exercised, the layout is identical;
  * restore takes an optional sharding tree and ``device_put``s each leaf to
    it — restoring onto a *different* mesh (elastic re-size) is therefore the
    same code path as normal restore;
  * ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
    writes to disk on a background thread, overlapping I/O with the next
    training steps — the standard large-run pattern.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> Tuple[Dict[str, Any], Any]:
    # jax.tree.flatten_with_path only exists on newer jax; tree_util spelling
    # works across the versions we support
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str | Path, step: int, tree, *,
         keep_last: Optional[int] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        if leaf is None:
            manifest[key] = None
            continue
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{key}.npy", arr)
        manifest[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    if keep_last:
        steps = sorted(available_steps(ckpt_dir))
        for s in steps[:-keep_last]:
            shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
    return final


def available_steps(ckpt_dir: str | Path) -> List[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                  if not p.name.endswith(".tmp"))


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, step: int, target_tree, *,
            shardings=None):
    """Restore into the structure of ``target_tree``. With ``shardings`` the
    leaves are placed onto the (possibly different) mesh — elastic restore."""
    path = Path(ckpt_dir) / f"step_{step}"
    flat_t, treedef = _flatten(target_tree)
    flat_s = None
    if shardings is not None:
        flat_s, _ = _flatten(shardings)
    out = {}
    for key, target in flat_t.items():
        if target is None:
            out[key] = None
            continue
        arr = np.load(path / f"{key}.npy")
        if flat_s is not None and flat_s.get(key) is not None:
            out[key] = jax.device_put(arr, flat_s[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    leaves = [out[k] for k in flat_t]
    return jax.tree.unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, persist on a background thread."""

    def __init__(self, ckpt_dir: str | Path, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(
            lambda x: None if x is None else np.asarray(jax.device_get(x)),
            tree, is_leaf=lambda x: x is None)

        def _write():
            save(self.ckpt_dir, step, host_tree, keep_last=self.keep_last)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
