"""Deterministic host-sharded data pipeline with double-buffered prefetch.

Synthetic-but-learnable LM token streams: a seeded Markov-ish mixture of
n-gram templates over the vocab, so a few hundred training steps show a
clearly decreasing loss (used by examples/train_lm.py and the smoke tests).
Every batch is a pure function of (seed, step, host_id) — restart-safe and
identical across elastically re-sized runs that keep the global batch fixed.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 17
    global_batch: int = 8
    seq_len: int = 128
    n_templates: int = 64
    template_len: int = 16
    host_id: int = 0
    n_hosts: int = 1


class TokenStream:
    """Deterministic learnable token sequences."""

    def __init__(self, cfg: DataConfig, vocab_size: int):
        self.cfg = cfg
        self.vocab = vocab_size
        rng = np.random.default_rng(cfg.seed)
        self.templates = rng.integers(
            0, vocab_size, size=(cfg.n_templates, cfg.template_len))

    def batch(self, step: int) -> np.ndarray:
        """Global batch for a step; hosts slice their rows."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        reps = cfg.seq_len // cfg.template_len + 1
        ids = rng.integers(0, cfg.n_templates,
                           size=(cfg.global_batch, reps))
        toks = self.templates[ids].reshape(cfg.global_batch, -1)
        # sprinkle noise tokens so the task is not trivially memorizable
        noise = rng.random(toks.shape) < 0.02
        toks = np.where(noise, rng.integers(0, self.vocab, toks.shape), toks)
        return toks[:, :cfg.seq_len].astype(np.int32)

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self.batch(step)
        per = self.cfg.global_batch // self.cfg.n_hosts
        lo = self.cfg.host_id * per
        return {"tokens": toks[lo:lo + per]}


class MaskedFrameStream:
    """HuBERT-style stream: frame embeddings + masked-prediction labels."""

    def __init__(self, cfg: DataConfig, d_model: int, vocab_size: int):
        self.cfg = cfg
        self.d = d_model
        self.vocab = vocab_size
        rng = np.random.default_rng(cfg.seed)
        # codebook: labels are recoverable from embeddings (learnable task)
        self.codebook = rng.normal(size=(vocab_size, d_model)).astype(
            np.float32)

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, 1))
        per = cfg.global_batch // cfg.n_hosts
        labels = rng.integers(0, self.vocab, size=(per, cfg.seq_len))
        emb = self.codebook[labels] + \
            0.1 * rng.normal(size=(per, cfg.seq_len, self.d))
        mask = rng.random((per, cfg.seq_len)) < 0.3
        return {"embeddings": emb.astype(np.float32),
                "labels": labels.astype(np.int32), "mask": mask}


def make_stream(cfg: ArchConfig, data_cfg: DataConfig):
    if cfg.embedding_inputs:
        return MaskedFrameStream(data_cfg, cfg.d_model, cfg.vocab_size)
    return TokenStream(data_cfg, cfg.vocab_size)


class Prefetcher:
    """Double-buffered background prefetch of host batches."""

    def __init__(self, stream, start_step: int = 0, depth: int = 2):
        self.stream = stream
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.stream.host_batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
