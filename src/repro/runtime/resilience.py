"""Fault tolerance and straggler mitigation for long-running training.

On a real multi-pod deployment the failure domain is a host: a device error
surfaces as an exception from the jitted step (or a missing heartbeat). The
recovery policy implemented here is the standard one at 1000+ node scale:

  checkpoint every K steps (async)  ->  on failure: rebuild mesh over the
  surviving hosts (elastic)        ->  restore latest checkpoint with the
  new shardings                    ->  resume from the restored step.

``TrainSupervisor.run`` drives that loop; failures are injectable for tests.
``StragglerMonitor`` keeps an EWMA of step times and flags outliers — the
mitigation hook re-queues the step's data and (on real pods) reports the
slow host to the scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import manager as ckpt


@dataclasses.dataclass
class StragglerConfig:
    ewma_alpha: float = 0.1
    flag_factor: float = 2.5
    warmup_steps: int = 3


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.ewma: Optional[float] = None
        self.n = 0
        self.flagged: List[Dict] = []

    def record(self, step: int, seconds: float,
               host_times: Optional[Dict[int, float]] = None) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        is_straggler = False
        if self.ewma is not None and self.n > self.cfg.warmup_steps:
            if seconds > self.cfg.flag_factor * self.ewma:
                is_straggler = True
                slowest = None
                if host_times:
                    slowest = max(host_times, key=host_times.get)
                self.flagged.append(dict(step=step, seconds=seconds,
                                         ewma=self.ewma, host=slowest))
        a = self.cfg.ewma_alpha
        self.ewma = seconds if self.ewma is None else \
            (1 - a) * self.ewma + a * seconds
        return is_straggler


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep_last: int = 3
    max_failures: int = 5


class TrainSupervisor:
    """Checkpoint/restart driver around a step function.

    step_fn(state, step) -> state           (may raise on injected failure)
    save_tree(state) / load_tree(tree, state) adapt state <-> checkpointable
    pytree (params + opt state + data cursor).
    """

    def __init__(self, cfg: SupervisorConfig, step_fn: Callable,
                 state_to_tree: Callable, tree_to_state: Callable,
                 shardings=None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state_to_tree = state_to_tree
        self.tree_to_state = tree_to_state
        self.shardings = shardings
        self.checkpointer = ckpt.AsyncCheckpointer(cfg.ckpt_dir,
                                                   cfg.keep_last)
        self.monitor = StragglerMonitor()
        self.failures = 0
        self.restores = 0

    def _restore(self, state):
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return 0, state
        tree = self.state_to_tree(state)
        restored = ckpt.restore(self.cfg.ckpt_dir, step, tree,
                                shardings=self.shardings)
        self.restores += 1
        return step + 1, self.tree_to_state(restored, state)

    def run(self, state, n_steps: int, *, start_step: int = 0,
            on_metrics: Optional[Callable] = None):
        step = start_step
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                state = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                if self.monitor.record(step, dt):
                    pass  # on real pods: requeue + report slow host
                if on_metrics:
                    on_metrics(step, state, dt)
                if (step + 1) % self.cfg.ckpt_every == 0:
                    self.checkpointer.save(step, self.state_to_tree(state))
                step += 1
            except Exception:  # noqa: BLE001 — any step failure is recoverable
                self.failures += 1
                if self.failures > self.cfg.max_failures:
                    raise
                self.checkpointer.wait()
                step, state = self._restore(state)
        self.checkpointer.wait()
        return state
