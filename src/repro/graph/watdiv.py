"""WatDiv-style synthetic e-commerce knowledge graph + query workload.

WatDiv (Waterloo SPARQL Diversity Test Suite) is the second workload family
the adaptive-partitioning literature evaluates beside LUBM: a retail graph
(users, products, retailers, offers, reviews) whose benchmark queries are
grouped into the four structural families AWAPart's Exp-1 mixes — star (S*),
linear/path (L*), snowflake (F*) and complex (C*).

This module is a seeded miniature of that design:

* :func:`generate` builds the graph at a given ``scale`` (≈15k triples per
  unit) with Zipf-skewed popularity — ``product0`` is the most liked,
  reviewed, purchased and offered product, which is exactly the kind of
  single hot feature a flash crowd concentrates on;
* 16 named template queries (S1–S5, L1–L5, F1–F3, C1–C3) whose answer sets
  are non-empty **by construction**: every template's join path is either
  dense in the generated data or pinned by an explicitly emitted witness
  subgraph;
* ``topics`` groups the templates into *focus* families (``retail`` /
  ``social`` / ``review``) with near-disjoint feature sets — the axis the
  drift scenarios (``repro.scenario``) shift along;
* :meth:`WatDivDataset.sample_query` draws fresh random queries of any
  shape by walking actual edges of the store, so the walk instance itself
  is a witness binding and every sampled query is answerable.

The dataset satisfies the same ``Dataset`` duck type as ``graph.lubm``
(``store`` / ``dictionary`` / ``queries`` / ``workload`` /
``base_workload``), so it plugs straight into ``KGService.from_dataset``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graph.triples import Dictionary, TripleStore, build_store
from repro.query.pattern import Query, var

# --------------------------------------------------------------------------- #
# Schema
# --------------------------------------------------------------------------- #

PROPERTIES = [
    "rdf:type",
    # social
    "wsdbm:follows", "wsdbm:friendOf", "wsdbm:likes", "wsdbm:subscribes",
    # retail
    "wsdbm:makesPurchase", "wsdbm:purchaseFor", "wsdbm:purchaseDate",
    "gr:offers", "gr:includes", "gr:price",
    # reviews
    "rev:hasReview", "rev:reviewer", "rev:ratingValue",
    # attributes
    "wsdbm:hasGenre", "sorg:caption", "sorg:nationality",
    "foaf:givenName", "foaf:homepage", "og:tag",
]

N_CATEGORIES = 5

CLASSES = [
    "wsdbm:User", "wsdbm:Product", "wsdbm:Purchase", "rev:Review",
    "gr:Offer", "wsdbm:Retailer", "wsdbm:Website", "wsdbm:Genre",
    "wsdbm:Country", "og:Tag",
] + [f"wsdbm:ProductCategory{i}" for i in range(N_CATEGORIES)]

# category membership is materialized up to the product superclass, the same
# RDFS-entailment move graph.lubm makes for the ub:* hierarchy
SUPERCLASSES: Dict[str, Tuple[str, ...]] = {
    f"wsdbm:ProductCategory{i}": ("wsdbm:Product",)
    for i in range(N_CATEGORIES)
}


@dataclasses.dataclass
class WatDivNamed:
    """Concrete entity ids referenced as constants in the template queries."""
    product0: int        # the Zipf head: most liked/reviewed/offered product
    genre0: int          # product0's genre
    country0: int        # the most common user nationality
    retailer0: int
    website0: int        # the most-subscribed website
    tag0: int


@dataclasses.dataclass
class WatDivDataset:
    store: TripleStore
    dictionary: Dictionary
    named: WatDivNamed
    queries: Dict[str, Query]
    scale: int
    # focus families with near-disjoint feature sets — what drift scenarios
    # shift between (shape families live on Query.shape, as in graph.lubm)
    topics: Dict[str, Tuple[str, ...]]

    def workload(self, names: List[str],
                 frequencies: Dict[str, float] | None = None) -> List[Query]:
        freqs = frequencies or {}
        return [self.queries[n].with_frequency(freqs.get(n, 1.0))
                for n in names]

    def base_workload(self) -> List[Query]:
        return self.workload(sorted(self.queries))

    def extended_workload(self) -> List[Query]:
        return self.base_workload()

    def topic_workload(self, topic: str) -> List[Query]:
        return self.workload(list(self.topics[topic]))

    def family(self, shape: str) -> List[Query]:
        """All template queries of one structural shape
        (``star`` / ``linear`` / ``snowflake`` / ``complex``)."""
        return [q for _, q in sorted(self.queries.items())
                if q.shape == shape]

    # ------------------------------------------------------------------ #
    # the random query generator (witness-walk sampling)
    # ------------------------------------------------------------------ #
    def sample_query(self, rng: np.random.Generator,
                     shape: Optional[str] = None, name: str = "") -> Query:
        """Draw one random query of the given shape (random shape if None).

        Star/linear/snowflake queries are sampled by walking *actual edges*
        of the store outward from a random witness entity, so the walk
        itself is a binding and the query is answerable by construction.
        Complex queries instantiate one of the C templates (whose witness
        subgraphs the generator pinned). Same ``rng`` state, same query."""
        shape = shape or ["star", "linear", "snowflake",
                          "complex"][int(rng.integers(4))]
        if shape == "star":
            q = self._sample_star(rng)
        elif shape == "linear":
            q = self._sample_linear(rng)
        elif shape == "snowflake":
            q = self._sample_snowflake(rng)
        elif shape == "complex":
            tmpl = ["C1", "C2", "C3"][int(rng.integers(3))]
            q = self.queries[tmpl]
        else:
            raise ValueError(f"unknown shape {shape!r}")
        return dataclasses.replace(
            q, name=name or f"{shape[0].upper()}R{int(rng.integers(1 << 30))}")

    # hub classes and the attribute predicates dense on them
    _STAR_HUBS = (
        ("wsdbm:User", ("foaf:givenName", "sorg:nationality",
                        "wsdbm:subscribes", "wsdbm:likes")),
        ("wsdbm:Product", ("wsdbm:hasGenre", "sorg:caption", "og:tag")),
        ("rev:Review", ("rev:reviewer", "rev:ratingValue")),
        ("gr:Offer", ("gr:includes", "gr:price")),
    )
    # predicate chains that compose along dense paths (see generation)
    _CHAINS = (
        ("wsdbm:follows", "wsdbm:likes", "wsdbm:hasGenre"),
        ("wsdbm:makesPurchase", "wsdbm:purchaseFor", "sorg:caption"),
        ("gr:offers", "gr:includes", "wsdbm:hasGenre"),
        ("rev:hasReview", "rev:reviewer", "sorg:nationality"),
        ("wsdbm:friendOf", "wsdbm:subscribes"),
    )

    def _pid(self, term: str) -> int:
        tid = self.dictionary.lookup(term)
        assert tid is not None, term
        return tid

    def _sample_star(self, rng) -> Query:
        cls, preds = self._STAR_HUBS[int(rng.integers(len(self._STAR_HUBS)))]
        t, c = self._pid("rdf:type"), self._pid(cls)
        hubs = self.store.match(None, t, c)[:, 0]
        hub = int(hubs[int(rng.integers(len(hubs)))])      # witness entity
        X = var(0)
        pats, nv = [(X, t, c)], 1
        order = rng.permutation(len(preds))
        bound_const = False
        for pi in order.tolist():
            p = self._pid(preds[pi])
            rows = self.store.match(hub, p, None)
            if not len(rows):
                continue
            if not bound_const:        # exactly one constant-object pattern
                o = int(rows[int(rng.integers(len(rows)))][2])
                pats.append((X, p, o))
                bound_const = True
            else:
                pats.append((X, p, var(nv)))
                nv += 1
            if len(pats) >= 4:
                break
        return Query(name="SR", patterns=tuple(pats), shape="star")

    def _walk(self, rng, chain: Tuple[str, ...],
              tries: int = 32) -> Optional[List[Tuple[int, int, int]]]:
        """One witness walk along a predicate chain: each hop's subject is
        the previous hop's object, following real edges only."""
        pids = [self._pid(p) for p in chain]
        for _ in range(tries):
            rows = self.store.match(None, pids[0], None)
            trip = rows[int(rng.integers(len(rows)))]
            walk = [tuple(int(x) for x in trip)]
            ok = True
            for p in pids[1:]:
                nxt = self.store.match(walk[-1][2], p, None)
                if not len(nxt):
                    ok = False
                    break
                walk.append(tuple(int(x)
                                  for x in nxt[int(rng.integers(len(nxt)))]))
            if ok:
                return walk
        return None

    def _sample_linear(self, rng) -> Query:
        chain = self._CHAINS[int(rng.integers(len(self._CHAINS)))]
        walk = self._walk(rng, chain)
        if walk is None:               # vanishingly rare: fall back to L1
            return self.queries["L1"]
        pats = [(var(i), p, var(i + 1))
                for i, (_, p, _) in enumerate(walk)]
        if rng.random() < 0.5:         # pin the tail to the witness object
            s, p, o = pats[-1]
            pats[-1] = (s, p, walk[-1][2])
        return Query(name="LR", patterns=tuple(pats), shape="linear")

    def _sample_snowflake(self, rng) -> Query:
        # a 2-hop walk plus an attribute branch on the *middle* node
        chain = self._CHAINS[int(rng.integers(len(self._CHAINS)))][:2]
        walk = self._walk(rng, chain)
        if walk is None:
            return self.queries["F1"]
        X, Y, Z, W = var(0), var(1), var(2), var(3)
        pats = [(X, walk[0][1], Y), (Y, walk[1][1], Z)]
        mid = walk[0][2]
        branches = self.store.match(mid, None, None)
        used = {walk[1][1]}
        for row in branches[rng.permutation(len(branches))].tolist():
            p = int(row[1])
            if p not in used:
                pats.append((Y, p, W))
                break
        return Query(name="FR", patterns=tuple(pats), shape="snowflake")


# --------------------------------------------------------------------------- #
# Generation
# --------------------------------------------------------------------------- #


def _zipf_weights(n: int) -> np.ndarray:
    """Normalized 1/rank popularity weights: index 0 is the hot head."""
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64)
    return w / w.sum()


def generate(scale: int = 1, seed: int = 0) -> WatDivDataset:
    rng = np.random.default_rng(seed)
    d = Dictionary()
    pid = {name: d.encode(name) for name in PROPERTIES}
    cid = {name: d.encode(name) for name in CLASSES}
    rtype = pid["rdf:type"]

    next_id = len(d)

    def alloc(n: int) -> np.ndarray:
        nonlocal next_id
        ids = np.arange(next_id, next_id + n, dtype=np.int64)
        next_id += n
        return ids

    blocks: List[np.ndarray] = []

    def emit(s, p: int, o) -> None:
        s = np.asarray(s, dtype=np.int64).ravel()
        o_arr = (np.full(s.shape, o, dtype=np.int64)
                 if np.isscalar(o) else np.asarray(o, dtype=np.int64).ravel())
        blk = np.stack([s, np.full(s.shape, p, dtype=np.int64), o_arr], axis=1)
        blocks.append(blk)

    def emit_type(s, cls: str) -> None:
        emit(s, rtype, cid[cls])
        for sup in SUPERCLASSES.get(cls, ()):
            emit(s, rtype, cid[sup])

    # ------------------------------------------------------------------ #
    # entity pools
    # ------------------------------------------------------------------ #
    n_users = 600 * scale
    n_products = 240 * scale
    n_retailers = 12 * scale
    n_websites = 24 * scale
    n_genres = 12
    n_countries = 10
    n_tags = 30
    n_ratings = 10

    genres = alloc(n_genres);     emit_type(genres, "wsdbm:Genre")
    countries = alloc(n_countries); emit_type(countries, "wsdbm:Country")
    tags = alloc(n_tags);         emit_type(tags, "og:Tag")
    ratings = alloc(n_ratings)    # 1..10 rating vocabulary (no class)
    websites = alloc(n_websites); emit_type(websites, "wsdbm:Website")

    products = alloc(n_products)
    cat = rng.integers(0, N_CATEGORIES, n_products)
    for c in range(N_CATEGORIES):
        emit_type(products[cat == c], f"wsdbm:ProductCategory{c}")
    # product0 carries genre0 (the S1/L2/F1 constant); every product has
    # exactly one genre, one caption, 1-2 tags
    prod_genre = rng.choice(genres, size=n_products)
    prod_genre[0] = genres[0]
    emit(products, pid["wsdbm:hasGenre"], prod_genre)
    emit(products, pid["sorg:caption"], alloc(n_products))
    emit(products, pid["og:tag"], rng.choice(tags, size=n_products))
    extra_tag = rng.random(n_products) < 0.4
    emit(products[extra_tag], pid["og:tag"],
         rng.choice(tags, size=int(extra_tag.sum())))

    # popularity: product0 is the hot head of every retail interaction
    pop = _zipf_weights(n_products)

    users = alloc(n_users)
    emit_type(users, "wsdbm:User")
    emit(users, pid["foaf:givenName"], alloc(n_users))
    # country0 is deliberately the modal nationality (S2/L5/F3 constant)
    country_of = countries[
        np.minimum(rng.geometric(0.35, n_users) - 1, n_countries - 1)]
    emit(users, pid["sorg:nationality"], country_of)
    # subscriptions: website0 is the hot head
    sub_w = _zipf_weights(n_websites)
    emit(users, pid["wsdbm:subscribes"], rng.choice(websites, n_users, p=sub_w))
    # social edges: ~3 follows + ~2 friendships per user
    emit(np.repeat(users, 3), pid["wsdbm:follows"],
         rng.choice(users, 3 * n_users))
    emit(np.repeat(users, 2), pid["wsdbm:friendOf"],
         rng.choice(users, 2 * n_users))
    # likes, Zipf-weighted toward product0
    emit(np.repeat(users, 3), pid["wsdbm:likes"],
         rng.choice(products, 3 * n_users, p=pop))

    # purchases: 2 per user; ~30% of purchases spawn a review BY THE BUYER
    # of the purchased product — the C2 witness pattern, dense on purpose
    n_purch = 2 * n_users
    purchases = alloc(n_purch)
    emit_type(purchases, "wsdbm:Purchase")
    buyers = np.repeat(users, 2)
    bought = rng.choice(products, n_purch, p=pop)
    emit(buyers, pid["wsdbm:makesPurchase"], purchases)
    emit(purchases, pid["wsdbm:purchaseFor"], bought)
    emit(purchases, pid["wsdbm:purchaseDate"], alloc(n_purch))
    reviewed = rng.random(n_purch) < 0.3
    n_rev = int(reviewed.sum())
    reviews = alloc(n_rev)
    emit_type(reviews, "rev:Review")
    emit(bought[reviewed], pid["rev:hasReview"], reviews)
    emit(reviews, pid["rev:reviewer"], buyers[reviewed])
    emit(reviews, pid["rev:ratingValue"], rng.choice(ratings, n_rev))

    # retailers and offers: each retailer lists ~20 Zipf-weighted products
    retailers = alloc(n_retailers)
    emit_type(retailers, "wsdbm:Retailer")
    emit(retailers, pid["foaf:homepage"], rng.choice(websites, n_retailers))
    n_offers = 20 * n_retailers
    offers = alloc(n_offers)
    emit_type(offers, "gr:Offer")
    emit(np.repeat(retailers, 20), pid["gr:offers"], offers)
    listed = rng.choice(products, n_offers, p=pop)
    listed[0] = products[0]            # offer0 lists product0 (S3 witness)
    emit(offers, pid["gr:includes"], listed)
    emit(offers, pid["gr:price"], alloc(n_offers))

    # ------------------------------------------------------------------ #
    # pinned witness subgraph: guarantees the sparse template joins
    # ------------------------------------------------------------------ #
    u0, u1 = users[0], users[1]
    emit(u0, pid["wsdbm:friendOf"], u1)
    emit(u0, pid["wsdbm:likes"], products[0])    # C1: friends co-like
    emit(u1, pid["wsdbm:likes"], products[0])
    emit(u1, pid["wsdbm:subscribes"], websites[0])   # L4 tail
    emit(u0, pid["sorg:nationality"], countries[0])  # S5 ∩ S2 witness

    triples = np.concatenate(blocks, axis=0)
    assert triples.max() < np.iinfo(np.int32).max
    store = build_store(triples.astype(np.int32), d)
    named = WatDivNamed(
        product0=int(products[0]), genre0=int(genres[0]),
        country0=int(countries[0]), retailer0=int(retailers[0]),
        website0=int(websites[0]), tag0=int(tags[0]))
    queries, topics = _make_queries(pid, cid, named)
    return WatDivDataset(store=store, dictionary=d, named=named,
                         queries=queries, scale=scale, topics=topics)


# --------------------------------------------------------------------------- #
# The 16-query template workload
# --------------------------------------------------------------------------- #


def _make_queries(pid: Dict[str, int], cid: Dict[str, int],
                  nm: WatDivNamed) -> Tuple[Dict[str, Query],
                                            Dict[str, Tuple[str, ...]]]:
    t = pid["rdf:type"]
    X, Y, Z, W, V1, V2 = (var(i) for i in range(6))

    def q(name: str, shape: str, *pats) -> Query:
        return Query(name=name, patterns=tuple(pats), shape=shape)

    qs = [
        # ---- stars
        q("S1", "star",
          (X, t, cid["wsdbm:Product"]),
          (X, pid["wsdbm:hasGenre"], nm.genre0),
          (X, pid["sorg:caption"], V1)),
        q("S2", "star",
          (X, t, cid["wsdbm:User"]),
          (X, pid["sorg:nationality"], nm.country0),
          (X, pid["foaf:givenName"], V1)),
        q("S3", "star",
          (X, t, cid["gr:Offer"]),
          (X, pid["gr:includes"], nm.product0),
          (X, pid["gr:price"], V1)),
        q("S4", "star",
          (X, t, cid["rev:Review"]),
          (X, pid["rev:ratingValue"], V1),
          (X, pid["rev:reviewer"], Y)),
        q("S5", "star",
          (X, pid["wsdbm:likes"], nm.product0),
          (X, pid["wsdbm:subscribes"], Y),
          (X, pid["foaf:givenName"], V1)),
        # ---- linear paths
        q("L1", "linear",
          (X, pid["wsdbm:follows"], Y),
          (Y, pid["wsdbm:likes"], Z),
          (Z, pid["wsdbm:hasGenre"], W)),
        q("L2", "linear",
          (X, pid["wsdbm:makesPurchase"], Y),
          (Y, pid["wsdbm:purchaseFor"], Z),
          (Z, pid["wsdbm:hasGenre"], nm.genre0)),
        q("L3", "linear",
          (X, pid["gr:offers"], Y),
          (Y, pid["gr:includes"], Z),
          (Z, pid["sorg:caption"], W)),
        q("L4", "linear",
          (X, pid["wsdbm:friendOf"], Y),
          (Y, pid["wsdbm:subscribes"], nm.website0)),
        q("L5", "linear",
          (X, pid["rev:hasReview"], Y),
          (Y, pid["rev:reviewer"], Z),
          (Z, pid["sorg:nationality"], nm.country0)),
        # ---- snowflakes
        q("F1", "snowflake",
          (X, t, cid["wsdbm:Product"]),
          (X, pid["wsdbm:hasGenre"], nm.genre0),
          (X, pid["rev:hasReview"], Y),
          (Y, pid["rev:reviewer"], Z),
          (Z, pid["sorg:nationality"], W)),
        q("F2", "snowflake",
          (X, pid["gr:includes"], Y),
          (X, pid["gr:price"], V1),
          (Y, pid["wsdbm:hasGenre"], Z),
          (Y, pid["sorg:caption"], V2)),
        q("F3", "snowflake",
          (X, pid["wsdbm:likes"], Y),
          (X, pid["sorg:nationality"], nm.country0),
          (Y, pid["rev:hasReview"], Z),
          (Z, pid["rev:ratingValue"], W)),
        # ---- complex
        q("C1", "complex",
          (X, pid["wsdbm:friendOf"], Y),
          (X, pid["wsdbm:likes"], Z),
          (Y, pid["wsdbm:likes"], Z),
          (Z, t, cid["wsdbm:Product"])),
        q("C2", "complex",
          (X, pid["wsdbm:makesPurchase"], Y),
          (Y, pid["wsdbm:purchaseFor"], Z),
          (Z, pid["rev:hasReview"], W),
          (W, pid["rev:reviewer"], X)),
        q("C3", "complex",
          (X, pid["wsdbm:follows"], Y),
          (Y, pid["wsdbm:follows"], Z),
          (X, pid["sorg:nationality"], W),
          (Z, pid["sorg:nationality"], W)),
    ]
    topics = {
        "retail": ("S1", "S3", "L2", "L3", "F2", "C2"),
        "social": ("S2", "S5", "L1", "L4", "C1", "C3"),
        "review": ("S4", "L5", "F1", "F3"),
    }
    return {query.name: query for query in qs}, topics


_CACHE: Dict[Tuple[int, int], WatDivDataset] = {}


def load(scale: int = 1, seed: int = 0) -> WatDivDataset:
    """Memoized generation (the dataset is reused across benchmarks)."""
    key = (scale, seed)
    if key not in _CACHE:
        _CACHE[key] = generate(scale, seed)
    return _CACHE[key]
