"""LUBM(k)-style synthetic knowledge graph + the paper's 24-query workload.

Faithful to the evaluation setup of the paper: LUBM with 10 universities
(~1.5M triples after materialization), the 14 standard LUBM queries Q1..Q14,
and 10 extra queries EQ1..EQ10 that are "a mixture of linear, star, snowflake,
and complex queries" (Sec. V, Exp 1).

RDFS subclass/subproperty entailment (Student ⊒ GraduateStudent, degreeFrom ⊒
undergraduateDegreeFrom, ...) is materialized at generation time, as the
LUBM queries require inference the raw data does not contain.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.graph.triples import Dictionary, TripleStore, build_store
from repro.query.pattern import Query, var

# --------------------------------------------------------------------------- #
# Schema
# --------------------------------------------------------------------------- #

PROPERTIES = [
    "rdf:type", "ub:memberOf", "ub:subOrganizationOf",
    "ub:undergraduateDegreeFrom", "ub:mastersDegreeFrom",
    "ub:doctoralDegreeFrom", "ub:degreeFrom", "ub:worksFor", "ub:advisor",
    "ub:teacherOf", "ub:takesCourse", "ub:publicationAuthor", "ub:headOf",
    "ub:researchInterest", "ub:emailAddress", "ub:telephone", "ub:name",
    "ub:teachingAssistantOf",
]

CLASSES = [
    "ub:University", "ub:Department", "ub:ResearchGroup", "ub:FullProfessor",
    "ub:AssociateProfessor", "ub:AssistantProfessor", "ub:Lecturer",
    "ub:UndergraduateStudent", "ub:GraduateStudent", "ub:Course",
    "ub:GraduateCourse", "ub:Publication", "ub:TeachingAssistant",
    # materialized superclasses
    "ub:Professor", "ub:Faculty", "ub:Student", "ub:Person", "ub:Organization",
    "ub:Chair",
]

SUPERCLASSES: Dict[str, Tuple[str, ...]] = {
    "ub:FullProfessor": ("ub:Professor", "ub:Faculty", "ub:Person"),
    "ub:AssociateProfessor": ("ub:Professor", "ub:Faculty", "ub:Person"),
    "ub:AssistantProfessor": ("ub:Professor", "ub:Faculty", "ub:Person"),
    "ub:Lecturer": ("ub:Faculty", "ub:Person"),
    "ub:UndergraduateStudent": ("ub:Student", "ub:Person"),
    "ub:GraduateStudent": ("ub:Student", "ub:Person"),
    "ub:University": ("ub:Organization",),
    "ub:Department": ("ub:Organization",),
    "ub:ResearchGroup": ("ub:Organization",),
    "ub:GraduateCourse": ("ub:Course",),
}

DEGREE_PROPS = ("ub:undergraduateDegreeFrom", "ub:mastersDegreeFrom",
                "ub:doctoralDegreeFrom")


@dataclasses.dataclass
class Named:
    """Concrete entity ids referenced as constants in the benchmark queries."""
    university0: int
    department0: int           # Department0 of University0
    grad_course0: int          # GraduateCourse0 of Department0
    assistant_prof0: int
    associate_prof0: int
    research_interest0: int


@dataclasses.dataclass
class LubmDataset:
    store: TripleStore
    dictionary: Dictionary
    named: Named
    queries: Dict[str, Query]
    n_universities: int

    def workload(self, names: List[str],
                 frequencies: Dict[str, float] | None = None) -> List[Query]:
        freqs = frequencies or {}
        return [self.queries[n].with_frequency(freqs.get(n, 1.0))
                for n in names]

    def base_workload(self) -> List[Query]:
        return self.workload([f"Q{i}" for i in range(1, 15)])

    def extended_workload(self) -> List[Query]:
        return self.workload([f"Q{i}" for i in range(1, 15)]
                             + [f"EQ{i}" for i in range(1, 11)])


# --------------------------------------------------------------------------- #
# Generation
# --------------------------------------------------------------------------- #


def generate(n_universities: int = 10, seed: int = 0) -> LubmDataset:
    rng = np.random.default_rng(seed)
    d = Dictionary()
    pid = {name: d.encode(name) for name in PROPERTIES}
    cid = {name: d.encode(name) for name in CLASSES}
    rtype = pid["rdf:type"]

    next_id = len(d)

    def alloc(n: int) -> np.ndarray:
        nonlocal next_id
        ids = np.arange(next_id, next_id + n, dtype=np.int64)
        next_id += n
        return ids

    blocks: List[np.ndarray] = []

    def emit(s: np.ndarray, p: int, o) -> None:
        s = np.asarray(s, dtype=np.int64).ravel()
        o_arr = (np.full(s.shape, o, dtype=np.int64)
                 if np.isscalar(o) else np.asarray(o, dtype=np.int64).ravel())
        blk = np.stack([s, np.full(s.shape, p, dtype=np.int64), o_arr], axis=1)
        blocks.append(blk)

    def emit_type(s: np.ndarray, cls: str) -> None:
        emit(s, rtype, cid[cls])
        for sup in SUPERCLASSES.get(cls, ()):
            emit(s, rtype, cid[sup])

    # research-interest vocabulary shared across the graph
    interests = alloc(40)

    universities = alloc(n_universities)
    emit_type(universities, "ub:University")
    named: Named | None = None

    for u_idx, univ in enumerate(universities):
        n_dept = int(rng.integers(15, 26))
        depts = alloc(n_dept)
        emit_type(depts, "ub:Department")
        emit(depts, pid["ub:subOrganizationOf"], univ)

        for d_idx, dept in enumerate(depts):
            nf_full = int(rng.integers(7, 11))
            nf_assoc = int(rng.integers(10, 15))
            nf_asst = int(rng.integers(8, 12))
            nf_lect = int(rng.integers(5, 8))
            full = alloc(nf_full); assoc = alloc(nf_assoc)
            asst = alloc(nf_asst); lect = alloc(nf_lect)
            for ids, cls in ((full, "ub:FullProfessor"),
                             (assoc, "ub:AssociateProfessor"),
                             (asst, "ub:AssistantProfessor"),
                             (lect, "ub:Lecturer")):
                emit_type(ids, cls)
            faculty = np.concatenate([full, assoc, asst, lect])
            emit(faculty, pid["ub:worksFor"], dept)
            # head of department (a full professor) — materialized ub:Chair
            emit(full[:1], pid["ub:headOf"], dept)
            emit_type(full[:1], "ub:Chair")

            # attributes: one literal-ish object each (unique ids)
            for prop in ("ub:emailAddress", "ub:telephone", "ub:name"):
                emit(faculty, pid[prop], alloc(len(faculty)))
            emit(faculty, pid["ub:researchInterest"],
                 rng.choice(interests, size=len(faculty)))

            # degrees: professors hold all three; lecturers one
            prof = np.concatenate([full, assoc, asst])
            for prop in DEGREE_PROPS:
                target = rng.choice(universities, size=len(prof))
                emit(prof, pid[prop], target)
                emit(prof, pid["ub:degreeFrom"], target)
            lect_deg = rng.choice(universities, size=len(lect))
            emit(lect, pid["ub:undergraduateDegreeFrom"], lect_deg)
            emit(lect, pid["ub:degreeFrom"], lect_deg)

            # courses: every faculty teaches 1-2; ~30% are graduate courses
            n_courses = len(faculty) + int(rng.integers(0, len(faculty) // 2 + 1))
            courses = alloc(n_courses)
            n_grad_c = max(1, int(0.3 * n_courses))
            grad_courses, ug_courses = courses[:n_grad_c], courses[n_grad_c:]
            emit_type(grad_courses, "ub:GraduateCourse")
            emit_type(ug_courses, "ub:Course")
            teachers = np.concatenate(
                [faculty, rng.choice(faculty, size=n_courses - len(faculty))])
            emit(teachers[:n_courses], pid["ub:teacherOf"], courses)

            # students
            n_ug = int(len(faculty) * rng.integers(9, 16))
            n_gr = int(len(faculty) * rng.integers(3, 6))
            ug = alloc(n_ug); gr = alloc(n_gr)
            emit_type(ug, "ub:UndergraduateStudent")
            emit_type(gr, "ub:GraduateStudent")
            students = np.concatenate([ug, gr])
            emit(students, pid["ub:memberOf"], dept)
            for prop in ("ub:emailAddress", "ub:telephone", "ub:name"):
                emit(students, pid[prop], alloc(len(students)))
            # course enrollment: UG take UG courses, grads take grad courses
            for group, pool, lo, hi in ((ug, ug_courses, 2, 5),
                                        (gr, grad_courses, 1, 4)):
                if len(pool) == 0:
                    continue
                k = int(rng.integers(lo, hi))
                take = rng.choice(pool, size=(len(group), k))
                emit(np.repeat(group, k), pid["ub:takesCourse"], take.ravel())
            # advisors + UG degree for grads
            emit(gr, pid["ub:advisor"], rng.choice(prof, size=len(gr)))
            gr_deg = rng.choice(universities, size=len(gr))
            emit(gr, pid["ub:undergraduateDegreeFrom"], gr_deg)
            emit(gr, pid["ub:degreeFrom"], gr_deg)
            # ~20% of grads TA a course
            n_ta = len(gr) // 5
            if n_ta and len(ug_courses):
                tas = gr[:n_ta]
                emit_type(tas, "ub:TeachingAssistant")
                emit(tas, pid["ub:teachingAssistantOf"],
                     rng.choice(ug_courses, size=n_ta))

            # publications: faculty author 5-15; grads co-author some
            n_pub_per = rng.integers(5, 16, size=len(faculty))
            n_pubs = int(n_pub_per.sum())
            pubs = alloc(n_pubs)
            emit_type(pubs, "ub:Publication")
            emit(pubs, pid["ub:publicationAuthor"],
                 np.repeat(faculty, n_pub_per))
            co = rng.random(n_pubs) < 0.25
            if co.any() and len(gr):
                emit(pubs[co], pid["ub:publicationAuthor"],
                     rng.choice(gr, size=int(co.sum())))

            # research groups
            n_rg = int(rng.integers(10, 21))
            rgs = alloc(n_rg)
            emit_type(rgs, "ub:ResearchGroup")
            emit(rgs, pid["ub:subOrganizationOf"], dept)

            if u_idx == 0 and d_idx == 0:
                named = Named(
                    university0=int(univ), department0=int(dept),
                    grad_course0=int(grad_courses[0]),
                    assistant_prof0=int(asst[0]),
                    associate_prof0=int(assoc[0]),
                    research_interest0=int(interests[0]),
                )

    triples = np.concatenate(blocks, axis=0)
    assert triples.max() < np.iinfo(np.int32).max
    store = build_store(triples.astype(np.int32), d)
    assert named is not None
    queries = _make_queries(pid, cid, named)
    return LubmDataset(store=store, dictionary=d, named=named,
                       queries=queries, n_universities=n_universities)


# --------------------------------------------------------------------------- #
# The 24-query workload
# --------------------------------------------------------------------------- #


def _make_queries(pid: Dict[str, int], cid: Dict[str, int],
                  nm: Named) -> Dict[str, Query]:
    t = pid["rdf:type"]
    X, Y, Z, W, V1, V2, V3 = (var(i) for i in range(7))

    def q(name: str, shape: str, *pats) -> Query:
        return Query(name=name, patterns=tuple(pats), shape=shape)

    qs = [
        q("Q1", "star",
          (X, t, cid["ub:GraduateStudent"]),
          (X, pid["ub:takesCourse"], nm.grad_course0)),
        q("Q2", "complex",
          (X, t, cid["ub:GraduateStudent"]),
          (Y, t, cid["ub:University"]),
          (Z, t, cid["ub:Department"]),
          (X, pid["ub:memberOf"], Z),
          (Z, pid["ub:subOrganizationOf"], Y),
          (X, pid["ub:undergraduateDegreeFrom"], Y)),
        q("Q3", "star",
          (X, t, cid["ub:Publication"]),
          (X, pid["ub:publicationAuthor"], nm.assistant_prof0)),
        q("Q4", "star",
          (X, t, cid["ub:Professor"]),
          (X, pid["ub:worksFor"], nm.department0),
          (X, pid["ub:name"], V1),
          (X, pid["ub:emailAddress"], V2),
          (X, pid["ub:telephone"], V3)),
        q("Q5", "star",
          (X, t, cid["ub:Person"]),
          (X, pid["ub:memberOf"], nm.department0)),
        q("Q6", "linear", (X, t, cid["ub:Student"])),
        q("Q7", "snowflake",
          (X, t, cid["ub:Student"]),
          (Y, t, cid["ub:Course"]),
          (X, pid["ub:takesCourse"], Y),
          (nm.associate_prof0, pid["ub:teacherOf"], Y)),
        q("Q8", "snowflake",
          (X, t, cid["ub:Student"]),
          (Y, t, cid["ub:Department"]),
          (X, pid["ub:memberOf"], Y),
          (Y, pid["ub:subOrganizationOf"], nm.university0),
          (X, pid["ub:emailAddress"], Z)),
        q("Q9", "complex",
          (X, t, cid["ub:Student"]),
          (Y, t, cid["ub:Faculty"]),
          (Z, t, cid["ub:Course"]),
          (X, pid["ub:advisor"], Y),
          (Y, pid["ub:teacherOf"], Z),
          (X, pid["ub:takesCourse"], Z)),
        q("Q10", "star",
          (X, t, cid["ub:Student"]),
          (X, pid["ub:takesCourse"], nm.grad_course0)),
        q("Q11", "star",
          (X, t, cid["ub:ResearchGroup"]),
          (X, pid["ub:subOrganizationOf"], nm.university0)),
        q("Q12", "snowflake",
          (X, t, cid["ub:Chair"]),
          (Y, t, cid["ub:Department"]),
          (X, pid["ub:worksFor"], Y),
          (Y, pid["ub:subOrganizationOf"], nm.university0)),
        q("Q13", "star",
          (X, t, cid["ub:Person"]),
          (X, pid["ub:degreeFrom"], nm.university0)),
        q("Q14", "linear", (X, t, cid["ub:UndergraduateStudent"])),
        # ---- 10 extra queries (Exp 1): linear / star / snowflake / complex
        q("EQ1", "linear",
          (X, pid["ub:advisor"], Y),
          (Y, pid["ub:worksFor"], Z),
          (Z, pid["ub:subOrganizationOf"], W)),
        q("EQ2", "star",
          (X, t, cid["ub:FullProfessor"]),
          (X, pid["ub:name"], V1),
          (X, pid["ub:emailAddress"], V2),
          (X, pid["ub:telephone"], V3),
          (X, pid["ub:researchInterest"], W)),
        q("EQ3", "snowflake",
          (X, t, cid["ub:FullProfessor"]),
          (X, pid["ub:teacherOf"], Y),
          (Z, pid["ub:takesCourse"], Y),
          (Z, t, cid["ub:UndergraduateStudent"])),
        q("EQ4", "complex",
          (X, t, cid["ub:GraduateStudent"]),
          (X, pid["ub:advisor"], Y),
          (Z, pid["ub:publicationAuthor"], Y),
          (Z, t, cid["ub:Publication"])),
        q("EQ5", "star",
          (Y, t, cid["ub:Department"]),
          (Y, pid["ub:subOrganizationOf"], nm.university0),
          (X, pid["ub:worksFor"], Y),
          (X, t, cid["ub:AssociateProfessor"])),
        q("EQ6", "linear",
          (X, pid["ub:publicationAuthor"], Y),
          (Y, pid["ub:worksFor"], Z),
          (Z, pid["ub:subOrganizationOf"], W)),
        q("EQ7", "complex",
          (X, t, cid["ub:GraduateStudent"]),
          (X, pid["ub:advisor"], Y),
          (Y, pid["ub:headOf"], Z),
          (Z, t, cid["ub:Department"])),
        q("EQ8", "snowflake",
          (X, pid["ub:teachingAssistantOf"], Y),
          (Y, t, cid["ub:Course"]),
          (X, pid["ub:memberOf"], Z),
          (Z, t, cid["ub:Department"])),
        q("EQ9", "star",
          (X, t, cid["ub:FullProfessor"]),
          (X, pid["ub:mastersDegreeFrom"], nm.university0),
          (X, pid["ub:researchInterest"], nm.research_interest0)),
        q("EQ10", "complex",
          (X, pid["ub:publicationAuthor"], Y),
          (X, pid["ub:publicationAuthor"], Z),
          (Y, t, cid["ub:FullProfessor"]),
          (Z, t, cid["ub:GraduateStudent"]),
          (Y, pid["ub:worksFor"], W)),
    ]
    return {query.name: query for query in qs}


_CACHE: Dict[Tuple[int, int], LubmDataset] = {}


def load(n_universities: int = 10, seed: int = 0) -> LubmDataset:
    """Memoized generation (the dataset is reused across benchmarks)."""
    key = (n_universities, seed)
    if key not in _CACHE:
        _CACHE[key] = generate(n_universities, seed)
    return _CACHE[key]
