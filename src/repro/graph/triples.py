"""Dictionary-encoded RDF triple store.

The paper stores triples in Virtuoso instances; the TPU-native analogue is a
dictionary-encoded ``int32 (N, 3)`` array with sorted permutation indexes
(SPO / POS / OSP), so any triple pattern resolves to a contiguous index range
via binary search — the same role Lucene plays for AWAPart's initial
partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

S, P, O = 0, 1, 2


class Dictionary:
    """Bidirectional term <-> id mapping (RDF dictionary encoding)."""

    def __init__(self) -> None:
        self._term_to_id: Dict[str, int] = {}
        self._id_to_term: List[str] = []

    def encode(self, term: str) -> int:
        tid = self._term_to_id.get(term)
        if tid is None:
            tid = len(self._id_to_term)
            self._term_to_id[term] = tid
            self._id_to_term.append(term)
        return tid

    def lookup(self, term: str) -> Optional[int]:
        return self._term_to_id.get(term)

    def decode(self, tid: int) -> str:
        return self._id_to_term[tid]

    def __len__(self) -> int:
        return len(self._id_to_term)


def _sort_index(triples: np.ndarray, order: Tuple[int, int, int]) -> np.ndarray:
    """Permutation sorting ``triples`` lexicographically by the given column order."""
    # np.lexsort keys: last key is primary.
    keys = tuple(triples[:, c] for c in reversed(order))
    return np.lexsort(keys).astype(np.int64)


@dataclasses.dataclass
class TripleStore:
    """Immutable dictionary-encoded triple set with SPO/POS/OSP indexes."""

    triples: np.ndarray                 # (N, 3) int32
    dictionary: Dictionary
    spo: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]
    pos: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]
    osp: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        assert self.triples.ndim == 2 and self.triples.shape[1] == 3
        self.triples = np.ascontiguousarray(self.triples, dtype=np.int32)
        if self.spo is None:
            self.spo = _sort_index(self.triples, (S, P, O))
        if self.pos is None:
            self.pos = _sort_index(self.triples, (P, O, S))
        if self.osp is None:
            self.osp = _sort_index(self.triples, (O, S, P))
        self._sorted_views: Dict[str, np.ndarray] = {}

    def _sorted_view(self, which: str) -> np.ndarray:
        """A permutation's sorted triple matrix, materialized lazily on the
        first pattern lookup that probes it, so a match is pure binary
        search (no O(N) gather per call) without paying memory for
        permutations a store never queries."""
        view = self._sorted_views.get(which)
        if view is None:
            view = np.ascontiguousarray(self.triples[getattr(self, which)])
            self._sorted_views[which] = view
        return view

    # ------------------------------------------------------------------ #
    @property
    def n_triples(self) -> int:
        return int(self.triples.shape[0])

    def _range(self, view: np.ndarray, cols: Sequence[int],
               vals: Sequence[int]) -> Tuple[int, int]:
        """[lo, hi) range in the sorted ``view`` matching vals on prefix cols."""
        # successive binary searches on each prefix column
        lo, hi = 0, view.shape[0]
        for c, v in zip(cols, vals):
            col = view[lo:hi, c]
            lo2 = lo + int(np.searchsorted(col, v, side="left"))
            hi2 = lo + int(np.searchsorted(col, v, side="right"))
            lo, hi = lo2, hi2
            if lo >= hi:
                return lo, lo
        return lo, hi

    def match(self, s: Optional[int], p: Optional[int],
              o: Optional[int]) -> np.ndarray:
        """Return (M, 3) triples matching the pattern; None = wildcard."""
        return self.triples[self.match_indices(s, p, o)]

    def match_indices(self, s: Optional[int], p: Optional[int],
                      o: Optional[int]) -> np.ndarray:
        """Row indices (into ``triples``) matching the pattern; None = wildcard.

        The permutation values in the sorted indexes *are* row ids, so
        ``match`` is just this plus a gather."""
        if s is not None and p is None and o is None:
            lo, hi = self._range(self._sorted_view("spo"), (S,), (s,))
            return self.spo[lo:hi]
        if s is not None and p is not None and o is None:
            lo, hi = self._range(self._sorted_view("spo"), (S, P), (s, p))
            return self.spo[lo:hi]
        if s is not None and p is not None and o is not None:
            lo, hi = self._range(self._sorted_view("spo"), (S, P, O),
                                 (s, p, o))
            return self.spo[lo:hi]
        if p is not None and o is None and s is None:
            lo, hi = self._range(self._sorted_view("pos"), (P,), (p,))
            return self.pos[lo:hi]
        if p is not None and o is not None and s is None:
            lo, hi = self._range(self._sorted_view("pos"), (P, O), (p, o))
            return self.pos[lo:hi]
        if o is not None and s is None and p is None:
            lo, hi = self._range(self._sorted_view("osp"), (O,), (o,))
            return self.osp[lo:hi]
        if o is not None and s is not None and p is None:
            lo, hi = self._range(self._sorted_view("osp"), (O, S), (o, s))
            return self.osp[lo:hi]
        return np.arange(self.n_triples, dtype=np.int64)  # fully unbound

    def count(self, s: Optional[int], p: Optional[int], o: Optional[int]) -> int:
        return int(self.match_indices(s, p, o).shape[0])

    # ------------------------------------------------------------------ #
    # live mutation (repro.write)
    # ------------------------------------------------------------------ #
    def apply_mutation(self, inserts: np.ndarray,
                       delete_rows: np.ndarray) -> np.ndarray:
        """Mutate the store in place: drop the rows in ``delete_rows``
        (global row ids), append ``inserts`` ((M, 3) int32 triples assumed
        not already present), and rebuild the SPO/POS/OSP permutations.

        Mutating *in place* is what keeps every holder of this store object
        (``FeatureSpace.store``, ``KGService.store``, the facade and its
        untouched shard views) consistent without re-wiring references.

        Returns the old-row -> new-row remap, (N_old,) int64 with ``-1`` for
        deleted rows. Surviving rows keep their relative order and inserts
        append after them, so with no deletes the remap is the identity and
        callers may skip re-indexing entirely.
        """
        delete_rows = np.asarray(delete_rows, dtype=np.int64)
        inserts = np.asarray(inserts, dtype=np.int32).reshape(-1, 3)
        n_old = self.n_triples
        remap = np.arange(n_old, dtype=np.int64)
        if len(delete_rows):
            keep = np.ones(n_old, dtype=bool)
            keep[delete_rows] = False
            remap[~keep] = -1
            remap[keep] = np.arange(int(keep.sum()), dtype=np.int64)
            triples = self.triples[keep]
        else:
            triples = self.triples
        if len(inserts):
            triples = np.concatenate([triples, inserts])
        if triples is not self.triples:
            self.triples = np.ascontiguousarray(triples, dtype=np.int32)
            self.spo = _sort_index(self.triples, (S, P, O))
            self.pos = _sort_index(self.triples, (P, O, S))
            self.osp = _sort_index(self.triples, (O, S, P))
            self._sorted_views.clear()
        return remap


def build_store(triples: np.ndarray, dictionary: Dictionary) -> TripleStore:
    # drop duplicate triples (materialization can produce them)
    uniq = np.unique(triples, axis=0)
    return TripleStore(triples=uniq, dictionary=dictionary)
