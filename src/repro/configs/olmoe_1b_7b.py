"""olmoe-1b-7b [moe]: 16L d=2048 16H (kv=16) expert_ff=1024 vocab=50304, 64e top-8.

[arXiv:2409.02060]: fully sparse MoE, 64 experts top-8, qk-norm.
AWAPart expert placement applies (rank-granularity dispatch).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    n_experts=64, top_k=8, qk_norm=True,
)
