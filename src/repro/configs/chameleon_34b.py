"""chameleon-34b [vlm]: 48L d=8192 64H (GQA kv=8) ff=22016 vocab=65536.

Early-fusion mixed-modal decoder [arXiv:2405.09818]; image VQ tokens share
the 65536 vocab, so the modality frontend is the (stub) VQ tokenizer and the
backbone is a plain decoder with qk-norm (Chameleon's stability fix).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, rope=True,
)
