"""Architecture config schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | encoder | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention flavor
    causal: bool = True
    rope: bool = True
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False

    # block flavor
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    activation: str = "silu"        # silu (SwiGLU) | gelu
    tied_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "expert"    # expert | rank (AWAPart-placed)

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0             # zamba2: shared attn block period (0 = none)

    # RWKV6
    rwkv: bool = False
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64

    # modality frontend stub: inputs are precomputed embeddings, not token ids
    embedding_inputs: bool = False

    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    softmax_f32: bool = True        # False: bf16 attention probs, f32 stats
    remat: str = "full"             # full | dots | none
    scan_layers: bool = True
    use_flash: bool = False         # Pallas flash-attention kernel path

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def sharding_profile(self) -> str:
        """dp (pure data-parallel, ZeRO-1) for small models; fsdp_tp above."""
        return "dp" if self.n_params() <= 1.5e9 else "fsdp_tp"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.attn_every == 0

    @property
    def has_decode(self) -> bool:
        return self.causal         # encoder-only archs have no decode step

    @property
    def subquadratic(self) -> bool:
        """True if long-context (500k) decode is supported."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n = self.vocab_size * d                       # embed
        if not self.tied_embeddings:
            n += self.vocab_size * d                  # head
        if self.rwkv:
            per = (2 * d * d                          # r, g (approx; r:d*d, g)
                   + 2 * d * d                        # k, v
                   + d * d                            # output
                   + 6 * d * self.rwkv_lora_dim * 2   # ddlerp + decay loras
                   + d * self.d_ff + self.d_ff * d    # channel mix
                   + 4 * d)
            return n + L * per
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            ngroups = 1
            per = (d * (2 * d_in + 2 * ngroups * self.ssm_state
                        + d_in // self.ssm_head_dim)
                   + d_in * d + 3 * d_in)
            n += L * per
            if self.attn_every:
                n_blocks = 1                           # shared (reused) block
                attn = (2 * d) * self.n_heads * hd + \
                    2 * (2 * d) * self.n_kv_heads * hd + self.n_heads * hd * d
                mlp = 3 * d * self.d_ff
                n += n_blocks * (attn + mlp)
            return n
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            mlp_mult = 3 if self.activation == "silu" else 2
            mlp = mlp_mult * d * self.d_ff
        return n + L * (attn + mlp + 2 * d)

    def n_active_params(self) -> int:
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        dense = self.n_params() - L * self.n_experts * 3 * d * self.d_ff
        return dense + L * self.top_k * 3 * d * self.d_ff

    def reduced(self, n_layers: int = 2, d_model: int = 64,
                vocab: int = 128) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = d_model / self.d_model
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        return dataclasses.replace(
            self,
            n_layers=n_layers, d_model=d_model,
            n_heads=heads if self.n_heads else 0,
            n_kv_heads=kv if self.n_kv_heads else 0,
            head_dim=d_model // max(heads, 1) if self.head_dim else 0,
            d_ff=max(32, int(self.d_ff * scale) // 8 * 8),
            vocab_size=vocab,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
            rwkv_head_dim=16 if self.rwkv else self.rwkv_head_dim,
            rwkv_lora_dim=8 if self.rwkv else self.rwkv_lora_dim,
            remat="none", scan_layers=True,
            compute_dtype="float32",     # CPU smoke tests: avoid bf16 emulation
        )


# input shapes assigned to the LM family (seq_len, global_batch)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def shape_supported(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, with the skip reason if not."""
    info = SHAPES[shape]
    if info["kind"] == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return True, ""
