"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) ff=5120 vocab=504.

Encoder-only transformer (same arch as wav2vec2) [arXiv:2106.07447].
The conv waveform frontend is a STUB: inputs are precomputed frame
embeddings; training is masked-prediction CE over the 504-unit codebook.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    causal=False, rope=False, qkv_bias=True,
    norm="layernorm", activation="gelu",
    embedding_inputs=True,
)
