"""rwkv6-3b [ssm]: 32L d=2560 (attention-free) ff=8960 vocab=65536.

RWKV-6 "Finch" [arXiv:2404.05892]: data-dependent decay WKV recurrence,
token-shift ddlerp, 40 heads x 64. Sub-quadratic: runs the 500k decode cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab_size=65536,
    rwkv=True, rwkv_head_dim=64, rwkv_lora_dim=64,
    rope=False,
)
