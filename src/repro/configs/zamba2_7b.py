"""zamba2-7b [hybrid]: 81L d=3584 32H (kv=32) ff=14336 vocab=32000, ssm_state=64.

Mamba2 backbone with a shared attention+MLP block applied periodically
[arXiv:2411.15242]. The shared block reuses one parameter set (Zamba's
signature memory saving).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6,
)
