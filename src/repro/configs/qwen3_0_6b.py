"""qwen3-0.6b [dense]: 28L d=1024 16H (GQA kv=8) ff=3072 vocab=151936.

[hf:Qwen/Qwen3-0.6B]: qk-norm, GQA, explicit head_dim=128, no QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
)
