"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, SHAPES, shape_supported

_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "chameleon-34b": "chameleon_34b",
    "zamba2-7b": "zamba2_7b",
    "smollm-360m": "smollm_360m",
    "starcoder2-15b": "starcoder2_15b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2.5-32b": "qwen2_5_32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get(a) for a in ARCH_IDS}
