"""Int8 gradient compression with error feedback (distributed-optimization trick).

At 1000+ node scale the gradient all-reduce is the dominant inter-pod
collective; 4x compression (f32 -> int8 + per-tensor scale) cuts it
proportionally. Error feedback accumulates the quantization residual into the
next step's gradient so convergence is preserved (1-bit-Adam lineage).

``compress``/``decompress`` are the wire format; ``compressed_gradients``
wraps a gradient pytree: quantize -> (all-reduce happens on the int8 wire
format at the mesh boundary) -> dequantize + residual update. On this
container the collective itself is GSPMD's; the numerics path is exercised
end-to-end and tested for bounded error + exactness-in-expectation.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """f32 tensor -> (int8 payload, f32 scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_gradients(grads, error_state: Optional[Any]):
    """Quantize a gradient pytree with error feedback.

    Returns (dequantized grads, new error_state). Non-float leaves pass
    through untouched."""
    def is_float(x):
        return x is not None and hasattr(x, "dtype") and \
            jnp.issubdtype(x.dtype, jnp.floating)

    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32) if is_float(g) else None,
            grads, is_leaf=lambda x: x is None)

    def leaf(g, e):
        if not is_float(g):
            return g, None
        corrected = g.astype(jnp.float32) + e
        q, scale = compress(corrected)
        deq = decompress(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads, is_leaf=lambda x: x is None)
    flat_e = tdef.flatten_up_to(error_state)
    out = [leaf(g, e if e is not None else 0.0)
           for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in out])
    new_e = tdef.unflatten([o[1] for o in out])
    return new_g, new_e
