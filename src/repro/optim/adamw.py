"""AdamW with cosine schedule, global-norm clipping, and integer-leaf masking.

Built from scratch (no optax dependency). Integer leaves (e.g. the MoE
``inv_perm`` placement map) are carried through untouched — they are runtime
metadata, not trainable parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def _trainable(leaf) -> bool:
    return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32) if _trainable(p)
        else None, params)
    return {"mu": zeros, "nu": jax.tree.map(
        lambda z: None if z is None else jnp.zeros_like(z), zeros,
        is_leaf=lambda x: x is None),
        "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree) if l is not None
              and jnp.issubdtype(l.dtype, jnp.inexact)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.float32(0.0)


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, mu, nu):
        if mu is None or g is None:
            return p, mu, nu
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu2, nu2

    flat_p, tdef = jax.tree.flatten(params)
    # flatten_up_to keeps None grad leaves aligned with their params
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                 "nu": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
