"""Version-tolerant aliases for jax APIs that moved between releases.

The codebase targets the current jax surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``); older jax (< 0.5) spells these
``jax.experimental.shard_map.shard_map(check_rep=...)``, ``with mesh:`` and
has no axis types. Routing every use through this module keeps the rest of
the code on the modern spelling.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for the block."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh            # Mesh is itself a context manager on older jax


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict (older jax returns a
    one-element list of dicts, one per executable)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (renamed from ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
