"""Distributed BGP query engine over a feature-partitioned triple store.

Execution model mirrors the paper's federated SPARQL (Sec. IV): a query runs
at its Primary Processing Node (PPN) — the shard holding the most of the
query's features — and every triple pattern whose matches live on other
shards is a SERVICE call: its bindings are shipped to the PPN (a
*distributed join*). We execute the joins for real (numpy) and account
network cost with an explicit model (message latency + bytes/bandwidth),
since this container has no actual cluster fabric; raw counters
(distributed joins, bytes, messages) are always reported alongside.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import FeatureSpace
from repro.core.migration import TRIPLE_BYTES
from repro.core.partition import PartitionState
from repro.graph.triples import TripleStore
from repro.query.pattern import Query, is_var


@dataclasses.dataclass
class NetworkModel:
    """Deterministic cluster cost model.

    Queries execute for real (numpy joins — results are exact), but their
    *time* is modeled, because this container has no cluster fabric and
    wall-clock numpy noise would swamp the federation costs the paper's
    technique optimizes. The model matches the paper's deployment shape:
    per-shard scans run in parallel (max, not sum), SERVICE calls pay a
    round-trip latency, and shipped bindings pay serialization+wire time
    (federated SPARQL over HTTP is slow — effective ~20 MB/s)."""
    latency_s: float = 0.050          # SERVICE round trip incl. query setup
    bandwidth_Bps: float = 20e6       # effective federated-result throughput
    scan_rows_per_s: float = 5e6      # Virtuoso-ish index scan rate
    join_rows_per_s: float = 5e6      # hash-join probe rate at the PPN
    row_bytes: float = 60.0           # serialized SPARQL result row (HTTP/XML)

    def time(self, messages: int, rows_shipped: int) -> float:
        return (messages * self.latency_s
                + rows_shipped * self.row_bytes / self.bandwidth_Bps)


@dataclasses.dataclass
class ExecStats:
    scan_rows_critical: int = 0        # sum over patterns of max-shard rows
    join_rows: int = 0                 # rows flowing through PPN joins
    distributed_joins: int = 0
    rows_shipped: int = 0              # binding rows crossing shards
    bytes_shipped: int = 0             # raw dictionary-encoded payload
    messages: int = 0
    rows: int = 0
    wall_s: float = 0.0                # actual numpy execution time (info)

    def modeled_time(self, net: NetworkModel | None = None) -> float:
        net = net or NetworkModel()
        return (self.scan_rows_critical / net.scan_rows_per_s
                + self.join_rows / net.join_rows_per_s
                + net.time(self.messages, self.rows_shipped))


class ShardedStore:
    """Per-shard TripleStores materialized from a PartitionState."""

    def __init__(self, store: TripleStore, space: FeatureSpace,
                 state: PartitionState, owners: np.ndarray | None = None):
        self.space = space
        self.state = state
        owners = space.triple_owners() if owners is None else owners
        shard_of_triple = state.triple_shards(owners)
        self.shards: List[TripleStore] = []
        for s in range(state.n_shards):
            sel = shard_of_triple == s
            self.shards.append(TripleStore(store.triples[sel],
                                           store.dictionary))

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_sizes(self) -> List[int]:
        return [sh.n_triples for sh in self.shards]


def _primary_shard(q: Query, space: FeatureSpace,
                   state: PartitionState) -> int:
    """PPN selection: shard holding the highest number of the query's
    features, weighted by feature size (Sec. IV)."""
    feats = space.query_features(q)
    votes = np.zeros(state.n_shards)
    for f in feats.tolist():
        votes[state.feature_to_shard[f]] += 1 + np.log1p(
            state.feature_sizes[f])
    return int(np.argmax(votes))


def _match_pattern(shard: TripleStore, pat: Tuple[int, int, int]) -> np.ndarray:
    s, p, o = pat
    return shard.match(None if is_var(s) else s,
                       None if is_var(p) else p,
                       None if is_var(o) else o)


def _estimated_count(shards: Sequence[TripleStore], pat) -> int:
    s, p, o = pat
    return sum(sh.count(None if is_var(s) else s,
                        None if is_var(p) else p,
                        None if is_var(o) else o) for sh in shards)


def _join(table: Optional[Dict[int, np.ndarray]], pat, rows: np.ndarray,
          ) -> Optional[Dict[int, np.ndarray]]:
    """Hash-join current binding table with matched triples on shared vars."""
    cols: Dict[int, np.ndarray] = {}
    for slot_idx, slot in enumerate(pat):
        if is_var(slot):
            cols[slot] = rows[:, slot_idx].astype(np.int64)
    # intra-pattern repeated variable (e.g. (?x, p, ?x)) — filter
    seen: Dict[int, int] = {}
    keep = np.ones(rows.shape[0], bool)
    for slot_idx, slot in enumerate(pat):
        if is_var(slot):
            if slot in seen:
                keep &= rows[:, seen[slot]] == rows[:, slot_idx]
            else:
                seen[slot] = slot_idx
    if not keep.all():
        cols = {v: c[keep] for v, c in cols.items()}
    if table is None:
        return cols
    shared = [v for v in cols if v in table]
    if not shared:   # cartesian product — cap to keep memory sane
        nl, nr = len(next(iter(table.values()))), len(next(iter(cols.values())))
        li = np.repeat(np.arange(nl), nr)
        ri = np.tile(np.arange(nr), nl)
    else:
        def keyify(colmap, names):
            ks = np.stack([colmap[v] for v in names], axis=1)
            # pack up to 2 int32-ish ids into one int64 key
            key = ks[:, 0]
            for c in range(1, ks.shape[1]):
                key = key * np.int64(1 << 31) + ks[:, c]
            return key
        lk = keyify(table, shared)
        rk = keyify(cols, shared)
        order = np.argsort(rk, kind="stable")
        rk_sorted = rk[order]
        lo = np.searchsorted(rk_sorted, lk, side="left")
        hi = np.searchsorted(rk_sorted, lk, side="right")
        counts = hi - lo
        li = np.repeat(np.arange(len(lk)), counts)
        # expand right indices per left row
        ri_parts = [order[l:h] for l, h in zip(lo, hi) if h > l]
        ri = (np.concatenate(ri_parts) if ri_parts
              else np.empty(0, dtype=np.int64))
    out: Dict[int, np.ndarray] = {v: c[li] for v, c in table.items()}
    for v, c in cols.items():
        if v not in out:
            out[v] = c[ri]
    return out


def _join_order(patterns: Sequence[Tuple[int, int, int]],
                counts: Dict[Tuple[int, int, int], int],
                ) -> List[Tuple[int, int, int]]:
    """Greedy join order: most selective first, staying connected."""
    remaining = list(patterns)
    bound_vars: set = set()
    order: List[Tuple[int, int, int]] = []
    while remaining:
        connected = [p for p in remaining
                     if any(is_var(s) and s in bound_vars for s in p)]
        pool = connected if connected and bound_vars else remaining
        pick = min(pool, key=lambda p: counts[p])
        order.append(pick)
        remaining.remove(pick)
        bound_vars.update(s for s in pick if is_var(s))
    return order


def execute(q: Query, sharded: ShardedStore,
            net: NetworkModel | None = None) -> Tuple[Dict[int, np.ndarray], ExecStats]:
    """Run a BGP; returns bindings {var: column} + execution statistics."""
    stats = ExecStats()
    ppn = _primary_shard(q, sharded.space, sharded.state)
    t0 = time.perf_counter()

    counts = {pat: _estimated_count(sharded.shards, pat)
              for pat in q.patterns}
    order = _join_order(q.patterns, counts)

    table: Optional[Dict[int, np.ndarray]] = None
    for pat in order:
        per_shard = [_match_pattern(sh, pat) for sh in sharded.shards]
        rows = (np.concatenate(per_shard, axis=0)
                if any(len(m) for m in per_shard)
                else np.empty((0, 3), np.int32))
        # shards scan their slices in parallel: pay the slowest
        stats.scan_rows_critical += max(
            (len(m) for m in per_shard), default=0)
        # federation accounting: matches living off-PPN are SERVICE-shipped
        for s_idx, m in enumerate(per_shard):
            if s_idx != ppn and len(m) > 0:
                stats.messages += 1
                stats.rows_shipped += len(m)
                stats.bytes_shipped += m.nbytes
                if len(q.patterns) > 1:
                    stats.distributed_joins += 1
        before = len(next(iter(table.values()))) if table else 0
        table = _join(table, pat, rows)
        after = len(next(iter(table.values()))) if table else 0
        stats.join_rows += before + len(rows) + after
        if table is not None and len(next(iter(table.values()), ())) == 0:
            break

    stats.wall_s = time.perf_counter() - t0
    stats.rows = len(next(iter(table.values()))) if table else 0
    return table or {}, stats


def run_workload(queries: Sequence[Query], sharded: ShardedStore,
                 net: NetworkModel | None = None,
                 ) -> Tuple[Dict[str, float], Dict[str, ExecStats]]:
    """Frequency-weighted execution of a workload; returns per-query modeled
    times (seconds) and stats. Frequencies scale a query's contribution to
    the *average* (the paper's T = sum_i T_Qi / f per query, averaged)."""
    net = net or NetworkModel()
    times: Dict[str, float] = {}
    all_stats: Dict[str, ExecStats] = {}
    for q in queries:
        _, st = execute(q, sharded, net)
        times[q.name] = st.modeled_time(net)
        all_stats[q.name] = st
    return times, all_stats


def workload_average_time(queries: Sequence[Query], sharded: ShardedStore,
                          net: NetworkModel | None = None) -> float:
    """Fig.-5 average: frequency-weighted mean runtime over the workload."""
    times, _ = run_workload(queries, sharded, net)
    freqs = np.array([q.frequency for q in queries])
    vals = np.array([times[q.name] for q in queries])
    return float((vals * freqs).sum() / freqs.sum())


# --------------------------------------------------------------------------- #
# layout-invariant query profiles (candidate evaluation without re-execution)
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class QueryProfile:
    """Everything about a query's execution that does NOT depend on the
    partition layout: the join order, each executed pattern's matched global
    row ids, the join-pipeline row counts, and the result cardinality.

    Join results are a property of the *global* triple set — shards only
    change where matches live, i.e. the federation accounting. A profile is
    computed once per query (one real execution worth of work against the
    global store) and then prices any candidate ``PartitionState`` with pure
    bincount arithmetic via :func:`stats_from_profile`."""
    pattern_rows: List[np.ndarray]     # global row ids per executed pattern
    join_rows: int
    rows: int
    n_patterns: int                    # len(q.patterns), for dj accounting


def profile_query(q: Query, store: TripleStore) -> QueryProfile:
    """One real execution against the global store, recording row ids."""
    counts = {pat: store.count(None if is_var(pat[0]) else pat[0],
                               None if is_var(pat[1]) else pat[1],
                               None if is_var(pat[2]) else pat[2])
              for pat in q.patterns}
    order = _join_order(q.patterns, counts)

    prof = QueryProfile(pattern_rows=[], join_rows=0, rows=0,
                        n_patterns=len(q.patterns))
    table: Optional[Dict[int, np.ndarray]] = None
    for pat in order:
        s, p, o = pat
        idx = store.match_indices(None if is_var(s) else s,
                                  None if is_var(p) else p,
                                  None if is_var(o) else o)
        prof.pattern_rows.append(np.asarray(idx, dtype=np.int64))
        rows = store.triples[idx]
        before = len(next(iter(table.values()))) if table else 0
        table = _join(table, pat, rows)
        after = len(next(iter(table.values()))) if table else 0
        prof.join_rows += before + len(rows) + after
        if table is not None and len(next(iter(table.values()), ())) == 0:
            break
    prof.rows = len(next(iter(table.values()))) if table else 0
    return prof


def stats_from_profile(q: Query, prof: QueryProfile, space: FeatureSpace,
                       state: PartitionState,
                       triple_shard: np.ndarray) -> ExecStats:
    """Re-account a profiled query under a candidate layout.

    Reproduces ``execute``'s federation statistics exactly — same PPN rule,
    same per-shard scan/shipping arithmetic — without re-running any joins.
    ``triple_shard`` maps every global triple row to its candidate shard."""
    stats = ExecStats(join_rows=prof.join_rows, rows=prof.rows)
    ppn = _primary_shard(q, space, state)
    multi = prof.n_patterns > 1
    for idx in prof.pattern_rows:
        per_shard = np.bincount(triple_shard[idx], minlength=state.n_shards)
        stats.scan_rows_critical += int(per_shard.max()) if len(idx) else 0
        off = per_shard.copy()
        off[ppn] = 0
        nz = int((off > 0).sum())
        shipped = int(off.sum())
        stats.messages += nz
        stats.rows_shipped += shipped
        stats.bytes_shipped += shipped * TRIPLE_BYTES
        if multi:
            stats.distributed_joins += nz
    return stats
