"""Compatibility layer over the planner/executor split.

The query engine now lives in two modules:

* ``repro.query.plan`` — the ``QueryPlan`` IR (``plan(q, stats_source)``),
  PPN selection, and layout-invariant ``QueryProfile`` pricing;
* ``repro.query.exec`` — the ``Executor`` protocol with the
  ``NumpyExecutor`` reference backend and the batched ``JaxExecutor``.

This module keeps :class:`ShardedStore` (per-shard views materialized from a
``PartitionState``) plus **deprecated** thin shims for the retired
free-function entry points (``execute`` / ``run_workload`` /
``workload_average_time`` / ``profile_query`` / ``stats_from_profile``).
The shims delegate to the new surface and warn; they will be removed after
one release. In-repo code must not call them (enforced by ``scripts/ci.sh``).
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState
from repro.graph.triples import TripleStore
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.pattern import Query

# canonical homes are repro.query.exec / repro.query.plan; re-exported here
# for backward compatibility
ExecStats = qexec.ExecStats
NetworkModel = qexec.NetworkModel
QueryProfile = qplan.QueryProfile
_primary_shard = qplan.primary_shard


class ShardedStore:
    """Per-shard TripleStores materialized from a PartitionState."""

    def __init__(self, store: TripleStore, space: FeatureSpace,
                 state: PartitionState, owners: np.ndarray | None = None):
        self.store = store
        self.space = space
        self.state = state
        owners = space.triple_owners() if owners is None else owners
        self.triple_shard = state.triple_shards(owners).astype(np.int32)
        self.shards: List[TripleStore] = []
        for s in range(state.n_shards):
            sel = self.triple_shard == s
            self.shards.append(TripleStore(store.triples[sel],
                                           store.dictionary))

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_sizes(self) -> List[int]:
        return [sh.n_triples for sh in self.shards]


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"repro.query.engine.{old} is deprecated; use {new} "
                  "(see docs/api.md, 'Plans and executors')",
                  DeprecationWarning, stacklevel=3)


def execute(q: Query, sharded, net: NetworkModel | None = None,
            ) -> Tuple[Dict[int, np.ndarray], ExecStats]:
    """Deprecated: plan once, then run an executor.

    ``net`` was accepted but never read; ``NetworkModel`` lives solely in
    ``ExecStats.modeled_time`` now."""
    _deprecated("execute", "plan.plan(q, kg) + exec.NumpyExecutor().run")
    return qexec.NumpyExecutor().run(qplan.plan(q, sharded), sharded)


def run_workload(queries: Sequence[Query], sharded,
                 net: NetworkModel | None = None,
                 ) -> Tuple[Dict[str, float], Dict[str, ExecStats]]:
    """Deprecated: use ``exec.run_workload`` (or ``KGService.query_batch``)."""
    _deprecated("run_workload", "exec.run_workload / KGService.query_batch")
    return qexec.run_workload(queries, sharded, net=net)


def workload_average_time(queries: Sequence[Query], sharded,
                          net: NetworkModel | None = None) -> float:
    """Deprecated: use ``exec.workload_average_time``."""
    _deprecated("workload_average_time", "exec.workload_average_time")
    return qexec.workload_average_time(queries, sharded, net=net)


def profile_query(q: Query, store: TripleStore) -> QueryProfile:
    """Deprecated: profiles are derived from plans now."""
    _deprecated("profile_query", "exec.profile_from_plan(plan.plan(q, store))")
    return qexec.profile_from_plan(qplan.plan(q, store), store)


def stats_from_profile(q: Query, prof: QueryProfile, space: FeatureSpace,
                       state: PartitionState,
                       triple_shard: np.ndarray) -> ExecStats:
    """Deprecated: use ``plan.stats_from_profile``."""
    _deprecated("stats_from_profile", "plan.stats_from_profile")
    return qplan.stats_from_profile(q, prof, space, state, triple_shard)
