"""Query planner — the ``QueryPlan`` IR shared by every executor.

AWAPart's adaptation loop reasons about *plans*, not executions: the same
ordered sequence of scan/join operators is (a) executed for real by a
pluggable backend (``repro.query.exec``), (b) profiled once against the
global store into a layout-invariant ``QueryProfile``, and (c) re-priced
under candidate layouts with pure bincount arithmetic — without re-deriving
join order, selectivities or the PPN each time (the duplication the old
``engine.execute`` / ``engine.profile_query`` pair carried).

``plan(q, stats_source)`` is the single entry point. ``stats_source`` is
anything holding the triples the query will run over:

* a bare :class:`~repro.graph.triples.TripleStore` (no partition metadata —
  single-node plan, ``ppn = 0``),
* an ``engine.ShardedStore`` or :class:`~repro.api.facade.PartitionedKG`
  (federated plan: PPN choice + per-pattern home-shard annotations).

``PartitionedKG`` caches one plan per ``(query, store)`` and invalidates the
cache when the layout changes (``commit`` / ``sync_universe``) — and when
the *graph* changes: a live write (``repro.write.apply_batch``) bumps the
facade epoch too, since plan selectivities, the PPN vote, and home-shard
annotations were all derived from pre-write matches. Every cached plan
carries the epoch it was built at and asserts on a stale hit. So a whole
adaptation round builds each query's plan exactly once, and no plan ever
outlives the graph it described.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.migration import TRIPLE_BYTES
from repro.query.pattern import Pattern, Query, is_var


def primary_shard(q: Query, space, state, replicas=None) -> int:
    """PPN selection: shard holding the highest number of the query's
    features, weighted by feature size (Sec. IV). With a
    ``repro.replicate.ReplicaMap``, every shard holding a *copy* of a
    feature collects that feature's vote — the PPN prefers the shard with
    the most local copies of the plan's features (a primary-only map votes
    identically to the replica-free rule)."""
    feats = space.query_features(q)
    votes = np.zeros(state.n_shards)
    for f in feats.tolist():
        w = 1 + np.log1p(state.feature_sizes[f])
        if replicas is None:
            votes[state.feature_to_shard[f]] += w
        else:
            votes[replicas.holders(f)] += w
    return int(np.argmax(votes))


def pattern_home(pat: Pattern, space, state, replicas=None,
                 ppn: int | None = None) -> int:
    """Shard homing a pattern's feature (PO if tracked, else P); -1 means an
    unbound predicate (broadcast to every shard). When the feature is
    replicated onto the query's PPN, the PPN serves it locally — the home
    IS the PPN (no SERVICE call)."""
    s, p, o = pat
    if is_var(p):
        return -1
    f = None
    if not is_var(o):
        f = space.po_index(p, o)
    if f is None:
        f = space.p_index(p)
    if replicas is not None and ppn is not None and replicas.has(f, ppn):
        return int(ppn)
    return int(state.feature_to_shard[f])


# --------------------------------------------------------------------------- #
# the IR
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class PlanOp:
    """One scan+join step: match ``pattern`` on every shard, hash-join the
    result into the binding table on ``join_vars``."""
    pattern: Pattern
    est_rows: int                  # global match count (selectivity estimate)
    selectivity: float             # est_rows / total triples
    join_vars: Tuple[int, ...]     # vars shared with the table built so far
    new_vars: Tuple[int, ...]      # vars first bound by this op
    cartesian: bool                # no shared vars: cross product (capped)
    home: int                      # federation annotation: feature-home shard
    service: bool                  # True when home is off-PPN (SERVICE call)


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Ordered scan/join ops + PPN choice + federation annotations for one
    BGP over one store. Executors consume this IR; they never re-derive it."""
    query: Query
    ops: Tuple[PlanOp, ...]
    ppn: int
    n_shards: int
    total_triples: int

    @property
    def n_patterns(self) -> int:
        return len(self.query.patterns)

    def explain(self) -> str:
        """Human-readable plan, EXPLAIN-style."""
        lines = [f"QueryPlan {self.query.name}: {len(self.ops)} ops, "
                 f"ppn=shard{self.ppn}/{self.n_shards}"]
        for i, op in enumerate(self.ops):
            kind = ("scan" if i == 0
                    else "cartesian" if op.cartesian
                    else f"hash-join on {list(op.join_vars)}")
            where = ("broadcast" if op.home < 0
                     else "local" if not op.service
                     else f"SERVICE shard{op.home}")
            lines.append(f"  [{i}] {op.pattern} {kind} "
                         f"~{op.est_rows} rows "
                         f"(sel={op.selectivity:.2e}) {where}")
        return "\n".join(lines)


def _resolve_source(stats_source) -> Tuple[object, object, object, object]:
    """(store, space, state, replicas) from any supported stats source."""
    store = getattr(stats_source, "store", stats_source)
    space = getattr(stats_source, "space", None)
    state = getattr(stats_source, "state", None)
    replicas = getattr(stats_source, "replicas", None)
    return store, space, state, replicas


def _join_order(patterns: Sequence[Pattern],
                counts: Dict[Pattern, int]) -> List[Pattern]:
    """Greedy join order: most selective first, staying connected."""
    remaining = list(patterns)
    bound_vars: set = set()
    order: List[Pattern] = []
    while remaining:
        connected = [p for p in remaining
                     if any(is_var(s) and s in bound_vars for s in p)]
        pool = connected if connected and bound_vars else remaining
        pick = min(pool, key=lambda p: counts[p])
        order.append(pick)
        remaining.remove(pick)
        bound_vars.update(s for s in pick if is_var(s))
    return order


def plan(q: Query, stats_source) -> QueryPlan:
    """Build the execution plan for ``q`` against ``stats_source``."""
    store, space, state, replicas = _resolve_source(stats_source)
    counts = {pat: store.count(None if is_var(pat[0]) else pat[0],
                               None if is_var(pat[1]) else pat[1],
                               None if is_var(pat[2]) else pat[2])
              for pat in q.patterns}
    order = _join_order(q.patterns, counts)
    federated = space is not None and state is not None
    ppn = primary_shard(q, space, state, replicas) if federated else 0
    n_shards = state.n_shards if federated else 1
    total = max(store.n_triples, 1)

    ops: List[PlanOp] = []
    bound: set = set()
    for i, pat in enumerate(order):
        pat_vars = [s for s in pat if is_var(s)]
        join_vars = tuple(dict.fromkeys(v for v in pat_vars if v in bound))
        new_vars = tuple(dict.fromkeys(v for v in pat_vars if v not in bound))
        home = (pattern_home(pat, space, state, replicas, ppn)
                if federated else 0)
        ops.append(PlanOp(pattern=pat, est_rows=counts[pat],
                          selectivity=counts[pat] / total,
                          join_vars=join_vars, new_vars=new_vars,
                          cartesian=i > 0 and not join_vars,
                          home=home,
                          service=federated and home not in (ppn, -1)))
        bound.update(pat_vars)
    return QueryPlan(query=q, ops=tuple(ops), ppn=ppn, n_shards=n_shards,
                     total_triples=store.n_triples)


# --------------------------------------------------------------------------- #
# layout-invariant profiles — a derived artifact of the plan
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class QueryProfile:
    """Everything about a plan's execution that does NOT depend on the
    partition layout: each executed op's matched global row ids, the
    join-pipeline row counts, and the result cardinality.

    Join results are a property of the *global* triple set — shards only
    change where matches live, i.e. the federation accounting. A profile is
    derived once per plan (one real execution worth of work against the
    global store, see ``exec.profile_from_plan``) and then prices any
    candidate ``PartitionState`` with pure bincount arithmetic via
    :func:`stats_from_profile`.

    Layout-invariant is not *write*-invariant: ``pattern_rows`` holds
    global row ids, which a ``repro.write`` mutation remaps (deletes
    compact the store, inserts append). The facade therefore tags cached
    profiles with its ``data_version`` and drops them on every effective
    write — a profile never prices a graph other than the one it was
    profiled on."""
    pattern_rows: List[np.ndarray]     # global row ids per executed op
    join_rows: int
    rows: int
    n_patterns: int                    # len(q.patterns), for dj accounting
    cartesian_rows: int = 0            # cross-product rows materialized
    expanded_rows: int = 0             # ragged hash-join pairs materialized


def stats_from_profile(q: Query, prof: QueryProfile, space, state,
                       triple_shard: np.ndarray, replicas=None,
                       owners: np.ndarray | None = None):
    """Re-account a profiled query under a candidate layout.

    Reproduces the executors' federation statistics exactly — same PPN rule,
    same per-shard scan/shipping arithmetic — without re-running any joins.
    ``triple_shard`` maps every global triple row to its candidate
    (primary) shard. With a ``repro.replicate.ReplicaMap`` (and the
    per-triple ``owners`` features), shipping is charged against the
    *nearest replica*: matches whose owner feature holds a copy on the PPN
    are scanned there — local, nothing shipped — and only copy-less
    matches ship from their primary."""
    from repro.query.exec import ExecStats
    stats = ExecStats(join_rows=prof.join_rows, rows=prof.rows,
                      cartesian_rows=prof.cartesian_rows,
                      expanded_rows=prof.expanded_rows)
    ppn = primary_shard(q, space, state, replicas)
    on_ppn = (replicas.on_shard(ppn)
              if replicas is not None and owners is not None
              and replicas.has_replicas else None)
    multi = prof.n_patterns > 1
    for idx in prof.pattern_rows:
        shard_ids = triple_shard[idx]
        if on_ppn is not None and len(idx):
            shard_ids = np.where(on_ppn[owners[idx]], np.int32(ppn),
                                 shard_ids)
        per_shard = np.bincount(shard_ids, minlength=state.n_shards)
        stats.scan_rows_critical += int(per_shard.max()) if len(idx) else 0
        off = per_shard.copy()
        off[ppn] = 0
        nz = int((off > 0).sum())
        shipped = int(off.sum())
        stats.messages += nz
        stats.rows_shipped += shipped
        stats.bytes_shipped += shipped * TRIPLE_BYTES
        if multi:
            stats.distributed_joins += nz
    return stats
