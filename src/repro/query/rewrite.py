"""Query → federated query rewriting (Table I / QRP in Fig. 6).

Produces the SERVICE-decorated form of a BGP against the current partition
metadata: patterns whose features are homed on the PPN stay plain; patterns
homed elsewhere become ``SERVICE <endpoint_k> { ... }`` clauses. The engine
executes the same plan natively; this module renders it (for logs, docs and
the examples) exactly as the paper's Query Rewriter would emit it.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState
from repro.graph.triples import Dictionary
from repro.query.pattern import Query, is_var


def _term(slot: int, d: Dictionary | None) -> str:
    if is_var(slot):
        return f"?v{-slot - 1}"
    if d is not None:
        try:
            return d.decode(slot)
        except IndexError:
            pass
    return f"<e{slot}>"


def pattern_home(pat: Tuple[int, int, int], space: FeatureSpace,
                 state: PartitionState) -> int:
    """Shard homing a pattern's feature (PO if tracked, else P)."""
    s, p, o = pat
    if is_var(p):
        return -1        # unbound predicate: broadcast
    if not is_var(o):
        po = space.po_index(p, o)
        if po is not None:
            return int(state.feature_to_shard[po])
    return int(state.feature_to_shard[space.p_index(p)])


def federated_sparql(q: Query, space: FeatureSpace, state: PartitionState,
                     dictionary: Dictionary | None = None,
                     endpoints: List[str] | None = None) -> str:
    """Render the federated form of ``q`` under the current PMeta."""
    from repro.query.engine import _primary_shard
    ppn = _primary_shard(q, space, state)
    eps = endpoints or [f"http://node{i}/sparql" for i in range(state.n_shards)]
    head = " ".join(f"?v{-v - 1}" for v in q.variables())
    lines = [f"SELECT {head} WHERE {{"]
    for pat in q.patterns:
        home = pattern_home(pat, space, state)
        triple = " ".join(_term(t, dictionary) for t in pat) + " ."
        if home in (ppn, -1):
            lines.append(f"  {triple}")
        else:
            lines.append(f"  SERVICE <{eps[home]}> {{ {triple} }}")
    lines.append("}")
    return "\n".join(lines)


def service_counts(q: Query, space: FeatureSpace,
                   state: PartitionState) -> Dict[str, int]:
    """How many patterns run locally at the PPN vs. via SERVICE calls."""
    from repro.query.engine import _primary_shard
    ppn = _primary_shard(q, space, state)
    local = remote = 0
    for pat in q.patterns:
        home = pattern_home(pat, space, state)
        if home in (ppn, -1):
            local += 1
        else:
            remote += 1
    return {"local": local, "service": remote, "ppn": ppn}
