"""Query → federated query rewriting (Table I / QRP in Fig. 6).

Produces the SERVICE-decorated form of a BGP against the current partition
metadata: patterns whose features are homed on the PPN stay plain; patterns
homed elsewhere become ``SERVICE <endpoint_k> { ... }`` clauses. The engine
executes the same plan natively; this module renders it (for logs, docs and
the examples) exactly as the paper's Query Rewriter would emit it.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState
from repro.graph.triples import Dictionary
from repro.query.pattern import Query, is_var
from repro.query.plan import pattern_home, primary_shard


def _term(slot: int, d: Dictionary | None) -> str:
    if is_var(slot):
        return f"?v{-slot - 1}"
    if d is not None:
        try:
            return d.decode(slot)
        except IndexError:
            pass
    return f"<e{slot}>"


def federated_sparql(q: Query, space: FeatureSpace, state: PartitionState,
                     dictionary: Dictionary | None = None,
                     endpoints: List[str] | None = None,
                     replicas=None) -> str:
    """Render the federated form of ``q`` under the current PMeta. Pass the
    layout's ``ReplicaMap`` (e.g. ``kg.replicas``) so the rendering matches
    the replica-aware plan the engine executes: the PPN vote counts local
    copies, and a pattern replicated onto the PPN stays plain (no SERVICE
    clause)."""
    ppn = primary_shard(q, space, state, replicas)
    eps = endpoints or [f"http://node{i}/sparql" for i in range(state.n_shards)]
    head = " ".join(f"?v{-v - 1}" for v in q.variables())
    lines = [f"SELECT {head} WHERE {{"]
    for pat in q.patterns:
        home = pattern_home(pat, space, state, replicas, ppn)
        triple = " ".join(_term(t, dictionary) for t in pat) + " ."
        if home in (ppn, -1):
            lines.append(f"  {triple}")
        else:
            lines.append(f"  SERVICE <{eps[home]}> {{ {triple} }}")
    lines.append("}")
    return "\n".join(lines)


def service_counts(q: Query, space: FeatureSpace, state: PartitionState,
                   replicas=None) -> Dict[str, int]:
    """How many patterns run locally at the PPN vs. via SERVICE calls."""
    ppn = primary_shard(q, space, state, replicas)
    local = remote = 0
    for pat in q.patterns:
        home = pattern_home(pat, space, state, replicas, ppn)
        if home in (ppn, -1):
            local += 1
        else:
            remote += 1
    return {"local": local, "service": remote, "ppn": ppn}
