"""Triple patterns and basic graph pattern (BGP) queries.

A pattern is ``(s, p, o)`` where each slot is either a non-negative dictionary
id (constant) or a negative int (variable). A ``Query`` is a conjunctive BGP —
the SPARQL subset AWAPart's QueryAnalyzer handles (SELECT over a BGP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

Pattern = Tuple[int, int, int]

# Variable slots are negative. var(0) == -1, var(1) == -2, ...
def var(i: int) -> int:
    return -(i + 1)


def is_var(slot: int) -> bool:
    return slot < 0


@dataclasses.dataclass(frozen=True)
class Query:
    name: str
    patterns: Tuple[Pattern, ...]
    frequency: float = 1.0
    # query shape tag used by the paper's Exp-1 workload (linear/star/snowflake/complex)
    shape: str = ""

    def variables(self) -> List[int]:
        out = []
        for pat in self.patterns:
            for slot in pat:
                if is_var(slot) and slot not in out:
                    out.append(slot)
        return out

    def with_frequency(self, f: float) -> "Query":
        return dataclasses.replace(self, frequency=f)


def join_structure(q: Query) -> List[Tuple[int, int, str]]:
    """Enumerate join-type edges between pattern pairs.

    Returns (i, j, kind) with kind in {SSJ, OOJ, OSJ} following the paper's
    definitions: SSJ = shared subject, OOJ = shared object, OSJ = object of
    one is subject of the other (the "elbow" join).
    """
    edges: List[Tuple[int, int, str]] = []
    pats = q.patterns
    for i in range(len(pats)):
        for j in range(i + 1, len(pats)):
            si, _, oi = pats[i]
            sj, _, oj = pats[j]
            if is_var(si) and si == sj:
                edges.append((i, j, "SSJ"))
            if is_var(oi) and oi == oj:
                edges.append((i, j, "OOJ"))
            if is_var(oi) and oi == sj:
                edges.append((i, j, "OSJ"))
            if is_var(oj) and oj == si:
                edges.append((j, i, "OSJ"))
    return edges
