"""Pluggable query executors over the ``QueryPlan`` IR.

Two backends behind one ``Executor`` protocol:

* :class:`NumpyExecutor` — the reference semantics: per-shard pattern
  matching, numpy hash joins, python-level federation accounting. Stats are
  byte-identical to the pre-split ``engine.execute``.
* :class:`JaxExecutor` — the batched backend: patterns are matched once
  against the global store (results deduplicated across the whole batch),
  the hash-join key packing / probe runs through ``repro.kernels.join``
  (``pallas=False``: the jitted-jnp oracle kernels; ``pallas=True`` — the
  ``executor="jax-pallas"`` knob — the Pallas sorted-probe kernel family,
  dispatched per ``repro.kernels.dispatch``: compiled on TPU,
  ``interpret=True`` when forced on CPU, jnp oracle fallback; see
  ``docs/kernels.md``), and the federation accounting for every distinct
  pattern in the window is ONE dispatched scatter-add (``bincount`` over
  ``triple_shard[match]`` segments) instead of a python loop per shard per
  query. Bindings and stats match the numpy backend exactly (modulo row
  order and the informational ``wall_s``).

Execution model mirrors the paper's federated SPARQL (Sec. IV): a query runs
at its Primary Processing Node (PPN) and every triple pattern whose matches
live on other shards is a SERVICE call whose bindings are shipped to the PPN.
Joins execute for real; *time* is modeled by :class:`NetworkModel` (this
container has no cluster fabric), which lives solely in
``ExecStats.modeled_time`` — executors never take a network argument.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

import numpy as np

from repro.core.migration import TRIPLE_BYTES
from repro.query import plan as qplan
from repro.query.pattern import Query, is_var

# Cross products ("cartesian" plan ops) materialize |left| x |right| rows;
# exceeding this cap raises JoinCapExceeded instead of exhausting memory.
DEFAULT_MAX_JOIN_ROWS = 50_000_000


class JoinCapExceeded(RuntimeError):
    """A join step — cartesian product or ragged hash-join expansion —
    would materialize more rows than the executor's ``max_join_rows``
    cap."""


@dataclasses.dataclass
class NetworkModel:
    """Deterministic cluster cost model.

    Queries execute for real (joins — results are exact), but their *time*
    is modeled, because this container has no cluster fabric and wall-clock
    noise would swamp the federation costs the paper's technique optimizes.
    The model matches the paper's deployment shape: per-shard scans run in
    parallel (max, not sum), SERVICE calls pay a round-trip latency, and
    shipped bindings pay serialization+wire time (federated SPARQL over HTTP
    is slow — effective ~20 MB/s)."""
    latency_s: float = 0.050          # SERVICE round trip incl. query setup
    bandwidth_Bps: float = 20e6       # effective federated-result throughput
    scan_rows_per_s: float = 5e6      # Virtuoso-ish index scan rate
    join_rows_per_s: float = 5e6      # hash-join probe rate at the PPN
    row_bytes: float = 60.0           # serialized SPARQL result row (HTTP/XML)
    plan_s: float = 0.002             # master-side cost per query plan built
    #   (the currency of repro.stream's pre-staging: a pipelined window hides
    #    plan builds behind the previous window's execution)

    def time(self, messages: int, rows_shipped: int) -> float:
        return (messages * self.latency_s
                + rows_shipped * self.row_bytes / self.bandwidth_Bps)


@dataclasses.dataclass
class ExecStats:
    scan_rows_critical: int = 0        # sum over patterns of max-shard rows
    join_rows: int = 0                 # rows flowing through PPN joins
    distributed_joins: int = 0
    rows_shipped: int = 0              # binding rows crossing shards
    bytes_shipped: int = 0             # rows_shipped * TRIPLE_BYTES
    messages: int = 0
    rows: int = 0
    cartesian_rows: int = 0            # cross-product rows materialized
    expanded_rows: int = 0             # ragged hash-join pairs materialized
    wall_s: float = 0.0                # actual local execution time (info)

    # every field that must agree between backends / profile re-accounting
    COMPARABLE = ("scan_rows_critical", "join_rows", "distributed_joins",
                  "rows_shipped", "bytes_shipped", "messages", "rows",
                  "cartesian_rows", "expanded_rows")

    def modeled_time(self, net: NetworkModel | None = None) -> float:
        net = net or NetworkModel()
        return (self.scan_rows_critical / net.scan_rows_per_s
                + self.join_rows / net.join_rows_per_s
                + net.time(self.messages, self.rows_shipped))


Bindings = Dict[int, np.ndarray]


@runtime_checkable
class Executor(Protocol):
    """Backend protocol: run one plan (or a whole workload window) against a
    sharded KG (``engine.ShardedStore`` or ``api.PartitionedKG``)."""

    name: str

    def run(self, plan: qplan.QueryPlan, kg) -> Tuple[Bindings, ExecStats]:
        ...

    def run_batch(self, plans: Sequence[qplan.QueryPlan], kg,
                  ) -> List[Tuple[Bindings, ExecStats]]:
        ...


# --------------------------------------------------------------------------- #
# shared join machinery (numpy reference semantics)
# --------------------------------------------------------------------------- #

def _pattern_cols(pat, rows: np.ndarray) -> Bindings:
    """Variable columns from matched triples, with intra-pattern repeated
    variables (e.g. ``(?x, p, ?x)``) filtered."""
    cols: Bindings = {}
    for slot_idx, slot in enumerate(pat):
        if is_var(slot):
            cols[slot] = rows[:, slot_idx].astype(np.int64)
    seen: Dict[int, int] = {}
    keep = np.ones(rows.shape[0], bool)
    for slot_idx, slot in enumerate(pat):
        if is_var(slot):
            if slot in seen:
                keep &= rows[:, seen[slot]] == rows[:, slot_idx]
            else:
                seen[slot] = slot_idx
    if not keep.all():
        cols = {v: c[keep] for v, c in cols.items()}
    return cols


def _cartesian_indices(nl: int, nr: int, stats: ExecStats,
                       max_rows: int) -> Tuple[np.ndarray, np.ndarray]:
    """Cross-product (left, right) index pairs, capped."""
    produced = nl * nr
    if produced > max_rows:
        raise JoinCapExceeded(
            f"cartesian join would materialize {produced} rows "
            f"({nl} x {nr}), above the {max_rows}-row cap; "
            "raise Executor(max_join_rows=...) or add a shared variable")
    stats.cartesian_rows += produced
    li = np.repeat(np.arange(nl), nr)
    ri = np.tile(np.arange(nr), nl)
    return li, ri


def _check_expansion(total: int, stats: ExecStats, max_rows: int) -> int:
    """Cap + account the data-dependent ragged hash-join expansion, exactly
    like the cartesian path: the check fires before any pair array is
    materialized."""
    if total > max_rows:
        raise JoinCapExceeded(
            f"hash-join expansion would materialize {total} rows, above "
            f"the {max_rows}-row cap; raise Executor(max_join_rows=...) "
            "or add a more selective pattern")
    stats.expanded_rows += total
    return total


def _key_columns(table: Bindings, cols: Bindings, shared: Sequence[int],
                 ) -> Tuple[List[np.ndarray], List[np.ndarray]]:
    """Shared-var key columns, reduced to at most two int64 columns.

    Two dictionary ids (< 2^31) pack exactly into one int64; with three or
    more shared variables the leading columns are first combined and
    dense-ranked over the union of both sides, so the packed key never
    overflows (a straight base-2^31 pack of three columns wraps int64 and
    hash-equates rows whose leading variable differs by a multiple of 4)."""
    lcs = [table[v] for v in shared]
    rcs = [cols[v] for v in shared]
    while len(lcs) > 2:
        lkey = lcs[0] * np.int64(1 << 31) + lcs[1]
        rkey = rcs[0] * np.int64(1 << 31) + rcs[1]
        _, inv = np.unique(np.concatenate([lkey, rkey]), return_inverse=True)
        lcs = [inv[:len(lkey)].astype(np.int64)] + lcs[2:]
        rcs = [inv[len(lkey):].astype(np.int64)] + rcs[2:]
    return lcs, rcs


def _join_numpy(table: Optional[Bindings], pat, rows: np.ndarray,
                stats: ExecStats, max_rows: int) -> Optional[Bindings]:
    """Hash-join current binding table with matched triples on shared vars.
    The key packing + searchsorted probe is ``join.ops.hash_probe_numpy``
    (one copy of the base-2^31 packing math repo-wide); the per-left-row
    run concatenation below is the readable reference expansion every
    backend's vectorized equivalent must reproduce."""
    from repro.kernels.join import ops as join_ops

    cols = _pattern_cols(pat, rows)
    if table is None:
        return cols
    shared = [v for v in cols if v in table]
    if not shared:
        nl = len(next(iter(table.values())))
        nr = len(next(iter(cols.values())))
        li, ri = _cartesian_indices(nl, nr, stats, max_rows)
    else:
        lcs, rcs = _key_columns(table, cols, shared)
        order, lo, counts = join_ops.hash_probe_numpy(lcs, rcs)
        total = _check_expansion(int(counts.sum()), stats, max_rows)
        li = np.repeat(np.arange(len(lo)), counts)
        ri_parts = [order[l:h] for l, h in zip(lo, lo + counts) if h > l]
        ri = (np.concatenate(ri_parts) if ri_parts
              else np.empty(0, dtype=np.int64))
        assert len(ri) == total
    out: Bindings = {v: c[li] for v, c in table.items()}
    for v, c in cols.items():
        if v not in out:
            out[v] = c[ri]
    return out


def _table_len(table: Optional[Bindings]) -> int:
    return len(next(iter(table.values()))) if table else 0


# --------------------------------------------------------------------------- #
# numpy backend — reference semantics
# --------------------------------------------------------------------------- #

def _has_replicated_layout(kg) -> bool:
    """Does ``kg`` carry a ReplicaMap with actual read copies? (ShardedStore
    and primary-only facades answer False — the replica-free fast paths
    stay byte-identical to the pre-replication executors.)"""
    replicas = getattr(kg, "replicas", None)
    return replicas is not None and replicas.has_replicas


class NumpyExecutor:
    """Per-shard matching + numpy joins; the reference backend."""

    name = "numpy"

    def __init__(self, max_join_rows: int = DEFAULT_MAX_JOIN_ROWS):
        self.max_join_rows = max_join_rows

    def run(self, plan: qplan.QueryPlan, kg) -> Tuple[Bindings, ExecStats]:
        stats = ExecStats()
        t0 = time.perf_counter()
        shards = kg.shards
        # replicated layout: shard views hold read copies, so every triple
        # is scanned exactly once at its *read* shard for this query — the
        # PPN when the owner feature has a local copy there, else the
        # primary. The match set (hence every binding) is unchanged; only
        # which shard serves each row — the federation accounting — moves.
        read = (kg.read_shard(plan.ppn) if _has_replicated_layout(kg)
                else None)
        multi = plan.n_patterns > 1
        table: Optional[Bindings] = None
        for op in plan.ops:
            s, p, o = op.pattern
            if read is None:
                per_shard = [sh.match(None if is_var(s) else s,
                                      None if is_var(p) else p,
                                      None if is_var(o) else o)
                             for sh in shards]
            else:
                per_shard = []
                for s_idx, sh in enumerate(shards):
                    vidx = sh.match_indices(None if is_var(s) else s,
                                            None if is_var(p) else p,
                                            None if is_var(o) else o)
                    keep = read[kg.shard_rows(s_idx)[vidx]] == s_idx
                    per_shard.append(sh.triples[vidx[keep]])
            rows = (np.concatenate(per_shard, axis=0)
                    if any(len(m) for m in per_shard)
                    else np.empty((0, 3), np.int32))
            # shards scan their slices in parallel: pay the slowest
            stats.scan_rows_critical += max(
                (len(m) for m in per_shard), default=0)
            # federation accounting: matches living off-PPN are shipped
            for s_idx, m in enumerate(per_shard):
                if s_idx != plan.ppn and len(m) > 0:
                    stats.messages += 1
                    stats.rows_shipped += len(m)
                    stats.bytes_shipped += len(m) * TRIPLE_BYTES
                    if multi:
                        stats.distributed_joins += 1
            before = _table_len(table)
            table = _join_numpy(table, op.pattern, rows, stats,
                                self.max_join_rows)
            stats.join_rows += before + len(rows) + _table_len(table)
            if table is not None and _table_len(table) == 0:
                break
        stats.wall_s = time.perf_counter() - t0
        stats.rows = _table_len(table)
        m = getattr(kg, "metrics", None)
        if m is not None:          # repro.obs: backend execution counters
            m.counter("executor.queries").inc()
            m.histogram("executor.wall_s").observe(stats.wall_s)
        return table or {}, stats

    def run_batch(self, plans: Sequence[qplan.QueryPlan], kg,
                  ) -> List[Tuple[Bindings, ExecStats]]:
        m = getattr(kg, "metrics", None)
        if m is not None:
            m.counter("executor.batches").inc()
        return [self.run(p, kg) for p in plans]


# --------------------------------------------------------------------------- #
# jax backend — batched execution
# --------------------------------------------------------------------------- #

# A probe spec names the backend tier of the fused join pipeline
# (``join.ops.hash_join_pipeline``): ("numpy", None) — pure host, no device
# round trip; ("oracle", None) — device-resident jitted-jnp stages
# (pow2-padded, enable_x64); ("pallas", force) — the Pallas word-pair
# kernel stages under the shared kernels.dispatch policy (force: None=auto,
# True/False pin a path).
ProbeSpec = Tuple[str, Optional[bool]]


def _join_jax(table: Optional[Bindings], pat, rows: np.ndarray,
              stats: ExecStats, max_rows: int, probe: ProbeSpec,
              cols: Optional[Bindings] = None) -> Optional[Bindings]:
    """Same join semantics as :func:`_join_numpy`, with the whole
    probe→expand→gather chain fused into ``join.ops.hash_join_pipeline``:
    packed keys (int64 math — carried as 32-bit word pairs on the Pallas
    path), match runs, expanded pair positions, and the gathered build-side
    permutation stay device-resident between stages on the device tiers —
    the host sees one final ``(li, ri)`` materialization. The pipeline
    enforces ``max_rows`` on the data-dependent expansion total before any
    pair array exists, mirroring the cartesian cap."""
    from repro.kernels.join import ops as join_ops

    cols = _pattern_cols(pat, rows) if cols is None else cols
    if table is None:
        return cols
    shared = [v for v in cols if v in table]
    if not shared:
        nl, nr = _table_len(table), len(next(iter(cols.values())))
        li, ri = _cartesian_indices(nl, nr, stats, max_rows)
    else:
        lcs, rcs = _key_columns(table, cols, shared)
        mode, force = probe
        try:
            li, ri, total = join_ops.hash_join_pipeline(
                lcs, rcs, mode=mode, use_kernel=force, max_total=max_rows)
        except join_ops.ExpansionCapExceeded as e:
            raise JoinCapExceeded(
                f"{e}; raise Executor(max_join_rows=...) or add a more "
                "selective pattern") from None
        stats.expanded_rows += total
    out: Bindings = {v: c[li] for v, c in table.items()}
    for v, c in cols.items():
        if v not in out:
            out[v] = c[ri]
    return out


def _federation_bincounts(shard_ids_list: Sequence[np.ndarray],
                          n_shards: int) -> np.ndarray:
    """(n_entries, n_shards) serving-shard counts for every distinct
    executed (pattern[, read layout]) of the batch — one jax scatter-add
    dispatch for the whole workload window. Each entry is the per-match
    shard ids (primary ``triple_shard`` gather, or the replica-aware
    ``read_shard`` gather when the layout holds read copies)."""
    import jax.numpy as jnp

    from repro.kernels.join import ops as join_ops

    if not shard_ids_list:
        return np.zeros((0, n_shards), np.int64)
    lens = np.array([len(i) for i in shard_ids_list], np.int64)
    if lens.sum() == 0:
        return np.zeros((len(shard_ids_list), n_shards), np.int64)
    # the segment build is the same segmented ragged expansion as the join's
    # pair expansion (segment id per flat output slot), through the same
    # dispatch seam: host numpy on CPU, device tiers on TPU
    seg = join_ops.expand_segment_ids(lens)
    shard_ids = np.concatenate(
        [np.asarray(i, np.int32) for i in shard_ids_list])
    out = jnp.zeros((len(shard_ids_list), n_shards), jnp.int32)
    out = out.at[jnp.asarray(seg), jnp.asarray(shard_ids)].add(1)
    return np.asarray(out).astype(np.int64)


class JaxExecutor:
    """Batched backend: global-store matching with pattern results
    (indices, rows, variable columns) deduplicated across the whole window,
    kernel-dispatched key-packing/probe for the hash joins, and one
    scatter-add dispatch for the batch's federation accounting over
    distinct patterns.

    Two probe backends share the join machinery (``repro.kernels.join``):

    * ``pallas=False`` (``executor="jax"``) — the jitted-jnp pack/search
      kernels (``hash_probe_oracle``); ``probe_kernel`` = ``None`` auto
      (jitted on TPU, same-math numpy elsewhere), ``True``/``False`` force.
    * ``pallas=True`` (``executor="jax-pallas"``) — the Pallas sorted-probe
      kernel family under the shared ``kernels.dispatch`` hot-path policy:
      compiled kernels on TPU for large-enough joins, the jitted oracle
      elsewhere; ``probe_kernel=True`` forces the kernels (``interpret``
      mode on CPU — how the equivalence tests pin bit-equality)."""

    name = "jax"

    def __init__(self, max_join_rows: int = DEFAULT_MAX_JOIN_ROWS,
                 probe_kernel: bool | None = None, pallas: bool = False):
        self.max_join_rows = max_join_rows
        self.probe_kernel = probe_kernel
        self.pallas = pallas
        if pallas:
            self.name = "jax-pallas"

    def _probe_spec(self) -> ProbeSpec:
        from repro.kernels import dispatch

        if self.pallas:
            return ("pallas", self.probe_kernel)
        jit = (self.probe_kernel if self.probe_kernel is not None
               else dispatch.on_tpu())
        return ("oracle" if jit else "numpy", None)

    def run(self, plan: qplan.QueryPlan, kg) -> Tuple[Bindings, ExecStats]:
        return self.run_batch([plan], kg)[0]

    def run_batch(self, plans: Sequence[qplan.QueryPlan], kg,
                  ) -> List[Tuple[Bindings, ExecStats]]:
        store = kg.store
        triple_shard = kg.triple_shard
        probe = self._probe_spec()
        # global-store matches deduplicated across the whole window:
        # pattern -> (row ids, matched triples, variable columns)
        match_cache: Dict[tuple, tuple] = {}

        results: List[Tuple[Bindings, ExecStats]] = []
        executed: List[Tuple[int, tuple]] = []         # (query, pattern)
        for qi, plan in enumerate(plans):
            stats = ExecStats()
            t0 = time.perf_counter()
            table: Optional[Bindings] = None
            ops_run = 0
            for op in plan.ops:
                hit = match_cache.get(op.pattern)
                if hit is None:
                    s, p, o = op.pattern
                    idx = store.match_indices(None if is_var(s) else s,
                                              None if is_var(p) else p,
                                              None if is_var(o) else o)
                    rows = store.triples[idx]
                    hit = (idx, rows, _pattern_cols(op.pattern, rows))
                    match_cache[op.pattern] = hit
                idx, rows, cols = hit
                executed.append((qi, op.pattern))
                ops_run += 1
                before = _table_len(table)
                table = _join_jax(table, op.pattern, rows, stats,
                                  self.max_join_rows, probe, cols=cols)
                stats.join_rows += before + len(rows) + _table_len(table)
                if table is not None and _table_len(table) == 0:
                    break
            if table is not None and ops_run == 1:
                # single-op result IS the cached column dict: copy so two
                # queries in the window never alias the same binding arrays
                table = {v: c.copy() for v, c in table.items()}
            stats.rows = _table_len(table)
            stats.wall_s = time.perf_counter() - t0
            results.append((table or {}, stats))

        # one dispatched batch prices the federation of every distinct
        # pattern executed in the window. On a replicated layout the
        # serving shard of a match depends on the query's PPN (its local
        # copies serve for free), so entries are keyed per (pattern, ppn)
        # and gathered through the facade's cached read_shard(ppn).
        t0 = time.perf_counter()
        replicated = _has_replicated_layout(kg)
        if replicated:
            keys = [(pat, plans[qi].ppn) for qi, pat in executed]
            distinct = list(dict.fromkeys(keys))
            idx_lists = [kg.read_shard(ppn)[match_cache[pat][0]]
                         for pat, ppn in distinct]
        else:
            keys = [pat for _, pat in executed]
            distinct = list(match_cache)
            idx_lists = [triple_shard[match_cache[pat][0]]
                         for pat in distinct]
        counts = _federation_bincounts(idx_lists, kg.n_shards)
        count_of = dict(zip(distinct, counts))
        for key, (qi, pat) in zip(keys, executed):
            stats = results[qi][1]
            plan = plans[qi]
            per_shard = count_of[key]
            stats.scan_rows_critical += int(per_shard.max())
            off = per_shard.copy()
            off[plan.ppn] = 0
            nz = int((off > 0).sum())
            stats.messages += nz
            stats.rows_shipped += int(off.sum())
            stats.bytes_shipped += int(off.sum()) * TRIPLE_BYTES
            if plan.n_patterns > 1:
                stats.distributed_joins += nz
        if plans:
            acct = (time.perf_counter() - t0) / len(plans)
            for _, stats in results:
                stats.wall_s += acct
        m = getattr(kg, "metrics", None)
        if m is not None:          # repro.obs: backend execution counters
            m.counter("executor.batches").inc()
            m.counter("executor.queries").inc(len(plans))
            m.counter("executor.match_dedup_hits").inc(
                len(executed) - len(match_cache))
            for _, stats in results:
                m.histogram("executor.wall_s").observe(stats.wall_s)
        return results


_EXECUTORS = {
    "numpy": NumpyExecutor,
    "jax": JaxExecutor,
    "jax-pallas": lambda **kw: JaxExecutor(pallas=True, **kw),
}


def get_executor(spec: "str | Executor | None") -> Executor:
    """Resolve an executor: an instance passes through, a name (``"numpy"`` /
    ``"jax"`` / ``"jax-pallas"``) constructs the backend, ``None`` means the
    numpy reference."""
    if spec is None:
        return NumpyExecutor()
    if isinstance(spec, str):
        try:
            return _EXECUTORS[spec]()
        except KeyError:
            raise ValueError(f"unknown executor {spec!r}; "
                             f"expected one of {sorted(_EXECUTORS)}") from None
    return spec


# --------------------------------------------------------------------------- #
# profiles (derived from plans) + workload helpers
# --------------------------------------------------------------------------- #

def profile_from_plan(plan: qplan.QueryPlan, store,
                      max_join_rows: int = DEFAULT_MAX_JOIN_ROWS,
                      ) -> qplan.QueryProfile:
    """One real execution of ``plan`` against the global store, recording the
    layout-invariant artifacts (matched row ids, join-pipeline counts).
    ``max_join_rows`` should match the serving executor's cap so profiling
    never rejects a workload the executor was configured to allow.

    The recorded row ids index the store *as it is now*: a live write
    (``repro.write``) compacts/appends rows, so profiles are valid per
    facade ``data_version`` — ``PartitionedKG.profile`` re-derives after
    any effective mutation rather than serving remapped-out ids."""
    prof = qplan.QueryProfile(pattern_rows=[], join_rows=0, rows=0,
                              n_patterns=plan.n_patterns)
    stats = ExecStats()
    table: Optional[Bindings] = None
    for op in plan.ops:
        s, p, o = op.pattern
        idx = store.match_indices(None if is_var(s) else s,
                                  None if is_var(p) else p,
                                  None if is_var(o) else o)
        prof.pattern_rows.append(np.asarray(idx, dtype=np.int64))
        rows = store.triples[idx]
        before = _table_len(table)
        table = _join_numpy(table, op.pattern, rows, stats, max_join_rows)
        prof.join_rows += before + len(rows) + _table_len(table)
        if table is not None and _table_len(table) == 0:
            break
    prof.rows = _table_len(table)
    prof.cartesian_rows = stats.cartesian_rows
    prof.expanded_rows = stats.expanded_rows
    return prof


def _plans_for(queries: Sequence[Query], kg) -> List[qplan.QueryPlan]:
    if hasattr(kg, "plan"):           # PartitionedKG: cached per (query, store)
        return [kg.plan(q) for q in queries]
    return [qplan.plan(q, kg) for q in queries]


def run_workload(queries: Sequence[Query], kg,
                 executor: "str | Executor | None" = None,
                 net: NetworkModel | None = None,
                 ) -> Tuple[Dict[str, float], Dict[str, ExecStats]]:
    """Execute a workload window in one batch; returns per-query modeled
    times (seconds) and stats, keyed by query name."""
    ex = get_executor(executor)
    net = net or NetworkModel()
    plans = _plans_for(queries, kg)
    results = ex.run_batch(plans, kg)
    times = {q.name: st.modeled_time(net)
             for q, (_, st) in zip(queries, results)}
    all_stats = {q.name: st for q, (_, st) in zip(queries, results)}
    return times, all_stats


def workload_average_time(queries: Sequence[Query], kg,
                          executor: "str | Executor | None" = None,
                          net: NetworkModel | None = None) -> float:
    """Fig.-5 average: frequency-weighted mean runtime over the workload."""
    times, _ = run_workload(queries, kg, executor, net)
    freqs = np.array([q.frequency for q in queries])
    vals = np.array([times[q.name] for q in queries])
    return float((vals * freqs).sum() / freqs.sum())
