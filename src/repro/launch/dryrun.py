import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed
on the 16×16 single-pod mesh and the 2×16×16 two-pod mesh for every supported
cell, and the compiled artifact yields memory/cost/collective statistics for
the roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro import compat
import repro.configs as configs
from repro.configs.base import SHAPES, ArchConfig, shape_supported
from repro.launch import hlo_analysis, roofline, sharding
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import lm, transformer
from repro.models.moe import ShardCtx
from repro.optim import AdamWConfig, adamw_init


def _shape_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(cfg: ArchConfig, shape_name: str, mesh, *,
               donate: bool = True):
    """Build (lowered, compiled, meta) for one cell."""
    kind = SHAPES[shape_name]["kind"]
    ctx = ShardCtx(mesh=mesh, dp_axes=dp_axes(mesh))
    opt_cfg = AdamWConfig()
    key = jax.random.PRNGKey(0)

    params_sds = jax.eval_shape(lambda: transformer.init_params(key, cfg)[0])
    # the logical-axes tree contains strings (not jax types), so it cannot be
    # eval_shape'd; a reduced config has the identical tree structure and
    # identical axis names — materialize it cheaply from there.
    _, axes = transformer.init_params(jax.random.PRNGKey(0), cfg.reduced())
    profile = cfg.sharding_profile
    p_sh = sharding.tree_shardings(axes, params_sds, mesh, profile=profile,
                                   kind="param")

    batch_sds = lm.input_specs(cfg, shape_name)
    b_sh = sharding.batch_specs(batch_sds, mesh, profile=profile)

    if kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        o_sh = sharding.opt_state_shardings(axes, params_sds, opt_sds, mesh)

        def step(params, opt_state, batch):
            return lm.train_step(params, opt_state, batch, cfg, ctx, opt_cfg)

        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1) if donate else ())
        with compat.set_mesh(mesh):
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif kind == "prefill":
        def step(params, batch):
            return lm.prefill_step(params, batch, cfg, ctx)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        with compat.set_mesh(mesh):
            lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        cache_sds = lm.cache_specs(cfg, shape_name)
        c_axes = sharding.cache_axes(cfg)
        c_sh = sharding.tree_shardings(
            {k: c_axes[k] for k in cache_sds}, cache_sds, mesh,
            profile=profile)

        def step(params, caches, batch):
            return lm.decode_step(params, caches, batch, cfg, ctx)

        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,) if donate else ())
        with compat.set_mesh(mesh):
            lowered = jitted.lower(params_sds, cache_sds, batch_sds)

    compiled = lowered.compile()
    return lowered, compiled


def extrapolated_costs(cfg: ArchConfig, shape_name: str, mesh) -> dict:
    """Per-layer cost extrapolation.

    XLA's HLO cost analysis counts a while-loop body once, so the scanned
    L-layer artifact under-reports flops/bytes/collectives by ~L. We compile
    two small *unrolled* variants (L_a, L_b layers) and extrapolate linearly:
    total(L) = cost(L_a) + (L - L_a) * (cost(L_b) - cost(L_a)) / (L_b - L_a).
    For zamba2 a third 1-layer point with ``attn_every=1`` isolates the
    shared attention block's per-application cost, since the L=1/2 points
    contain exactly one application each."""
    from repro.models.transformer import n_shared_apps

    def measure(l_small: int, attn_every: int | None = None) -> dict:
        over = dict(n_layers=l_small, scan_layers=False)
        if attn_every is not None:
            over["attn_every"] = attn_every
        cfg_s = dataclasses.replace(cfg, **over)
        _, compiled = lower_cell(cfg_s, shape_name, mesh, donate=False)
        cost = compat.cost_analysis(compiled)
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        return dict(flops=float(cost.get("flops", 0.0)),
                    bytes=float(cost.get("bytes accessed", 0.0)),
                    coll=coll)

    # MoE cells: the L=1 point is unstable (dispatch-buffer layouts differ
    # between 1- and 2-layer modules), so use the (2, 4) pair instead.
    la_, lb_ = (2, 4) if cfg.is_moe else (1, 2)
    a = measure(la_)    # base + la layers (+1 shared app for hybrids)
    b = measure(lb_)    # base + lb layers (+1 shared app)
    l_full = cfg.n_layers
    extra_apps = 0
    c = None
    if cfg.attn_every:
        # apps(L=1) == apps(L=2) == 1; full model has n_shared_apps(cfg)
        extra_apps = n_shared_apps(cfg) - 1
        c = measure(2, attn_every=1)   # 2 layers + 2 shared apps

    def extrap(ka: float, kb: float, kc: float | None) -> float:
        per_layer = max((kb - ka) / (lb_ - la_), 0.0)
        total = ka + (l_full - la_) * per_layer
        if kc is not None and extra_apps:
            per_app = max(kc - kb, 0.0)
            total += extra_apps * per_app
        return max(total, 0.0)

    def coll_key(k):
        return extrap(a["coll"][k], b["coll"][k],
                      c["coll"][k] if c else None)

    out = dict(
        flops=extrap(a["flops"], b["flops"], c["flops"] if c else None),
        bytes=extrap(a["bytes"], b["bytes"], c["bytes"] if c else None),
        collectives={k: int(coll_key(k)) for k in a["coll"]},
        points=dict(l_a=la_, l_b=lb_, a=a, b=b, c=c, extra_apps=extra_apps))
    return out


def analyze_cell(cfg: ArchConfig, shape_name: str, mesh_name: str,
                 lowered, compiled, extrap: dict | None = None) -> dict:
    info = SHAPES[shape_name]
    kind = info["kind"]
    n_chips = 512 if mesh_name == "multi" else 256
    n_tokens = (info["global_batch"] * info["seq_len"]
                if kind in ("train", "prefill") else info["global_batch"])

    cost = compat.cost_analysis(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    if extrap is not None:
        cost["flops"] = extrap["flops"]
        cost["bytes accessed"] = extrap["bytes"]
        coll = extrap["collectives"]
    mf = roofline.model_flops(cfg, shape_name, n_tokens, kind)
    rf = roofline.build(cost, coll, n_chips, mf)

    mem_stats = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            mem_stats[attr] = getattr(mem, attr, None)

    return dict(
        arch=cfg.arch_id, shape=shape_name, mesh=mesh_name, kind=kind,
        n_chips=n_chips, n_tokens=n_tokens,
        n_params=cfg.n_params(), n_active_params=cfg.n_active_params(),
        cost={k: v for k, v in cost.items()
              if k in ("flops", "bytes accessed", "transcendentals")},
        memory=mem_stats, collectives=coll, roofline=rf.to_dict(),
    )


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path,
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    ok, reason = shape_supported(cfg, shape_name)
    name = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{name}.json"
    if not ok:
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                   skipped=True, reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skip] {name}: {reason}")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        lowered, compiled = lower_cell(cfg, shape_name, mesh)
        extrap = extrapolated_costs(cfg, shape_name, mesh)
        rec = analyze_cell(cfg, shape_name, mesh_name, lowered, compiled,
                           extrap)
        rec["extrapolation"] = extrap["points"]
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["skipped"] = False
        out_path.write_text(json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(f"[ok]   {name}: compile={rec['compile_s']}s "
              f"dominant={r['dominant']} "
              f"t=(c {r['t_compute']*1e3:.2f} | m {r['t_memory']*1e3:.2f} | "
              f"x {r['t_collective']*1e3:.2f}) ms "
              f"useful={r['useful_flops_ratio']:.2f} "
              f"frac={r['roofline_fraction']:.3f}")
        return rec
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                   skipped=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[FAIL] {name}: {type(e).__name__}: {str(e)[:200]}")
        return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides, e.g. --set remat=dots")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        field_types = {f.name: f.type for f in
                       dataclasses.fields(ArchConfig)}
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                run_cell(arch, shape_name, mesh_name, out_dir,
                         overrides or None, args.tag)


if __name__ == "__main__":
    main()
