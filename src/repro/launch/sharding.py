"""Logical-axis -> PartitionSpec resolution (MaxText-style rule table).

Every parameter / activation / cache dimension carries a logical axis name;
rules map each name to an ordered list of mesh-axis candidates. Resolution is
greedy left-to-right per tensor with two constraints:
  * divisibility — a mesh axis is only used if it divides the dim size,
  * exclusivity — each mesh axis is used at most once per tensor.
Non-divisible axes degrade to replication (8 kv heads never shard on a
16-way model axis), and long decode caches shard their time dim over the
otherwise-idle ``data`` axis when batch==1.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# ordered candidates per logical axis; tuples are joint (multi-axis) shards
PRIORITIES: Dict[str, List[Tuple[str, ...]]] = {
    "batch": [("pod", "data"), ("data",), ("pod",)],
    "cache_time": [("pod", "data"), ("data",), ("pod",)],
    # dp profile (small models): batch spreads over the model axis too
    "batch_dp": [("pod", "data", "model"), ("data", "model"),
                 ("pod", "data"), ("data",)],
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    # head_dim deliberately has NO candidates: sharding the attention
    # contraction dim forces SPMD into replicated compute + reshard storms
    # (measured ~30x flop inflation on smollm/qwen2.5 whose head counts
    # don't divide the model axis). Non-divisible head axes replicate.
    "head_dim": [],
    "experts": [("model",)],
    "ff": [("model",)],
    "embed": [("pod", "data"), ("data",)],     # FSDP axis for params
    "embed2": [("model",)],
    "heads_x_dim": [("model",)],
    "state": [],
    "layers": [],
    "shared_apps": [],
}


def spec_for(axes: Optional[Tuple[Optional[str], ...]],
             shape: Sequence[int], mesh, *, profile: str = "fsdp_tp") -> P:
    """Resolve one tensor's logical axes tuple to a PartitionSpec.

    Profiles:
      * ``fsdp_tp`` (large models): params FSDP over data + TP over model.
      * ``dp`` (<= ~1.5B params): pure data parallelism — batch spreads over
        BOTH mesh axes, parameters replicate, optimizer moments stay sharded
        (ZeRO-1). Small models can't use 16-way TP productively (head counts
        often indivisible; per-layer FSDP gathers cost more than they save).
    """
    if axes is None:
        return P()
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    parts: List[Any] = []
    # expert weights are already n_experts/TP-way sharded on `model`;
    # FSDP-sharding their embed dim too would force a full expert-weight
    # all-gather around every shard_map MoE layer (measured: dominates the
    # collective term). Same for embedding/head tables (vocab -> model):
    # FSDP on their embed dim makes SPMD gather full (B, S, V) logits in the
    # loss backward (measured: 211 GB/step on the two-pod mesh). Tensors
    # already model-sharded stay out of FSDP.
    has_experts = ("experts" in axes) or ("vocab" in axes)
    for dim, name in enumerate(axes):
        assignment = None
        lookup = name
        if profile == "dp" and name in ("batch", "cache_time"):
            lookup = "batch_dp"
        if has_experts and name == "embed":
            name = None
        if name is not None:
            for cand in PRIORITIES.get(lookup, []):
                if any(a in used or a not in mesh_sizes for a in cand):
                    continue
                total = 1
                for a in cand:
                    total *= mesh_sizes[a]
                if shape[dim] % total == 0 and shape[dim] > 0:
                    assignment = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        parts.append(assignment)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(axes_tree, shape_tree, mesh, *, profile: str = "fsdp_tp",
                   kind: str = "cache"):
    """NamedSharding tree for a params/cache pytree.

    ``axes_tree`` leaves are tuples of logical names (or None); ``shape_tree``
    leaves are arrays or ShapeDtypeStructs. ``kind="param"`` with the ``dp``
    profile replicates everything (pure data parallelism)."""
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(e, str) or e is None for e in x))
    flat_axes = jax.tree.flatten(axes_tree, is_leaf=is_axes_leaf)[0]
    flat_shapes, tdef = jax.tree.flatten(shape_tree)
    assert len(flat_axes) == len(flat_shapes), \
        (len(flat_axes), len(flat_shapes))
    if profile == "dp" and kind == "param":
        shardings = [NamedSharding(mesh, P()) for _ in flat_axes]
    else:
        shardings = [NamedSharding(mesh, spec_for(a, s.shape, mesh,
                                                  profile=profile))
                     for a, s in zip(flat_axes, flat_shapes)]
    return tdef.unflatten(shardings)


def batch_specs(batch_tree, mesh, *, profile: str = "fsdp_tp",
                ) -> Dict[str, Any]:
    """Input batch shardings: leading dim is batch; everything else replicated."""
    def leaf(sd):
        if getattr(sd, "ndim", 0) >= 1:
            return NamedSharding(mesh, spec_for(
                ("batch",) + (None,) * (sd.ndim - 1), sd.shape, mesh,
                profile=profile))
        return NamedSharding(mesh, P())
    return jax.tree.map(leaf, batch_tree)


def cache_axes(cfg) -> Dict[str, Tuple]:
    """Logical axes for decode caches (mirrors transformer.init_decode_caches)."""
    if cfg.rwkv:
        return dict(
            tm_shift=("layers", "batch", "embed2"),
            cm_shift=("layers", "batch", "embed2"),
            wkv=("layers", "batch", "heads", None, None))
    if cfg.family in ("ssm", "hybrid"):
        axes = dict(
            conv=("layers", "batch", None, "ff"),
            ssm=("layers", "batch", "heads", "state", None))
        if cfg.attn_every:
            axes["k"] = ("shared_apps", "batch", "cache_time", "kv_heads",
                         "head_dim")
            axes["v"] = axes["k"]
        return axes
    kv = ("layers", "batch", "cache_time", "kv_heads", "head_dim")
    return {"k": kv, "v": kv}


def opt_state_shardings(axes_tree, params_shapes, opt_state_shapes, mesh):
    """Adam moments are ALWAYS FSDP-sharded (ZeRO-1 when params replicate);
    None/int leaves replicate."""
    moment_shardings = tree_shardings(axes_tree, params_shapes, mesh,
                                      profile="fsdp_tp", kind="param")
    return {
        "mu": _mask_like(moment_shardings, opt_state_shapes["mu"], mesh),
        "nu": _mask_like(moment_shardings, opt_state_shapes["nu"], mesh),
        "step": NamedSharding(mesh, P()),
    }


def _mask_like(param_shardings, moment_tree, mesh):
    rep = NamedSharding(mesh, P())
    flat_s = jax.tree.leaves(param_shardings)
    flat_m, tdef = jax.tree.flatten(moment_tree,
                                    is_leaf=lambda x: x is None)
    # params tree and moment tree align leaf-for-leaf (moments None for ints)
    out = []
    si = 0
    for m in flat_m:
        s = flat_s[si]
        si += 1
        out.append(rep if m is None else s)
    return tdef.unflatten(out)
