"""Collective-traffic extraction from optimized HLO text.

``compiled.cost_analysis()`` has no collective-bytes entry, so we parse the
per-device HLO module. Optimized HLO prints operands without type literals,
so wire bytes are derived from the *result* type plus the collective's
semantics (ring algorithms), with the group size parsed from
``replica_groups=[G,S]`` iota notation:

    all-reduce         2*(g-1)/g * result      (reduce-scatter + all-gather ring)
    all-gather           (g-1)/g * result      (result = gathered size)
    reduce-scatter       (g-1)   * result      (result = scattered shard)
    all-to-all           (g-1)/g * result
    collective-permute             result

Async ``-start``/``-done`` pairs are counted once via the ``-start`` op.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64"
                      r"|c64|c128)\[([0-9,]*)\]")
# result type is either a single literal or a tuple which may contain
# /*index=N*/ comments — match non-greedily up to the opcode
_OP_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _result_bytes(result_str: str) -> int:
    total = 0
    for dtype, dims in _TYPE_RE.findall(result_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _wire_factor(op: str, g: int) -> float:
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)
    if op == "all-to-all":
        return (g - 1) / g
    return 1.0                     # collective-permute


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes injected into the interconnect, per collective family."""
    totals: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_str, op, _ = m.group(1), m.group(2), m.group(3)
        size = _result_bytes(result_str)
        g = _group_size(line)
        totals[op] += size * _wire_factor(op, g)
        counts[op] += 1
    out: Dict[str, int] = {f"{k.replace('-', '_')}_bytes": int(v)
                           for k, v in totals.items()}
    out.update({f"{k.replace('-', '_')}_count": v for k, v in counts.items()})
    out["total_bytes"] = int(sum(totals.values()))
    out["total_count"] = sum(counts.values())
    return out
