"""Roofline terms for TPU v5e from dry-run compiled artifacts.

Hardware constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM bandwidth,
~50 GB/s/link ICI. ``cost_analysis()``/HLO parsing operate on the per-device
partitioned module, so the three terms are per-chip step times directly:

    t_compute    = device_FLOPs / peak_FLOP/s
    t_memory     = device_HBM_bytes / HBM_bw
    t_collective = device_collective_bytes / ICI_bw
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


@dataclasses.dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    device_flops: float
    device_bytes: float
    device_coll_bytes: float
    model_flops_total: float      # 6*N*D (train) / 2*N*D (inference), global
    hlo_flops_total: float        # device_flops * n_chips
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        if self.hlo_flops_total <= 0:
            return 0.0
        return self.model_flops_total / self.hlo_flops_total

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        bound: (useful flop time) / (achievable step time)."""
        ideal = self.model_flops_total / (self.n_chips * PEAK_FLOPS)
        if self.bound_time <= 0:
            return 0.0
        return ideal / self.bound_time

    def to_dict(self) -> Dict:
        return dict(
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, dominant=self.dominant,
            device_flops=self.device_flops, device_bytes=self.device_bytes,
            device_coll_bytes=self.device_coll_bytes,
            model_flops_total=self.model_flops_total,
            hlo_flops_total=self.hlo_flops_total,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
            n_chips=self.n_chips)


def model_flops(cfg, shape_name: str, n_tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for inference (N = active params)."""
    n = cfg.n_active_params()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens


def build(cost: Dict, coll: Dict, n_chips: int,
          model_flops_total: float) -> Roofline:
    dev_flops = float(cost.get("flops", 0.0))
    dev_bytes = float(cost.get("bytes accessed", 0.0))
    dev_coll = float(coll.get("total_bytes", 0))
    return Roofline(
        t_compute=dev_flops / PEAK_FLOPS,
        t_memory=dev_bytes / HBM_BW,
        t_collective=dev_coll / ICI_BW,
        device_flops=dev_flops, device_bytes=dev_bytes,
        device_coll_bytes=dev_coll,
        model_flops_total=model_flops_total,
        hlo_flops_total=dev_flops * n_chips,
        n_chips=n_chips)
