"""KG serving driver — the paper's own system end-to-end (Fig. 6).

Master-node loop: LUBM dataset -> workload-aware initial partition (WawPart
[21]) -> serve federated queries over the shards -> monitor per-query
runtimes (TM) -> on workload change, run the Fig.-5 adaptation -> migrate
triples -> keep serving. ``--experiment 1|2`` reproduces the paper's two
evaluations.

  PYTHONPATH=src python -m repro.launch.serve --universities 5 --shards 8 \
      --experiment 1
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.features import FeatureSpace
from repro.graph import lubm
from repro.query import engine, rewrite


def build_system(universities: int, shards: int, seed: int = 0,
                 config: AdaptConfig | None = None):
    ds = lubm.load(universities, seed)
    space = FeatureSpace(ds.store,
                         type_predicate=ds.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=shards, config=config)
    return ds, space, ctrl


def serve_workload(ds, space, state, queries, net=None):
    sharded = engine.ShardedStore(ds.store, space, state)
    times, stats = engine.run_workload(queries, sharded, net)
    return sharded, times, stats


def experiment1(ds, space, ctrl, verbose=True):
    """Workload-composition change: 14 base queries -> +10 new queries."""
    base = ds.base_workload()
    space.track_workload(base)
    state = ctrl.initial_partition(base)
    extended = ds.extended_workload()
    _, t_initial, s_initial = serve_workload(ds, space, state, extended)

    def measure(cand):
        sh = engine.ShardedStore(ds.store, space, cand)
        return engine.workload_average_time(list(ctrl.workload.values()), sh)

    new_queries = ds.workload([f"EQ{i}" for i in range(1, 11)])
    state2, report = ctrl.adapt(new_queries, measure=measure)
    _, t_adapt, s_adapt = serve_workload(ds, space, state2, extended)
    if verbose:
        _print_exp(t_initial, t_adapt, s_initial, s_adapt, report)
    return dict(initial=t_initial, adaptive=t_adapt, report=report,
                stats_initial=s_initial, stats_adaptive=s_adapt,
                state=state2)


def experiment2(ds, space, ctrl, hot_query: str = "Q1",
                hot_share: float = 0.5, verbose=True):
    """Frequency change: hot_query becomes hot_share of the workload."""
    base = ds.base_workload()
    space.track_workload(base)
    state = ctrl.initial_partition(base)
    n = len(base)
    hot_freq = hot_share * (n - 1) / (1 - hot_share)
    biased = ds.workload([q.name for q in base],
                         frequencies={hot_query: hot_freq})
    sharded0 = engine.ShardedStore(ds.store, space, state)
    t0 = engine.workload_average_time(biased, sharded0)

    def measure(cand):
        sh = engine.ShardedStore(ds.store, space, cand)
        return engine.workload_average_time(biased, sh)

    state2, report = ctrl.adapt(biased, measure=measure)
    sharded1 = engine.ShardedStore(ds.store, space, state2)
    t1 = engine.workload_average_time(biased, sharded1)
    if verbose:
        print(f"[exp2] biased-workload avg: initial {t0*1e3:.1f} ms -> "
              f"adaptive {t1*1e3:.1f} ms "
              f"({(1 - t1 / max(t0, 1e-12)) * 100:.1f}% improvement) | "
              f"{report.plan.summary()}")
    return dict(t_initial=t0, t_adaptive=t1, report=report, state=state2)


def _print_exp(t0: Dict, t1: Dict, s0, s1, report) -> None:
    new_q = [n for n in t0 if n.startswith("EQ")]
    old_q = [n for n in t0 if not n.startswith("EQ")]
    avg = lambda t, qs: float(np.mean([t[q] for q in qs]))
    print(f"[exp1] adaptation accepted={report.accepted} "
          f"dj {report.dj_before:.0f}->{report.dj_after:.0f} | "
          f"{report.plan.summary()}")
    print(f"[exp1] new queries avg: {avg(t0,new_q)*1e3:.1f} -> "
          f"{avg(t1,new_q)*1e3:.1f} ms "
          f"({(1 - avg(t1,new_q)/avg(t0,new_q))*100:.1f}% improvement)")
    print(f"[exp1] old queries avg: {avg(t0,old_q)*1e3:.1f} -> "
          f"{avg(t1,old_q)*1e3:.1f} ms")
    print(f"[exp1] all 24 avg:      {avg(t0,list(t0))*1e3:.1f} -> "
          f"{avg(t1,list(t1))*1e3:.1f} ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=10)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--experiment", type=int, default=1, choices=[1, 2])
    ap.add_argument("--show-federated", action="store_true",
                    help="print a federated SPARQL rewrite example")
    args = ap.parse_args()

    t0 = time.time()
    ds, space, ctrl = build_system(args.universities, args.shards)
    print(f"loaded LUBM({args.universities}): {ds.store.n_triples} triples "
          f"({time.time()-t0:.1f}s), {space.n_features} features, "
          f"{args.shards} shards")
    if args.experiment == 1:
        out = experiment1(ds, space, ctrl)
    else:
        out = experiment2(ds, space, ctrl)
    if args.show_federated:
        state = out["state"]
        q = ds.queries["Q9"]
        print("\nFederated rewrite of Q9 under the adapted partition:")
        print(rewrite.federated_sparql(q, space, state, ds.dictionary))


if __name__ == "__main__":
    main()
