"""KG serving driver — the paper's own system end-to-end (Fig. 6).

Master-node loop via the ``repro.api`` service facade: LUBM dataset ->
workload-aware initial partition (WawPart [21]) -> serve federated queries
over the shards -> monitor per-query runtimes (TM) -> on workload change,
run the Fig.-5 adaptation as an incremental shard-view delta -> keep
serving. ``--experiment 1|2`` reproduces the paper's two evaluations,
``--partitioner hash|wawpart|awapart`` swaps the strategy,
``--executor numpy|jax|jax-pallas`` swaps the query backend under the same
harness (``jax-pallas`` probes hash joins through the ``repro.kernels.join``
Pallas kernels — see ``docs/kernels.md``), and
``--migration-budget BYTES`` throttles accepted migrations into a chunked
``MigrationSession`` drained one chunk per serving window (default: atomic),
and ``--writes-per-window N`` interleaves N synthetic live inserts
(``repro.write``: fresh subjects carrying sampled (p, o) pairs, routed by
primary and fanned out to replicas) ahead of every drain window — mixed
read/write serving. ``--stream`` swaps the experiment for the
continuous-admission loop (``repro.stream``): an open-loop replay at
``--arrival-rate`` qps with writes and the migration drain in flight,
reporting p50/p95/p99 admission→completion tails per window.
``--trace out.json`` records the run's ``repro.obs`` spans (per-query
plan→scan→join→federate→ship, windows, migration chunks, adaptation
rounds) as a Perfetto-loadable Chrome trace, and ``--metrics-csv``
dumps the metrics-registry snapshot.

  PYTHONPATH=src python -m repro.launch.serve --universities 5 --shards 8 \
      --experiment 1 --executor jax --migration-budget 1048576 \
      --writes-per-window 256
  PYTHONPATH=src python -m repro.launch.serve --universities 3 --shards 8 \
      --stream --arrival-rate 400 --migration-budget 1048576 \
      --writes-per-window 128
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import numpy as np

from repro.api import (AWAPartitioner, HashPartitioner, KGService,
                       WawPartitioner)
from repro.core.adaptive import AdaptConfig
from repro.graph import lubm
from repro.query import rewrite

PARTITIONERS = {"hash": HashPartitioner, "wawpart": WawPartitioner,
                "awapart": AWAPartitioner}


def build_system(universities: int, shards: int, seed: int = 0,
                 config: AdaptConfig | None = None,
                 partitioner: str = "awapart", executor: str = "numpy",
                 migration_budget: int | None = None,
                 replica_budget: int | None = None,
                 trace: bool = False):
    """Load LUBM and assemble the service facade (no partition yet)."""
    ds = lubm.load(universities, seed)
    part = (HashPartitioner() if partitioner == "hash"
            else PARTITIONERS[partitioner](config))
    svc = KGService.from_dataset(ds, shards, part, executor=executor,
                                 migration_budget=migration_budget,
                                 replica_budget=replica_budget,
                                 trace=trace)
    return ds, svc


def synthetic_writes(svc: KGService, n: int, rng):
    """Insert ``n`` synthetic rows into the live graph: fresh subjects
    (``svc.fresh_ids`` — entity ids live past the dictionary) carrying
    (p, o) pairs sampled from existing triples, so the writes land across
    the same features the workload reads. Returns the ``WriteReport``."""
    t = svc.kg.store.triples
    rows = t[rng.integers(0, len(t), n)].copy()
    rows[:, 0] = svc.fresh_ids(n).astype(np.int32)
    return svc.insert(rows)


def drive_migration(svc: KGService, window, verbose=True,
                    writes_per_window: int = 0, rng=None):
    """Drain a pending MigrationSession while continuing to serve: each
    ``query_batch`` window applies exactly one bounded chunk ahead of
    serving, then executes against the updated hybrid layout; with
    ``writes_per_window`` > 0, that many synthetic live inserts land ahead
    of every window (mixed read/write serving — later chunks carry the
    post-write rows). Returns per-window average modeled query times
    observed during the drain."""
    averages = []
    session = svc.session
    if writes_per_window and rng is None:
        rng = np.random.default_rng(0)
    while svc.session is not None:
        wrote = ""
        if writes_per_window:
            rep = synthetic_writes(svc, writes_per_window, rng)
            wrote = (f" | +{rep.n_inserted} rows on shards "
                     f"{rep.touched_shards}")
        results = svc.query_batch(window)       # serve + one chunk
        avg = float(np.mean([st.modeled_time(svc.net)
                             for _, st in results]))
        averages.append(avg)
        if verbose:
            print(f"[migrate] window {len(averages) - 1}: "
                  f"avg {avg * 1e3:6.1f} ms | epoch {svc.kg.epoch} | "
                  f"{session.applied}/{session.n_chunks} chunks, "
                  f"{session.bytes_applied / 1e6:.2f} MB migrated{wrote}")
    return averages


def experiment1(ds, svc: KGService, verbose=True,
                writes_per_window: int = 0):
    """Workload-composition change: 14 base queries -> +10 new queries."""
    kg = svc.bootstrap(ds.base_workload())
    extended = ds.extended_workload()
    t_initial, s_initial = svc.run_workload(extended)

    if not hasattr(svc.partitioner, "adapt"):   # static strategy: no round
        avg0 = float(np.mean(list(t_initial.values())))
        if verbose:
            print(f"[exp1] strategy={svc.partitioner.name} (static): "
                  f"all-24 avg {avg0*1e3:.1f} ms, no adaptation")
        return dict(initial=t_initial, adaptive=t_initial, report=None,
                    stats_initial=s_initial, stats_adaptive=s_initial,
                    state=kg.state, kg=kg)

    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    if svc.session is not None:        # throttled: drain while serving
        if verbose:
            print(f"[exp1] migration session: {svc.session.n_chunks} chunks "
                  f"of <= {svc.migration_budget} B "
                  f"({report.plan.summary()})")
        drive_migration(svc, extended, verbose=verbose,
                        writes_per_window=writes_per_window)
    t_adapt, s_adapt = svc.run_workload(extended)
    if verbose:
        _print_exp(t_initial, t_adapt, s_initial, s_adapt, report)
    return dict(initial=t_initial, adaptive=t_adapt, report=report,
                stats_initial=s_initial, stats_adaptive=s_adapt,
                state=kg.state, kg=kg)


def experiment2(ds, svc: KGService, hot_query: str = "Q1",
                hot_share: float = 0.5, verbose=True,
                writes_per_window: int = 0):
    """Frequency change: hot_query becomes hot_share of the workload."""
    base = ds.base_workload()
    svc.bootstrap(base)
    n = len(base)
    hot_freq = hot_share * (n - 1) / (1 - hot_share)
    biased = ds.workload([q.name for q in base],
                         frequencies={hot_query: hot_freq})
    t0 = svc.workload_average_time(biased)

    if not hasattr(svc.partitioner, "adapt"):   # static strategy: no round
        if verbose:
            print(f"[exp2] strategy={svc.partitioner.name} (static): "
                  f"biased avg {t0*1e3:.1f} ms, no adaptation")
        return dict(t_initial=t0, t_adaptive=t0, report=None,
                    state=svc.kg.state, kg=svc.kg)

    report = svc.adapt(biased)
    if svc.session is not None:        # throttled: drain while serving
        drive_migration(svc, biased, verbose=verbose,
                        writes_per_window=writes_per_window)
    t1 = svc.workload_average_time(biased)
    if verbose:
        print(f"[exp2] biased-workload avg: initial {t0*1e3:.1f} ms -> "
              f"adaptive {t1*1e3:.1f} ms "
              f"({(1 - t1 / max(t0, 1e-12)) * 100:.1f}% improvement) | "
              f"{report.plan.summary()}")
    return dict(t_initial=t0, t_adaptive=t1, report=report,
                state=svc.kg.state, kg=svc.kg)


def _print_exp(t0: Dict, t1: Dict, s0, s1, report) -> None:
    new_q = [n for n in t0 if n.startswith("EQ")]
    old_q = [n for n in t0 if not n.startswith("EQ")]
    avg = lambda t, qs: float(np.mean([t[q] for q in qs]))
    print(f"[exp1] adaptation accepted={report.accepted} "
          f"dj {report.dj_before:.0f}->{report.dj_after:.0f} "
          f"clusters={report.n_clusters} | {report.plan.summary()}")
    print(f"[exp1] new queries avg: {avg(t0,new_q)*1e3:.1f} -> "
          f"{avg(t1,new_q)*1e3:.1f} ms "
          f"({(1 - avg(t1,new_q)/avg(t0,new_q))*100:.1f}% improvement)")
    print(f"[exp1] old queries avg: {avg(t0,old_q)*1e3:.1f} -> "
          f"{avg(t1,old_q)*1e3:.1f} ms")
    print(f"[exp1] all 24 avg:      {avg(t0,list(t0))*1e3:.1f} -> "
          f"{avg(t1,list(t1))*1e3:.1f} ms")


def stream_demo(ds, svc: KGService, rate_qps: float, passes: int = 4,
                writes_per_window: int = 0, verbose=True):
    """Continuous-admission serving (``repro.stream``): bootstrap, accept
    an adaptation round, then replay an open-loop arrival process of the
    extended workload — writes admitted mid-stream, the migration drain
    retiring into idle gaps — and report per-window p50/p95/p99 tails."""
    from repro.api import WriteBatch
    from repro.stream import interleave, open_loop_arrivals, replay

    svc.bootstrap(ds.base_workload())
    window = ds.extended_workload()
    svc.query_batch(window)
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    in_flight = svc.session.n_chunks if svc.session is not None else 0

    queries = window * passes
    writes = []
    if writes_per_window:
        rng = np.random.default_rng(0)
        t = svc.kg.store.triples
        fresh = svc.fresh_ids(passes * writes_per_window)
        for k in range(passes):
            rows = t[rng.integers(0, len(t), writes_per_window)].copy()
            rows[:, 0] = fresh[k * writes_per_window:
                               (k + 1) * writes_per_window].astype(np.int32)
            writes.append((k * len(window), WriteBatch(inserts=rows)))
    stream = svc.stream(pipeline=True)
    replay(stream, interleave(
        queries, open_loop_arrivals(len(queries), rate_qps), writes))
    results = stream.poll()

    stats = stream.stats()
    lat = stats["latency"]
    if verbose:
        for w, s in stream.recorder.per_window().items():
            print(f"[stream] window {w}: n={s['n']:3d} "
                  f"p50 {s['p50'] * 1e3:8.1f} ms | "
                  f"p95 {s['p95'] * 1e3:8.1f} ms | "
                  f"p99 {s['p99'] * 1e3:8.1f} ms")
        hidden = sum(w["hidden_s"] for w in stream.window_log)
        print(f"[stream] {len(results)} queries @ {rate_qps:g} qps over "
              f"{stream.n_windows} windows, makespan {stream.now:.2f}s, "
              f"{hidden * 1e3:.1f} ms of stalls hidden | accepted="
              f"{report.accepted}, {in_flight} chunks drained mid-stream, "
              f"{stats['rows_inserted']} rows written")
        print(f"[stream] overall p50 {lat['p50'] * 1e3:.1f} ms | "
              f"p95 {lat['p95'] * 1e3:.1f} ms | "
              f"p99 {lat['p99'] * 1e3:.1f} ms")
    return dict(stream=stream, results=results, stats=stats, report=report,
                state=svc.kg.state, kg=svc.kg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--universities", type=int, default=10)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--experiment", type=int, default=1, choices=[1, 2])
    ap.add_argument("--partitioner", default="awapart",
                    choices=sorted(PARTITIONERS))
    ap.add_argument("--executor", default="numpy",
                    choices=["numpy", "jax", "jax-pallas"],
                    help="query backend (jax = batched execution, "
                         "jax-pallas = batched + Pallas join kernels)")
    ap.add_argument("--migration-budget", type=int, default=None,
                    help="bytes of migration traffic per serving window "
                         "(default: atomic commit)")
    ap.add_argument("--replica-budget", type=int, default=None,
                    help="bytes of read-replica copies the adaptation may "
                         "pin onto remote readers' shards (default: no "
                         "replication)")
    ap.add_argument("--writes-per-window", type=int, default=0,
                    help="synthetic live inserts ahead of every drain "
                         "window (repro.write; needs --migration-budget "
                         "to produce multiple windows)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous-admission serving demo (repro.stream) "
                         "instead of an experiment: open-loop replay with "
                         "writes and the migration drain in flight, "
                         "p50/p95/p99 tails per window")
    ap.add_argument("--arrival-rate", type=float, default=200.0,
                    help="open-loop arrival rate for --stream (queries/s)")
    ap.add_argument("--show-federated", action="store_true",
                    help="print a federated SPARQL rewrite example")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record repro.obs spans (per-query plan/scan/join/"
                         "federate/ship, windows, migration chunks, "
                         "adaptation rounds) and export a Chrome-trace JSON "
                         "(.jsonl for JSON-lines) to PATH")
    ap.add_argument("--metrics-csv", metavar="PATH", default=None,
                    help="dump the service's metrics-registry snapshot "
                         "(counters/gauges/histograms) as CSV to PATH")
    args = ap.parse_args()

    t0 = time.time()
    ds, svc = build_system(args.universities, args.shards,
                           partitioner=args.partitioner,
                           executor=args.executor,
                           migration_budget=args.migration_budget,
                           replica_budget=args.replica_budget,
                           trace=args.trace is not None)
    print(f"loaded LUBM({args.universities}): {ds.store.n_triples} triples "
          f"({time.time()-t0:.1f}s), {svc.space.n_features} features, "
          f"{args.shards} shards, strategy={svc.partitioner.name}, "
          f"executor={svc.executor.name}")
    if args.stream:
        out = stream_demo(ds, svc, args.arrival_rate,
                          writes_per_window=args.writes_per_window)
    elif args.experiment == 1:
        out = experiment1(ds, svc,
                          writes_per_window=args.writes_per_window)
    else:
        out = experiment2(ds, svc,
                          writes_per_window=args.writes_per_window)
    if args.show_federated:
        state = out["state"]
        q = ds.queries["Q9"]
        print("\nFederated rewrite of Q9 under the adapted partition:")
        print(rewrite.federated_sparql(q, svc.space, state, ds.dictionary,
                                       replicas=svc.kg.replicas))
    if args.trace:
        n = svc.tracer().export(args.trace)
        print(f"[obs] wrote {n} trace events to {args.trace}")
    if args.metrics_csv:
        svc.metrics.to_csv(args.metrics_csv)
        print(f"[obs] wrote metrics snapshot to {args.metrics_csv}")


if __name__ == "__main__":
    main()
