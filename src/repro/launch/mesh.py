"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests and benches must keep seeing the
single real CPU device; only the dry-run forces 512 placeholder devices.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
