"""End-to-end training driver.

Wires together: config -> mesh -> sharded init -> data pipeline (prefetch) ->
jitted train_step -> supervisor (async checkpoint / restore-on-failure /
straggler monitor) -> optional AWAPart expert-placement adaptation for MoE
archs.

On this CPU container it runs reduced configs (``--reduced``) for real; the
same driver lowers the full configs on the production mesh (see dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict

import jax
import numpy as np

import repro.configs as configs
from repro.data.pipeline import DataConfig, Prefetcher, make_stream
from repro.launch.mesh import dp_axes, make_host_mesh
from repro.models import lm
from repro.models.moe import ShardCtx
from repro.optim import AdamWConfig
from repro.runtime.resilience import SupervisorConfig, TrainSupervisor


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    metrics: Dict[str, float]


def build(arch: str, *, reduced: bool, batch: int, seq: int, steps: int,
          seed: int = 0, data_parallel: int = 1, model_parallel: int = 1,
          use_flash: bool = False):
    cfg = configs.get(arch)
    if reduced:
        cfg = cfg.reduced()
    if use_flash:
        cfg = dataclasses.replace(cfg, use_flash=True)
    mesh = make_host_mesh(data=data_parallel, model=model_parallel)
    ctx = ShardCtx(mesh=mesh, dp_axes=dp_axes(mesh))
    opt_cfg = AdamWConfig(total_steps=steps, warmup_steps=max(steps // 20, 5))

    key = jax.random.PRNGKey(seed)
    params, axes, opt_state = lm.init_all(key, cfg)

    data_cfg = DataConfig(seed=seed, global_batch=batch, seq_len=seq)
    stream = make_stream(cfg, data_cfg)

    step_fn = jax.jit(functools.partial(
        lm.train_step, cfg=cfg, ctx=ctx, opt_cfg=opt_cfg),
        donate_argnums=(0, 1))
    return cfg, mesh, ctx, params, opt_state, stream, step_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a node failure at this step (test)")
    args = ap.parse_args()

    cfg, mesh, ctx, params, opt_state, stream, step_fn = build(
        args.arch, reduced=args.reduced, batch=args.batch, seq=args.seq,
        steps=args.steps)
    prefetch = Prefetcher(stream)
    losses = []

    def one_step(state: TrainState, step: int) -> TrainState:
        if step == args.inject_failure_at and not getattr(
                one_step, "_failed", False):
            one_step._failed = True
            raise RuntimeError("injected node failure")
        _, batch_np = next(prefetch)
        batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(state.params, state.opt_state,
                                             batch)
        return TrainState(params, opt_state,
                          {k: float(v) for k, v in metrics.items()})

    sup = TrainSupervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        one_step,
        state_to_tree=lambda s: {"params": s.params, "opt": s.opt_state},
        tree_to_state=lambda tree, s: TrainState(tree["params"], tree["opt"],
                                                 s.metrics),
    )

    def on_metrics(step, state, dt):
        losses.append(state.metrics.get("loss", float("nan")))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {state.metrics['loss']:.4f} "
                  f"lr {state.metrics['lr']:.2e} {dt*1e3:.0f} ms")

    t0 = time.time()
    state = TrainState(params, opt_state, {})
    state = sup.run(state, args.steps, on_metrics=on_metrics)
    prefetch.close()
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s | "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} | "
          f"failures={sup.failures} restores={sup.restores} "
          f"stragglers={len(sup.monitor.flagged)}")


if __name__ == "__main__":
    main()
