"""Query & triple feature extraction — the paper's QueryAnalyzer (Sec. III.A).

Features identifying triples (and clustering queries):
  * ``P``  — all triples sharing predicate P,
  * ``PO`` — all triples sharing predicate P *and* object O.

Join-structure features (``SSJ``/``OOJ``/``OSJ``) are extracted per query and
feed the Fig.-5 scoring statistics, not the Jaccard bitmaps (per Fig. 1, the
Jaccard sets contain only P and PO features).

Every triple has exactly one *owner* feature — its PO feature if that (p, o)
pair is tracked, else its P feature. The partition maps owner features to
shards, so a feature's triples live in exactly one shard (no replication —
Sec. III.B). Tracked PO pairs are all ``rdf:type`` pairs plus any (p, o) pair
appearing as a constant-object pattern in the observed workload; tracking a
new PO feature *splits* it out of its parent P feature (adaptive granularity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.graph.triples import TripleStore
from repro.query.pattern import Query, is_var

FeatureKey = Tuple  # ("P", p) | ("PO", p, o)


class FeatureSpace:
    """Dense indexing of the feature universe over a dataset + workload."""

    def __init__(self, store: TripleStore, type_predicate: int | None = None):
        self.store = store
        self.type_predicate = type_predicate
        self._keys: List[FeatureKey] = []
        self._index: Dict[FeatureKey, int] = {}
        self._tracked_po: Dict[int, int] = {}   # packed (p, o) -> feature idx
        preds = np.unique(store.triples[:, 1])
        for p in preds.tolist():
            self._add(("P", int(p)))
        if type_predicate is not None:
            t = store.triples
            mask = t[:, 1] == type_predicate
            for o in np.unique(t[mask, 2]).tolist():
                self.track_po(type_predicate, int(o))

    # ------------------------------------------------------------------ #
    def _add(self, key: FeatureKey) -> int:
        idx = self._index.get(key)
        if idx is None:
            idx = len(self._keys)
            self._keys.append(key)
            self._index[key] = idx
        return idx

    @staticmethod
    def _pack(p: int, o: int) -> int:
        return (int(p) << 32) | int(o)

    def track_po(self, p: int, o: int) -> int:
        idx = self._add(("PO", int(p), int(o)))
        self._tracked_po[self._pack(p, o)] = idx
        return idx

    def track_p(self, p: int) -> int:
        """Ensure predicate ``p`` has a P feature, returning its index.
        The write path (``repro.write``) calls this when an insert carries a
        predicate the store has never seen — features are otherwise derived
        from the store's predicates at construction."""
        return self._add(("P", int(p)))

    def track_workload(self, queries: Iterable[Query]) -> List[int]:
        """Track every constant-object (p, o) pattern in the workload."""
        added = []
        for q in queries:
            for s, p, o in q.patterns:
                if not is_var(p) and not is_var(o):
                    added.append(self.track_po(p, o))
        return added

    # ------------------------------------------------------------------ #
    @property
    def n_features(self) -> int:
        return len(self._keys)

    def key(self, idx: int) -> FeatureKey:
        return self._keys[idx]

    def index_of(self, key: FeatureKey) -> int | None:
        """Feature index of a key, or None if untracked (the key-based
        translation ``repro.write.rebuild_from_scratch`` uses to map one
        space's universe onto another's)."""
        return self._index.get(tuple(key))

    def feature_keys(self) -> List[FeatureKey]:
        """All tracked keys, in feature-index order."""
        return list(self._keys)

    def p_index(self, p: int) -> int:
        return self._index[("P", int(p))]

    def po_index(self, p: int, o: int) -> int | None:
        return self._tracked_po.get(self._pack(p, o))

    def p_index_batch(self, p: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`p_index` over a predicate column: P-feature
        index per row, ``-1`` where the predicate is untracked (instead of
        the scalar method's KeyError). One sorted-key ``searchsorted``
        instead of a dict probe per row."""
        p = np.asarray(p, dtype=np.int64)
        out = np.full(p.shape, -1, dtype=np.int32)
        tracked = [(key[1], i) for i, key in enumerate(self._keys)
                   if key[0] == "P"]
        if tracked and len(p):
            tracked.sort()
            keys = np.array([k for k, _ in tracked], dtype=np.int64)
            vals = np.array([i for _, i in tracked], dtype=np.int32)
            pos = np.clip(np.searchsorted(keys, p), 0, len(keys) - 1)
            hit = keys[pos] == p
            out[hit] = vals[pos[hit]]
        return out

    def po_index_batch(self, p: np.ndarray, o: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`po_index` over (p, o) columns: tracked-PO
        feature index per row, ``-1`` where the pair is untracked. The
        batched half of the write path's routing (``repro.write``) — one
        packed-key ``searchsorted`` over the tracked-PO table for the whole
        batch."""
        p = np.asarray(p, dtype=np.int64)
        o = np.asarray(o, dtype=np.int64)
        out = np.full(p.shape, -1, dtype=np.int32)
        if self._tracked_po and len(p):
            packed = (p << 32) | o
            keys = np.array(sorted(self._tracked_po), dtype=np.int64)
            vals = np.array([self._tracked_po[k] for k in keys.tolist()],
                            dtype=np.int32)
            pos = np.clip(np.searchsorted(keys, packed), 0, len(keys) - 1)
            hit = keys[pos] == packed
            out[hit] = vals[pos[hit]]
        return out

    # ------------------------------------------------------------------ #
    def query_features(self, q: Query, *, fine: bool = True) -> np.ndarray:
        """The query's P/PO feature set as sorted unique indices.

        ``fine=False`` is the Fig.-1 clustering granularity: PO features only
        for ``rdf:type`` patterns, plain P otherwise (Q2 there counts its
        constant-object ``subOrganizationOf`` as a P feature). ``fine=True``
        is the ownership/scoring granularity: any tracked (p, o) pair."""
        feats = set()
        for s, p, o in q.patterns:
            if is_var(p):
                continue
            if not is_var(o) and (fine or p == self.type_predicate):
                po = self.po_index(p, o)
                feats.add(po if po is not None else self.p_index(p))
            else:
                feats.add(self.p_index(p))
        return np.array(sorted(feats), dtype=np.int32)

    def workload_bitmaps(self, queries: Sequence[Query],
                         n_features: int | None = None) -> np.ndarray:
        """Packed uint32 bitmaps, one row per query (input to Jaccard)."""
        nf = n_features or self.n_features
        n_words = (nf + 31) // 32
        out = np.zeros((len(queries), n_words), dtype=np.uint32)
        # |= with duplicate word indices needs np.bitwise_or.at
        for i, q in enumerate(queries):
            f = self.query_features(q, fine=False)   # Fig.-1 granularity
            np.bitwise_or.at(out[i], f // 32,
                             (np.uint32(1) << (f % 32).astype(np.uint32)))
        return out

    # ------------------------------------------------------------------ #
    def triple_owners(self) -> np.ndarray:
        """Owner feature index per triple, (N,) int32. Vectorized re-keying."""
        t = self.store.triples
        p = t[:, 1].astype(np.int64)
        o = t[:, 2].astype(np.int64)
        owner = self.p_index_batch(p)
        assert len(owner) == 0 or owner.min() >= 0, \
            "store carries a predicate with no P feature"
        po = self.po_index_batch(p, o)
        hit = po >= 0
        owner[hit] = po[hit]
        return owner

    def feature_sizes(self, owners: np.ndarray | None = None) -> np.ndarray:
        owners = self.triple_owners() if owners is None else owners
        return np.bincount(owners, minlength=self.n_features).astype(np.int64)


@dataclasses.dataclass
class QueryStats:
    """Per-query join structure used by the Fig.-5 scoring statistics."""
    features: np.ndarray          # P/PO feature idx per pattern (len = #patterns)
    join_edges: List[Tuple[int, int, str]]   # (pat_i, pat_j, SSJ|OOJ|OSJ)


def query_stats(q: Query, space: FeatureSpace) -> QueryStats:
    from repro.query.pattern import join_structure
    feats = []
    for s, p, o in q.patterns:
        if is_var(p):
            feats.append(-1)
            continue
        if not is_var(o):
            po = space.po_index(p, o)
            feats.append(po if po is not None else space.p_index(p))
        else:
            feats.append(space.p_index(p))
    return QueryStats(features=np.array(feats, dtype=np.int32),
                      join_edges=join_structure(q))
