"""AWAPartController — the complete Fig.-5 adaptive partitioning loop.

Pipeline per adaptation round (Sec. III.B, Fig. 5):
  1. merge new queries + frequencies into the workload (line 1),
  2. record the baseline average execution time T_base (line 2),
  3. extract features of the new queries (line 3) — newly-seen constant-object
     patterns become tracked PO features (ownership split, no data movement),
  4. Jaccard distance matrix over query bitmaps -> HAC -> query clusters at
     similarity distance d -> feature groups g (lines 4-5),
  5. score every key feature against every shard (lines 7-12) and assign the
     single copy to the argmax-score shard (line 14),
  6. proximity-assign unclustered features; bin-pack the rest for balance
     (lines 13, 16-23),
  7. measure T_new; accept the new partition only if it improves, else revert
     (lines 24-27).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import hac, migration
from repro.core.features import FeatureSpace
from repro.core.partition import (PartitionState, balanced_partition,
                                  greedy_balance)
from repro.core.scoring import (ScoreWeights, WorkloadStats,
                                distributed_joins, score_matrix,
                                workload_stats)
from repro.kernels.jaccard import ops as jaccard_ops
from repro.query.pattern import Query


@dataclasses.dataclass
class AdaptConfig:
    linkage: str = "single"          # the paper runs single linkage on LUBM
    cut_distance: float = 0.75       # initial partition: the paper-style manual dendrogram pick
    # beyond-paper: the right cut is workload-dependent (the paper reads it
    # off the dendrogram by hand); we extend the paper's own accept/revert
    # guard to SELECT it — each candidate cut yields a candidate partition,
    # the measured objective picks the winner, and the guard still protects
    # against regression. Empty tuple = single fixed cut_distance.
    cut_candidates: tuple = (0.45, 0.6, 0.75, 0.9)
    balance_tolerance: float = 1.15
    weights: ScoreWeights = dataclasses.field(default_factory=ScoreWeights)
    adapt_threshold: float = 1.25    # adapt when avg time degrades by 25%
    # migration-cost-aware accept guard: expected number of query executions
    # in the next TM window, over which the per-query savings must amortize
    # the migration traffic. None = estimate from the TM (observed execution
    # count, floored at the workload's total frequency).
    amortize_window: Optional[int] = None
    # read-replication budget (bytes of non-primary copies, repro.replicate):
    # each round promotes the hottest workload features onto the PPNs that
    # read them remotely and demotes replicas that fell cold, greedy under
    # this cap. Copy traffic counts toward the guard's migration cost;
    # replica-served shipping savings count toward its benefit. 0 = off.
    replica_budget: int = 0
    # write-rate term (repro.write): every extra replica copy of a feature
    # written w times per TM window costs w triple-payloads of recurring
    # fanout traffic per window. The accept guard adds that per-window
    # fanout delta (current map vs proposed map, priced at the network
    # bandwidth and scaled by this weight) to the benefit side, and the
    # replica proposal penalizes hot-written candidates by the same weight
    # — so a hot-written feature becomes cheaper to demote than to keep
    # replicated. 0 disables write-fanout pricing.
    write_cost_weight: float = 1.0
    # write-heat drift trigger (repro.stream / PR-6 headroom): should_adapt
    # fires on data drift alone — no query-time degradation needed — when
    # some feature accumulated at least ``write_drift_min_rows`` fresh rows
    # this TM window AND that fresh heat is at least ``write_drift_ratio``
    # of the feature's current size (churn comparable to the feature
    # itself). A round (accepted or not) consumes the signal, so a rejected
    # round cannot re-trigger on the same writes. min_rows <= 0 disables.
    write_drift_ratio: float = 0.5
    write_drift_min_rows: int = 64


@dataclasses.dataclass
class AdaptReport:
    accepted: bool
    plan: migration.MigrationPlan
    dj_before: float
    dj_after: float
    t_base: Optional[float] = None
    t_new: Optional[float] = None
    n_clusters: int = 0
    chosen_cut: float = 0.0
    migration_s: float = 0.0         # modeled traffic time of the plan
    amortize_window: int = 0         # TM window the guard amortized over
    replicas: Optional[object] = None  # accepted target ReplicaMap (or None)
    replica_bytes: int = 0           # non-primary copy bytes under the target
    # expected replica write-fanout traffic per TM window (bytes) under the
    # layout the round returned — observed write heat x extra copies
    fanout_bytes: int = 0
    # why the guard accepted/rejected: "amortized" (savings paid for the
    # migration), "improved" (t_new < t_base, no traffic to price),
    # "unamortized" (gain too small for the journey), "no_gain",
    # "dj_improved"/"dj_no_gain" (measureless distributed-join guard)
    reason: str = ""
    # per-feature workload heat of this round (repr-suppressed array) — the
    # chunk priority, computed once here and reused by the session builder
    heat: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)


def _accepts_replicas(measure: Callable) -> bool:
    """Can ``measure`` price a replicated candidate — i.e. accept a
    keyword ``replicas`` argument? Custom objectives without one predate
    replication and must keep working (the round then prices primary-only
    and leaves the served replicas untouched). Detection is by parameter
    *name* and the argument is always passed by keyword, so an unrelated
    second positional parameter never receives a ReplicaMap."""
    try:
        params = inspect.signature(measure).parameters
    except (TypeError, ValueError):       # builtins/C callables: assume yes
        return True
    if any(p.kind is p.VAR_KEYWORD for p in params.values()):
        return True
    p = params.get("replicas")
    return p is not None and p.kind in (p.POSITIONAL_OR_KEYWORD,
                                        p.KEYWORD_ONLY)


class AWAPartController:
    """Master-node control plane: QAFE + PM + HAC + PMeta (Fig. 6)."""

    def __init__(self, space: FeatureSpace, n_shards: int,
                 config: AdaptConfig | None = None):
        self.space = space
        self.n_shards = n_shards
        self.config = config or AdaptConfig()
        self.workload: Dict[str, Query] = {}
        self.exec_times: Dict[str, List[float]] = {}     # TM metadata
        self.state: Optional[PartitionState] = None
        self._baseline_avg: Optional[float] = None
        # per-feature write touches this TM window (repro.write): the
        # data-drift signal — feeds the guard's fanout pricing and the
        # replica proposal's demotion penalty; cleared with the window
        self.write_heat = np.zeros(space.n_features, dtype=np.float64)
        # write heat already consumed by an adaptation round this window —
        # a rejected round marks its heat seen instead of clearing it (the
        # fanout pricing still wants the full window's heat), so the drift
        # trigger only ever fires on writes no round has judged yet
        self._drift_seen = np.zeros(space.n_features, dtype=np.float64)

    # ------------------------------------------------------------------ #
    # workload bookkeeping (QAFE + TM)
    # ------------------------------------------------------------------ #
    def observe(self, query: Query, runtime: float) -> None:
        self.workload[query.name] = query
        self.exec_times.setdefault(query.name, []).append(runtime)

    def avg_execution_time(self) -> float:
        """Fig.-5 line 2: mean over queries of their mean runtime."""
        per_q = [np.mean(v) for v in self.exec_times.values() if v]
        return float(np.mean(per_q)) if per_q else 0.0

    def should_adapt(self) -> bool:
        # data drift alone is a trigger: a churn-hot feature no longer waits
        # for the next query-driven degradation to relocate (repro.write
        # feeds the heat, the round's fanout pricing + chunk priority
        # consume it)
        if self.write_drift():
            return True
        # no baseline yet: adapt on the first *observed* degradation signal —
        # an empty TM (fresh session, zero queries served) must not trigger a
        # pointless round
        if self._baseline_avg is None:
            return any(self.exec_times.values())
        cur = self.avg_execution_time()
        return cur > self.config.adapt_threshold * self._baseline_avg

    def write_drift(self) -> bool:
        """True when some feature's *fresh* write heat (rows written this
        TM window and not yet judged by a round) clears both drift
        thresholds: at least ``write_drift_min_rows`` rows, and at least
        ``write_drift_ratio`` of the feature's current size."""
        cfg = self.config
        min_rows = int(getattr(cfg, "write_drift_min_rows", 0) or 0)
        if min_rows <= 0 or self.state is None or not len(self.write_heat):
            return False
        wh = self.write_heat
        seen = self._drift_seen
        if len(seen) < len(wh):
            seen = np.pad(seen, (0, len(wh) - len(seen)))
        fresh = wh - seen
        hot = fresh >= min_rows
        if not hot.any():
            return False
        sizes = self.state.feature_sizes.astype(np.float64)
        if len(sizes) < len(wh):
            sizes = np.pad(sizes, (0, len(wh) - len(sizes)))
        ratio = float(getattr(cfg, "write_drift_ratio", 0.0))
        return bool((hot & (fresh >= ratio * np.maximum(sizes[:len(wh)],
                                                        1.0))).any())

    def reset_baseline(self, value: Optional[float] = None) -> None:
        """Set (or clear, with None) the T_base reference of Fig.-5 line 2.

        Clearing forces the next ``should_adapt`` to fire; setting it to the
        post-migration average starts a fresh monitoring window."""
        self._baseline_avg = value

    def clear_window(self) -> None:
        """Restart the TM window: runtime observations and write heat both
        describe exactly one serving window, so whoever restarts the window
        (accepted round, finished drain) clears them together."""
        self.exec_times.clear()
        if len(self.write_heat):
            self.write_heat[:] = 0.0
        if len(self._drift_seen):
            self._drift_seen[:] = 0.0

    def note_writes(self, report) -> None:
        """Fold an applied ``repro.write.WriteReport`` into this window's
        data-drift signal.

        Features born on the write path (new predicates / new ``rdf:type``
        classes) join the tracked state at the placement the facade chose —
        keeping ``self.state`` aligned with the grown universe so the next
        round's ``extend_for_space`` and migration planning stay
        length-consistent. Each written feature's heat accumulates the rows
        written; sizes are re-derived from the space at round time."""
        if self.state is not None:
            for fid, _key, shard in report.new_features:
                if fid == len(self.state.feature_to_shard):
                    self.state = PartitionState(
                        np.append(self.state.feature_to_shard,
                                  np.int32(shard)),
                        np.append(self.state.feature_sizes, np.int64(0)),
                        self.state.n_shards)
        if len(self.write_heat) < self.space.n_features:
            self.write_heat = np.pad(
                self.write_heat,
                (0, self.space.n_features - len(self.write_heat)))
        for f, c in report.feature_writes.items():
            if f < len(self.write_heat):
                self.write_heat[f] += c

    # ------------------------------------------------------------------ #
    # clustering (lines 4-5)
    # ------------------------------------------------------------------ #
    def cluster_queries(self, queries: Sequence[Query],
                        cut: Optional[float] = None) -> np.ndarray:
        bitmaps = self.space.workload_bitmaps(queries)
        dist = np.asarray(jaccard_ops.jaccard_distance(bitmaps))
        z = hac.hac_numpy(dist, self.config.linkage)
        return hac.cut(z, cut if cut is not None
                       else self.config.cut_distance)

    def feature_groups(self, queries: Sequence[Query],
                       labels: np.ndarray) -> List[np.ndarray]:
        groups = []
        for lbl in np.unique(labels):
            feats: set = set()
            for q, l in zip(queries, labels):
                if l == lbl:
                    feats.update(self.space.query_features(q).tolist())
            groups.append(np.array(sorted(feats), dtype=np.int32))
        return groups

    # ------------------------------------------------------------------ #
    # assignment (lines 7-23)
    # ------------------------------------------------------------------ #
    def _assign(self, queries: Sequence[Query], base: PartitionState,
                cut: Optional[float] = None,
                ) -> Tuple[PartitionState, WorkloadStats, int]:
        """Lines 6–23: place feature groups (query clusters) as units, under a
        hard balance cap; oversized groups degrade to per-feature placement."""
        stats = workload_stats(queries, self.space)
        new = base.copy()
        labels = self.cluster_queries(queries, cut)
        groups = self.feature_groups(queries, labels)
        sizes = new.feature_sizes.astype(np.int64)
        total = max(int(sizes.sum()), 1)
        cap = self.config.balance_tolerance * total / self.n_shards

        # resolve feature->group overlaps by frequency weight of the cluster
        feat_group: Dict[int, int] = {}
        gweight = np.zeros(len(groups))
        for gi, lbl in enumerate(np.unique(labels)):
            gweight[gi] = sum(q.frequency for q, l in zip(queries, labels)
                              if l == lbl)
        for gi in np.argsort(-gweight).tolist():
            for f in groups[gi].tolist():
                feat_group.setdefault(f, gi)
        members = [np.array([f for f, g in feat_group.items() if g == gi],
                            dtype=np.int64) for gi in range(len(groups))]

        # loads excluding the features we are about to (re)place
        key_set = np.zeros(len(sizes), bool)
        key_set[list(feat_group.keys())] = True
        loads = np.bincount(new.feature_to_shard[~key_set],
                            weights=sizes[~key_set],
                            minlength=self.n_shards).astype(np.float64)

        ki_of = {int(k): i for i, k in enumerate(stats.key_features)}
        # place heaviest (size × frequency) groups first
        order = np.argsort(-(np.array([sizes[m].sum() for m in members])
                             * np.maximum(gweight, 1e-9)))
        for gi in order.tolist():
            mem = members[gi]
            if len(mem) == 0:
                continue
            scores = score_matrix(stats, new, self.config.weights)
            gsize = float(sizes[mem].sum())
            rows = [ki_of[int(f)] for f in mem if int(f) in ki_of]
            gscore = (scores[rows].sum(0) if rows
                      else np.zeros(self.n_shards))
            fits = loads + gsize <= cap
            if fits.any():          # group placed as a unit
                cand = np.where(fits, gscore, -np.inf)
                dst = int(np.argmax(cand))
                new.feature_to_shard[mem] = dst
                loads[dst] += gsize
            else:                    # oversized: per-feature, big first
                for f in mem[np.argsort(-sizes[mem])].tolist():
                    fs = float(sizes[f])
                    row = (scores[ki_of[int(f)]] if int(f) in ki_of
                           else np.zeros(self.n_shards))
                    ok = loads + fs <= cap
                    dst = (int(np.argmax(np.where(ok, row, -np.inf)))
                           if ok.any() else int(np.argmin(loads)))
                    new.feature_to_shard[f] = dst
                    loads[dst] += fs
        # proximity + balance for non-workload features (lines 16-23)
        movable = np.arange(len(sizes))[~key_set]
        greedy_balance(new, movable, self.config.balance_tolerance)
        return new, stats, len(groups)

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def initial_partition(self, queries: Sequence[Query]) -> PartitionState:
        """WawPart-style initial workload-aware partition ([21])."""
        for q in queries:
            self.workload[q.name] = q
        # start from round-robin by size (balanced, workload-agnostic) ...
        base = balanced_partition(self.space.feature_sizes(), self.n_shards)
        # ... then pull workload features together
        state, _, _ = self._assign(list(self.workload.values()), base)
        self.state = state
        return state

    def _expected_window(self, queries: Sequence[Query]) -> int:
        """Expected query executions in the next TM window — what the
        migration-cost guard amortizes the plan's traffic over. Configured
        (``amortize_window``) or estimated: the observed TM execution count,
        floored at the workload's total frequency (every workload query runs
        at least once per window)."""
        if self.config.amortize_window is not None:
            return int(self.config.amortize_window)
        observed = sum(len(v) for v in self.exec_times.values())
        expected = sum(q.frequency for q in queries)
        return int(max(observed, expected))

    def adapt(self, new_queries: Sequence[Query],
              measure: Optional[Callable[[PartitionState], float]] = None,
              net=None, replicas=None) -> Tuple[PartitionState, AdaptReport]:
        """One Fig.-5 adaptation round. ``measure`` returns the average
        workload execution time under a candidate partition (used for the
        accept/revert guard); if None, the frequency-weighted distributed
        join count is the guard objective.

        The line-24 guard is migration-cost-aware when ``net`` (a
        ``NetworkModel``-like object) is given alongside ``measure``: the
        destination layout is accepted only if the modeled per-query savings,
        amortized over the expected TM window (``_expected_window``), pay for
        shipping ``plan.bytes`` of migration traffic — pricing the *journey*,
        not just the destination.

        ``replicas`` (the live ``repro.replicate.ReplicaMap``) switches the
        round replica-aware: the winning layout gets a fresh replica proposal
        (hottest features promoted under ``config.replica_budget``, cold
        replicas demoted), ``measure`` is called as ``measure(cand, rmap)``
        to price the replicated destination, and the plan's bytes include
        the copy traffic — so the guard weighs replica cost against
        replica-served savings. The accepted target map is returned as
        ``report.replicas``."""
        assert self.state is not None, "call initial_partition first"
        cfg = self.config
        if replicas is not None and measure is not None \
                and not _accepts_replicas(measure):
            replicas = None       # replica-unaware custom objective: price
            #                       primary-only, leave served copies alone
        for q in new_queries:                        # line 1
            self.workload[q.name] = q
        queries = list(self.workload.values())

        # line 2 — T_base under the layout actually being served (including
        # its current read replicas, if any)
        t_base = None
        if measure:
            t_base = (measure(self.state, replicas=replicas)
                      if replicas is not None and replicas.has_replicas
                      else measure(self.state))
        self._baseline_avg = t_base if t_base is not None else self._baseline_avg

        # line 3: track new PO features; ownership split grows the universe
        self.space.track_workload(queries)
        cur, _ = migration.extend_for_space(self.state, self.space)
        if replicas is not None:
            # plan over the grown universe: new (split) PO features start
            # primary-only on their inherited shard, like the facade's view
            replicas = replicas.copy()
            replicas.extend(cur.feature_to_shard)

        # lines 4-23, once per candidate cut; the measured objective picks
        # the winning candidate (beyond-paper extension of the line-24 guard)
        cuts = self.config.cut_candidates or (self.config.cut_distance,)
        best = None
        for cut in cuts:
            cand, stats, ncl = self._assign(queries, cur, cut=cut)
            obj = measure(cand) if measure else distributed_joins(stats, cand)
            if best is None or obj < best[0]:
                best = (obj, cand, stats, cut, ncl)
        obj_new, new, stats, chosen_cut, n_clusters = best

        # per-feature workload heat over the grown universe: the replica
        # promotion order here AND the session's chunk priority (via the
        # report) — computed exactly once per round
        heat = migration.feature_heat(self.space, queries)

        # write heat over the grown universe (repro.write): rows written to
        # each feature this TM window, scaled by the config's write-rate
        # weight — priced wherever a replica copy would have to receive them
        wh = self.write_heat
        if len(wh) < self.space.n_features:
            wh = np.pad(wh, (0, self.space.n_features - len(wh)))
        wh = wh * float(getattr(cfg, "write_cost_weight", 1.0))

        def _fanout_bytes(rmap) -> int:
            """Expected per-window write-fanout traffic under a replica map:
            every extra copy of a feature receives its writes too."""
            if rmap is None or not rmap.has_replicas or not wh.any():
                return 0
            extra = np.maximum(rmap.n_copies() - 1, 0)
            return int((extra * wh[:len(extra)]).sum()
                       * migration.TRIPLE_BYTES)

        # replica promotion/demotion for the winning layout: hottest
        # workload features onto their remote readers' PPNs, greedy under
        # the byte budget; features not re-proposed are demoted. Hot-written
        # features are penalized by their write heat — a copy whose
        # recurring fanout outweighs its read savings is not proposed, which
        # is exactly how a hot-written replica becomes a demotion candidate.
        rmap_new = None
        if replicas is not None:
            from repro import replicate
            rmap_new = replicate.propose_replicas(
                self.space, new, queries,
                int(getattr(cfg, "replica_budget", 0) or 0), heat=heat,
                write_heat=wh if wh.any() else None)

        dj_before = distributed_joins(stats, cur)
        dj_after = distributed_joins(stats, new)
        mplan = migration.plan(cur, new, replicas, rmap_new)

        t_new = obj_new if measure else None                 # line 24
        if measure and rmap_new is not None and rmap_new.has_replicas:
            # replica-served savings enter the benefit side of the guard
            t_new = measure(new, replicas=rmap_new)
        migration_s = 0.0
        window = 0
        fan_base = _fanout_bytes(replicas)
        fan_new = _fanout_bytes(rmap_new) if rmap_new is not None \
            else fan_base
        if measure:
            gain = t_base - t_new
            if net is not None and (mplan.n_moves or mplan.n_replica_ops):
                # migration-cost-aware guard: the destination must amortize
                # the cost of getting there (moves AND replica copies) over
                # the expected TM window. The write-fanout delta is a
                # RECURRING per-window cost/saving entering the benefit side
                # directly: dropping a hot-written copy saves its fanout
                # every window from now on, keeping one keeps paying it.
                migration_s = migration.migration_seconds(mplan, net)
                window = self._expected_window(queries)
                fan_gain_s = (fan_base - fan_new) / net.bandwidth_Bps
                benefit = gain * window + fan_gain_s
                # window == 0 means nothing to amortize over: savings can
                # never pay for a positive migration cost, so reject
                accepted = benefit > 0 and benefit >= migration_s
                reason = ("amortized" if accepted
                          else "no_gain" if benefit <= 0 else "unamortized")
            else:
                accepted = t_new < t_base                    # lines 25-27
                reason = "improved" if accepted else "no_gain"
        else:
            accepted = dj_after < dj_before
            reason = "dj_improved" if accepted else "dj_no_gain"
        if accepted:
            self.state = new
        else:
            self.state = cur
            mplan = migration.MigrationPlan([], 0, 0)
            rmap_new = None                # served replicas stay as they are
        # the round judged this window's write heat either way — mark it
        # consumed so a rejected round can't re-trigger the drift signal on
        # the same writes (an accepted round's clear_window resets both)
        if len(self.write_heat) < self.space.n_features:
            self.write_heat = np.pad(
                self.write_heat,
                (0, self.space.n_features - len(self.write_heat)))
        self._drift_seen = self.write_heat.copy()
        return self.state, AdaptReport(
            accepted=accepted, plan=mplan, dj_before=dj_before,
            dj_after=dj_after, t_base=t_base, t_new=t_new,
            n_clusters=n_clusters, chosen_cut=chosen_cut,
            migration_s=migration_s, amortize_window=window,
            replicas=rmap_new,
            # chunk priority = read heat + write heat: a churn-hot feature
            # should reach its destination as early as a read-hot one
            heat=heat + wh,
            replica_bytes=(rmap_new.replica_bytes(new.feature_sizes)
                           if rmap_new is not None else 0),
            fanout_bytes=fan_new if accepted else fan_base,
            reason=reason)
