"""Hierarchical agglomerative clustering (Fig. 4) — single/complete/average linkage.

Two implementations with identical semantics:
  * ``hac_numpy`` — host-side reference (scipy-compatible merge list),
  * ``hac_jax``   — jit-able ``lax.fori_loop`` version over a padded distance
    matrix, so clustering can run on-device inside the adaptation step.

Merges use Lance–Williams updates. Output is a scipy-style ``Z`` matrix
(n-1, 4): [cluster_a, cluster_b, distance, new_size] with original leaves
0..n-1 and merged cluster k getting id n+k. ``cut(Z, d)`` yields flat labels
(the paper's "feature set g based on HAC at similarity distance d", Fig. 5
line 5).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

LINKAGES = ("single", "complete", "average")


def _lw_update(d_ki: np.ndarray, d_kj: np.ndarray, n_i: float, n_j: float,
               linkage: str):
    if linkage == "single":
        return np.minimum(d_ki, d_kj)
    if linkage == "complete":
        return np.maximum(d_ki, d_kj)
    if linkage == "average":
        return (n_i * d_ki + n_j * d_kj) / (n_i + n_j)
    raise ValueError(f"unknown linkage {linkage!r}")


def hac_numpy(dist: np.ndarray, linkage: str = "single") -> np.ndarray:
    """(n, n) symmetric distance matrix -> (n-1, 4) merge matrix Z."""
    assert linkage in LINKAGES
    d = np.array(dist, dtype=np.float64)
    n = d.shape[0]
    np.fill_diagonal(d, np.inf)
    active = np.ones(n, dtype=bool)
    sizes = np.ones(n)
    ids = np.arange(n)          # scipy-style cluster id held by each slot
    z = np.zeros((max(n - 1, 0), 4))
    for step in range(n - 1):
        masked = np.where(active[:, None] & active[None, :], d, np.inf)
        i, j = np.unravel_index(np.argmin(masked), masked.shape)
        if i > j:
            i, j = j, i
        dij = masked[i, j]
        z[step] = (min(ids[i], ids[j]), max(ids[i], ids[j]), dij,
                   sizes[i] + sizes[j])
        # merge j into slot i
        new_row = _lw_update(d[i], d[j], sizes[i], sizes[j], linkage)
        d[i, :] = new_row
        d[:, i] = new_row
        d[i, i] = np.inf
        active[j] = False
        sizes[i] += sizes[j]
        ids[i] = n + step
    return z


@functools.partial(jax.jit, static_argnames=("linkage",))
def hac_jax(dist: jnp.ndarray, linkage: str = "single") -> jnp.ndarray:
    """Jit-able HAC; same Z semantics as :func:`hac_numpy`."""
    assert linkage in LINKAGES
    n = dist.shape[0]
    big = jnp.float32(jnp.inf)
    d0 = jnp.asarray(dist, jnp.float32)
    d0 = d0.at[jnp.arange(n), jnp.arange(n)].set(big)

    def body(step, carry):
        d, active, sizes, ids, z = carry
        pair_ok = active[:, None] & active[None, :]
        masked = jnp.where(pair_ok, d, big)
        flat = jnp.argmin(masked)
        i0, j0 = flat // n, flat % n
        i = jnp.minimum(i0, j0)
        j = jnp.maximum(i0, j0)
        dij = masked[i, j]
        z = z.at[step].set(jnp.stack([
            jnp.minimum(ids[i], ids[j]).astype(jnp.float32),
            jnp.maximum(ids[i], ids[j]).astype(jnp.float32),
            dij, sizes[i] + sizes[j]]))
        di, dj = d[i], d[j]
        if linkage == "single":
            new_row = jnp.minimum(di, dj)
        elif linkage == "complete":
            new_row = jnp.maximum(di, dj)
        else:
            new_row = (sizes[i] * di + sizes[j] * dj) / (sizes[i] + sizes[j])
        d = d.at[i, :].set(new_row).at[:, i].set(new_row).at[i, i].set(big)
        active = active.at[j].set(False)
        sizes = sizes.at[i].add(sizes[j])
        ids = ids.at[i].set(n + step)
        return d, active, sizes, ids, z

    init = (d0, jnp.ones(n, bool), jnp.ones(n, jnp.float32),
            jnp.arange(n, dtype=jnp.int32),
            jnp.zeros((max(n - 1, 0), 4), jnp.float32))
    _, _, _, _, z = jax.lax.fori_loop(0, n - 1, body, init)
    return z


def cut(z: np.ndarray, distance: float) -> np.ndarray:
    """Flat cluster labels from Z, merging every row with dist <= distance."""
    z = np.asarray(z)
    m = z.shape[0]
    n = m + 1
    parent = np.arange(n + m)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for step in range(m):
        a, b, dist, _ = z[step]
        new_id = n + step
        if dist <= distance:
            parent[find(int(a))] = new_id
            parent[find(int(b))] = new_id
    roots = np.array([find(i) for i in range(n)])
    _, labels = np.unique(roots, return_inverse=True)
    return labels.astype(np.int32)
