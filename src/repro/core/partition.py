"""Partition metadata (the paper's PMeta): feature -> shard ownership."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class PartitionState:
    """Assignment of every owner feature to a shard (single copy, no replication)."""

    feature_to_shard: np.ndarray      # (F,) int32
    feature_sizes: np.ndarray         # (F,) int64 triples per feature
    n_shards: int

    def copy(self) -> "PartitionState":
        return PartitionState(self.feature_to_shard.copy(),
                              self.feature_sizes.copy(), self.n_shards)

    def shard_sizes(self) -> np.ndarray:
        return np.bincount(self.feature_to_shard, weights=self.feature_sizes,
                           minlength=self.n_shards).astype(np.int64)

    def imbalance(self) -> float:
        """max/mean shard size — 1.0 is perfectly balanced."""
        sizes = self.shard_sizes()
        mean = sizes.mean()
        return float(sizes.max() / mean) if mean > 0 else 1.0

    def triple_shards(self, owners: np.ndarray) -> np.ndarray:
        """Shard id per triple given owner-feature ids."""
        return self.feature_to_shard[owners]

    def features_on(self, shard: int) -> np.ndarray:
        return np.where(self.feature_to_shard == shard)[0]


def hash_partition(feature_sizes: np.ndarray, n_shards: int,
                   seed: int = 0) -> PartitionState:
    """Baseline: feature-hash partitioning (what non-workload-aware systems do)."""
    rng = np.random.default_rng(seed)
    f2s = rng.integers(0, n_shards, size=len(feature_sizes), dtype=np.int32)
    return PartitionState(f2s, np.asarray(feature_sizes, np.int64), n_shards)


def balanced_partition(feature_sizes: np.ndarray,
                       n_shards: int) -> PartitionState:
    """Workload-agnostic LPT round-robin: biggest feature to the least-loaded
    shard. The starting point both WawPart and AWAPart refine."""
    sizes = np.asarray(feature_sizes, np.int64)
    f2s = np.zeros(len(sizes), dtype=np.int32)
    shard_load = np.zeros(n_shards, dtype=np.int64)
    for f in np.argsort(-sizes).tolist():
        dst = int(np.argmin(shard_load))
        f2s[f] = dst
        shard_load[dst] += sizes[f]
    return PartitionState(f2s, sizes, n_shards)


def greedy_balance(state: PartitionState, movable: np.ndarray,
                   tolerance: float = 1.10) -> List[tuple]:
    """Fig.-5 lines 20–23: repeatedly move the largest movable feature from the
    largest shard into the smallest shard until within tolerance.

    Returns the list of (feature, src, dst) moves applied in place.
    """
    moves: List[tuple] = []
    movable_set = set(movable.tolist())
    for _ in range(10_000):
        sizes = state.shard_sizes()
        if sizes.max() <= tolerance * max(sizes.mean(), 1.0):
            break
        src = int(np.argmax(sizes))
        dst = int(np.argmin(sizes))
        feats = [f for f in state.features_on(src).tolist() if f in movable_set]
        if not feats:
            break
        gap = (sizes[src] - sizes[dst]) / 2
        fsz = state.feature_sizes[feats]
        # biggest feature that does not overshoot the midpoint (else smallest)
        ok = np.where(fsz <= gap)[0]
        pick = feats[int(ok[np.argmax(fsz[ok])])] if len(ok) else \
            feats[int(np.argmin(fsz))]
        if state.feature_sizes[pick] == 0:
            movable_set.discard(pick)
            continue
        state.feature_to_shard[pick] = dst
        moves.append((pick, src, dst))
        movable_set.discard(pick)
        if not movable_set:
            break
    return moves
