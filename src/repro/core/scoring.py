"""Fig.-5 scoring: key-feature statistics S_K and shard scores.

The paper's pseudo-code (lines 7–12) scores every key feature F_K against
every shard:

    S_K(c)     = p_c*w1 + q_c*w2 + s_c*w3 + p_t*w4 + q_t*w5 + s_t*w6
    Score(F_K, c) = [colocated-join gain](c) * w_dj * f   +   S_K(c)

with the statistics (Sec. III.B, "The statistics use other feature patterns,
such as SSJ, OOJ and OSJ and distributed joins in queries"):

  p — peer features: features adjacent to F_K through a join edge
      (SSJ/OOJ/OSJ) in some workload query. ``p_c`` counts peers already
      resident on shard c; ``p_t`` is the total number of distinct peers.
  q — out-degree (hops): join edges leaving F_K's patterns. ``q_c`` weights
      each query's out-degree by the fraction of its features on shard c;
      ``q_t`` is the frequency-weighted total.
  s — triple-size ratio of F_K within shard c (``s_c``) and within the whole
      dataset (``s_t``).

The distributed-join term: D(F_K, c) = frequency-weighted number of join
edges incident to F_K whose peer feature is *not* on shard c. The paper keeps
``min(D_QR)``; equivalently we add the *gain* ``max_c' D - D(c)`` so the
argmax-score shard is the min-distributed-join shard, with S_K refining ties.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.features import FeatureSpace, query_stats
from repro.core.partition import PartitionState
from repro.query.pattern import Query


@dataclasses.dataclass
class ScoreWeights:
    w1: float = 1.0      # peers in shard
    w2: float = 0.5      # out-degree share in shard
    w3: float = 2.0      # size ratio in shard
    w4: float = 0.1      # total peers
    w5: float = 0.1      # total out-degree
    w6: float = 0.1      # total size ratio
    w_dj: float = 10.0   # distributed-join gain weight


@dataclasses.dataclass
class WorkloadStats:
    """Join-structure statistics for a workload, keyed by feature index."""
    key_features: np.ndarray                  # (K,) feature idx in workload
    peers: Dict[int, set]                     # feature -> peer feature set
    out_degree: Dict[int, float]              # feature -> freq-weighted degree
    feature_freq: Dict[int, float]            # feature -> summed query frequency
    join_edges: List[tuple]                   # (feat_a, feat_b, freq, kind)


def workload_stats(queries: Sequence[Query], space: FeatureSpace) -> WorkloadStats:
    peers: Dict[int, set] = {}
    out_degree: Dict[int, float] = {}
    feature_freq: Dict[int, float] = {}
    join_edges: List[tuple] = []
    keys: set = set()
    for q in queries:
        st = query_stats(q, space)
        for f in st.features:
            if f >= 0:
                keys.add(int(f))
                feature_freq[int(f)] = feature_freq.get(int(f), 0.0) + q.frequency
        for i, j, kind in st.join_edges:
            fa, fb = int(st.features[i]), int(st.features[j])
            if fa < 0 or fb < 0:
                continue
            join_edges.append((fa, fb, q.frequency, kind))
            for a, b in ((fa, fb), (fb, fa)):
                peers.setdefault(a, set()).add(b)
                out_degree[a] = out_degree.get(a, 0.0) + q.frequency
    return WorkloadStats(
        key_features=np.array(sorted(keys), dtype=np.int32),
        peers=peers, out_degree=out_degree, feature_freq=feature_freq,
        join_edges=join_edges)


def distributed_joins(stats: WorkloadStats, state: PartitionState) -> float:
    """Frequency-weighted count of join edges crossing shard boundaries."""
    total = 0.0
    f2s = state.feature_to_shard
    for fa, fb, freq, _ in stats.join_edges:
        if f2s[fa] != f2s[fb]:
            total += freq
    return total


def score_matrix(stats: WorkloadStats, state: PartitionState,
                 weights: ScoreWeights | None = None) -> np.ndarray:
    """(K, n_shards) score for each key feature on each candidate shard."""
    w = weights or ScoreWeights()
    keys = stats.key_features
    n_sh = state.n_shards
    f2s = state.feature_to_shard
    sizes = state.feature_sizes.astype(np.float64)
    shard_sz = np.maximum(state.shard_sizes().astype(np.float64), 1.0)
    total_sz = max(sizes.sum(), 1.0)

    scores = np.zeros((len(keys), n_sh))
    for ki, k in enumerate(keys.tolist()):
        peer_list = list(stats.peers.get(k, ()))
        peer_shards = f2s[peer_list] if peer_list else np.empty(0, np.int64)
        p_t = float(len(peer_list))
        q_t = stats.out_degree.get(k, 0.0)
        s_t = float(sizes[k]) / total_sz
        freq = stats.feature_freq.get(k, 1.0)

        # distributed joins of k per candidate shard
        dj = np.zeros(n_sh)
        for fa, fb, f_q, _ in stats.join_edges:
            if fa == k and fb != k:
                dj += f_q * (np.arange(n_sh) != f2s[fb])
            elif fb == k and fa != k:
                dj += f_q * (np.arange(n_sh) != f2s[fa])
        dj_gain = dj.max() - dj   # max at the min-distributed-join shard

        for c in range(n_sh):
            p_c = float((peer_shards == c).sum())
            q_c = q_t * (p_c / max(p_t, 1.0))
            s_c = float(sizes[k]) / shard_sz[c]
            s_k = (p_c * w.w1 + q_c * w.w2 + s_c * w.w3
                   + p_t * w.w4 + q_t * w.w5 + s_t * w.w6)
            scores[ki, c] = dj_gain[c] * w.w_dj * freq + s_k
    return scores
