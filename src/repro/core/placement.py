"""AWAPart applied inside the LM framework: workload-aware expert & vocab placement.

The mapping from the paper's domain (Sec. 2b of DESIGN.md):

    SPARQL query            ->  request (sequence) routed through a MoE layer
    P/PO feature            ->  expert
    feature co-occurrence   ->  expert co-activation (same request, same layer)
    triples of a feature    ->  the expert's weight tensors
    shard                   ->  expert-parallel rank (``model`` axis)
    distributed join        ->  extra all-to-all destination rank per token
    triple migration        ->  expert weight permutation between ranks
    accept/revert guard     ->  measured avg distinct-ranks-per-token objective

Rank-granularity dispatch (``moe_dispatch="rank"``) ships each token once per
distinct destination rank, so clustering co-activated experts onto the same
rank cuts all-to-all bytes exactly the way co-locating a query's features
cuts distributed joins.

Vocab placement: token co-occurrence drives a vocabulary permutation that
balances hot embedding rows across the ``model`` shards (the paper's balance
constraint, applied to the embedding gather load).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import hac
from repro.kernels.jaccard import ops as jaccard_ops


# --------------------------------------------------------------------------- #
# expert placement
# --------------------------------------------------------------------------- #

def coactivation_bitmaps(routing: np.ndarray, n_experts: int,
                         n_requests: int) -> np.ndarray:
    """routing: (n_requests, k) expert ids per request (one MoE layer).

    Returns packed uint32 bitmaps (n_experts, ceil(n_requests/32)): expert e's
    bitmap marks the requests that activated it — the transpose of the KG
    case (features described by the queries that touch them)."""
    words = (n_requests + 31) // 32
    bm = np.zeros((n_experts, words), dtype=np.uint32)
    for r in range(routing.shape[0]):
        for e in np.unique(routing[r]):
            bm[e, r // 32] |= np.uint32(1) << np.uint32(r % 32)
    return bm


def cluster_experts(bitmaps: np.ndarray, *, linkage: str = "average",
                    cut_distance: float = 0.6) -> np.ndarray:
    dist = np.asarray(jaccard_ops.jaccard_distance(bitmaps))
    z = hac.hac_numpy(dist, linkage)
    return hac.cut(z, cut_distance)


def place_clusters(labels: np.ndarray, loads: np.ndarray,
                   n_ranks: int) -> np.ndarray:
    """Bin-pack expert clusters onto ranks with exactly E/n_ranks slots each.

    Returns ``expert_to_rank`` (E,). Clusters are split only when they exceed
    the per-rank slot budget (the paper's oversized-group fallback); packing
    order is by cluster token load, heaviest first, into the least-loaded
    rank with room (balance constraint)."""
    e = len(labels)
    slots = e // n_ranks
    rank_free = np.full(n_ranks, slots)
    rank_load = np.zeros(n_ranks)
    expert_to_rank = np.full(e, -1, dtype=np.int32)

    clusters = []
    for lbl in np.unique(labels):
        members = np.where(labels == lbl)[0]
        clusters.append((members, float(loads[members].sum())))
    clusters.sort(key=lambda c: -c[1])

    for members, load in clusters:
        # order members by load so splits keep heavy experts together
        members = members[np.argsort(-loads[members])]
        idx = 0
        while idx < len(members):
            candidates = np.where(rank_free > 0)[0]
            take_rank = candidates[np.argmin(rank_load[candidates])]
            take = members[idx: idx + rank_free[take_rank]]
            expert_to_rank[take] = take_rank
            rank_free[take_rank] -= len(take)
            rank_load[take_rank] += float(loads[take].sum())
            idx += len(take)
    assert (expert_to_rank >= 0).all()
    return expert_to_rank


def rank_map_to_perm(expert_to_rank: np.ndarray) -> np.ndarray:
    """expert_to_rank -> physical slot permutation.

    ``perm[slot] = logical expert`` with rank r owning slots
    [r*E_loc, (r+1)*E_loc). ``inv_perm = argsort(perm)`` maps logical->slot."""
    order = np.lexsort((np.arange(len(expert_to_rank)), expert_to_rank))
    return order.astype(np.int32)


def avg_distinct_ranks(routing: np.ndarray, expert_to_rank: np.ndarray,
                       n_ranks: int) -> float:
    """The dispatch-bytes objective: mean distinct destination ranks per
    token (= SERVICE calls per federated query)."""
    ranks = expert_to_rank[routing]                     # (T, k)
    distinct = np.array([len(np.unique(r)) for r in ranks])
    return float(distinct.mean())


@dataclasses.dataclass
class PlacementReport:
    accepted: bool
    ranks_before: float
    ranks_after: float
    moved_experts: int
    migration_bytes: int

    @property
    def bytes_saved_frac(self) -> float:
        if self.ranks_before <= 0:
            return 0.0
        return 1.0 - self.ranks_after / self.ranks_before


def plan_expert_placement(routing: np.ndarray, n_experts: int, n_ranks: int,
                          old_expert_to_rank: Optional[np.ndarray] = None,
                          expert_bytes: int = 0, *,
                          cut_distance: float = 0.6,
                          ) -> Tuple[np.ndarray, PlacementReport]:
    """One adaptation round for a single MoE layer.

    routing: (T, k) token->expert assignments observed since the last round.
    Returns (new expert_to_rank, report); reverts (returns the old map) if
    the distinct-ranks objective does not improve — the Fig.-5 guard."""
    e_loc = n_experts // n_ranks
    if old_expert_to_rank is None:
        old_expert_to_rank = np.repeat(np.arange(n_ranks), e_loc).astype(
            np.int32)
    loads = np.bincount(routing.reshape(-1), minlength=n_experts).astype(
        np.float64)
    n_req = routing.shape[0]
    bm = coactivation_bitmaps(routing, n_experts, n_req)
    labels = cluster_experts(bm, cut_distance=cut_distance)
    new_map = place_clusters(labels, loads, n_ranks)

    before = avg_distinct_ranks(routing, old_expert_to_rank, n_ranks)
    after = avg_distinct_ranks(routing, new_map, n_ranks)
    moved = int((new_map != old_expert_to_rank).sum())
    # the Fig.-5 guard, with a minimum-gain margin so marginal re-plans do
    # not churn expert weights for nothing
    if after < 0.99 * before:
        return new_map, PlacementReport(True, before, after, moved,
                                        moved * expert_bytes)
    return old_expert_to_rank, PlacementReport(False, before, after, 0, 0)


def apply_expert_placement(moe_params: Dict, expert_to_rank: np.ndarray):
    """Migrate expert weights to their new physical slots (the triple-swap).

    moe_params: one layer's {"wg","wi","wo","inv_perm",...}; returns a new
    dict with permuted stacked weights and updated logical->slot map.
    Composes with the CURRENT physical layout (repeated migrations are the
    normal case — like successive triple exchanges)."""
    import jax.numpy as jnp
    cur_inv = np.asarray(moe_params["inv_perm"])        # logical -> old slot
    perm_new = rank_map_to_perm(expert_to_rank)         # new slot -> logical
    # new slot s' holds logical expert perm_new[s'], currently stored at
    # old slot cur_inv[perm_new[s']]
    gather = cur_inv[perm_new]
    out = dict(moe_params)
    for w in ("wg", "wi", "wo"):
        out[w] = jnp.asarray(np.asarray(moe_params[w])[gather])
    out["inv_perm"] = jnp.asarray(np.argsort(perm_new).astype(np.int32))
    return out


# --------------------------------------------------------------------------- #
# vocabulary placement
# --------------------------------------------------------------------------- #

def vocab_permutation(token_counts: np.ndarray, n_shards: int) -> np.ndarray:
    """Balance hot tokens across vocab shards: sort by frequency, deal
    round-robin in serpentine order. Returns perm: new_id -> old_id with
    contiguous blocks per shard."""
    v = len(token_counts)
    per = v // n_shards
    order = np.argsort(-token_counts)
    shard_rows: List[List[int]] = [[] for _ in range(n_shards)]
    direction = 1
    s = 0
    for tok in order.tolist():
        shard_rows[s].append(tok)
        s += direction
        if s == n_shards or s < 0:
            direction *= -1
            s += direction
    perm = np.concatenate([np.array(rows[:per] + rows[per:], dtype=np.int64)
                           for rows in shard_rows])
    return perm.astype(np.int32)


def shard_gather_imbalance(token_counts: np.ndarray, perm: np.ndarray,
                           n_shards: int) -> float:
    """max/mean embedding-gather load across shards (1.0 = balanced)."""
    v = len(perm)
    per = v // n_shards
    loads = np.array([token_counts[perm[i * per:(i + 1) * per]].sum()
                      for i in range(n_shards)], dtype=np.float64)
    return float(loads.max() / max(loads.mean(), 1e-9))
