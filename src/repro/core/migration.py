"""Triple-migration planning between shard layouts (Sec. III.B / IV).

Only triples of *re-assigned* features move — the incremental adjustment that
distinguishes AWAPart from full re-partitioning. A plan lists
(feature, src, dst) moves plus the migration traffic they imply.

A plan can additionally be *chunked* (``chunk_plan``) into prioritized
``MigrationChunk``s — hottest workload features first, each chunk bounded by
a per-step bytes budget — so an online ``repro.migrate.MigrationSession`` can
apply it incrementally while queries keep being served, instead of one
stop-the-world commit. ``migration_seconds`` prices the traffic of a plan or
chunk under the same network model the executors use, which is what the
controller's migration-cost-aware accept guard amortizes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import PartitionState

TRIPLE_BYTES = 12  # dictionary-encoded (s, p, o) int32


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Tuple[int, int, int]]        # (feature, src_shard, dst_shard)
    n_triples: int
    bytes: int
    # replica ops (repro.replicate): an add ships a read copy of a feature
    # to a new holder shard (src == dst marks a zero-traffic promotion — the
    # data is already local, e.g. the feature's old primary keeps a copy);
    # a drop retires a copy in place (no traffic). local_moves lists the
    # features of `moves` whose destination already held a replica copy:
    # the primary re-designation ships nothing.
    replica_adds: List[Tuple[int, int, int]] = \
        dataclasses.field(default_factory=list)   # (feature, src, dst)
    replica_drops: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)   # (feature, shard)
    local_moves: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    @property
    def n_replica_ops(self) -> int:
        return len(self.replica_adds) + len(self.replica_drops)

    def summary(self) -> str:
        rep = (f", {len(self.replica_adds)}+/{len(self.replica_drops)}- "
               "replicas" if self.n_replica_ops else "")
        return (f"{self.n_moves} feature moves{rep}, {self.n_triples} "
                f"triples, {self.bytes / 1e6:.2f} MB migration traffic")


@dataclasses.dataclass
class MigrationChunk:
    """One bounded step of a chunked migration: a slice of a plan's ops
    (grouped per feature — a feature's move and its replica ops never split
    across chunks) whose total traffic fits the per-step bytes budget."""
    moves: List[Tuple[int, int, int]]        # (feature, src_shard, dst_shard)
    n_triples: int
    bytes: int
    replica_adds: List[Tuple[int, int, int]] = \
        dataclasses.field(default_factory=list)
    replica_drops: List[Tuple[int, int]] = \
        dataclasses.field(default_factory=list)
    local_moves: List[int] = dataclasses.field(default_factory=list)

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    def summary(self) -> str:
        rep = (f", {len(self.replica_adds)}+/{len(self.replica_drops)}- "
               "replicas" if self.replica_adds or self.replica_drops else "")
        return (f"chunk: {self.n_moves} moves{rep}, {self.n_triples} "
                f"triples, {self.bytes / 1e3:.1f} KB")


def migration_seconds(plan_or_chunk, net) -> float:
    """Modeled wall time to ship a plan/chunk's triples between shards: one
    transfer-setup latency per distinct (src, dst) shard pair plus wire time
    for the payload. Replica adds ship like moves; drops, src == dst local
    promotions, and moves onto a shard already holding a copy
    (``local_moves``) are free. ``net`` is any object with ``latency_s`` /
    ``bandwidth_Bps`` (e.g. ``repro.query.exec.NetworkModel``)."""
    local = set(getattr(plan_or_chunk, "local_moves", ()))
    pairs = {(src, dst) for f, src, dst in plan_or_chunk.moves
             if f not in local}
    pairs |= {(src, dst)
              for _, src, dst in getattr(plan_or_chunk, "replica_adds", [])
              if src != dst}
    return len(pairs) * net.latency_s + plan_or_chunk.bytes / net.bandwidth_Bps


def feature_heat(space, queries: Sequence) -> np.ndarray:
    """Frequency-weighted workload touch count per feature — the priority
    used to order migration chunks (hottest features migrate first, so the
    layout the workload actually hits converges earliest)."""
    heat = np.zeros(space.n_features, dtype=np.float64)
    for q in queries:
        heat[space.query_features(q)] += q.frequency
    return heat


def chunk_plan(plan: MigrationPlan, feature_sizes: np.ndarray,
               bytes_budget: int,
               priority: Optional[np.ndarray] = None) -> List[MigrationChunk]:
    """Split ``plan`` into ``MigrationChunk``s of at most ``bytes_budget``
    migration traffic each (a single feature's ops larger than the budget
    get their own chunk — ops are atomic at feature granularity, and a
    feature's move plus its replica adds/drops always land in ONE chunk:
    an add may retain a copy at the feature's old primary, which is only
    zero-traffic if it applies together with the move).

    Features are ordered hottest-first by ``priority`` (per-feature workload
    heat; ties broken by traffic, then by feature id for determinism), so
    early chunks carry the features the workload is actually touching.
    """
    if not plan.moves and not plan.replica_adds and not plan.replica_drops:
        return []
    sizes = np.asarray(feature_sizes, dtype=np.int64)

    groups: dict = {}

    def group(f: int) -> dict:
        return groups.setdefault(
            int(f), dict(moves=[], adds=[], drops=[], n_triples=0))

    local = set(plan.local_moves)
    for m in plan.moves:
        g = group(m[0])
        g["moves"].append(m)
        if m[0] not in local:             # dst already held a copy: free
            g["n_triples"] += int(sizes[m[0]])
    for a in plan.replica_adds:
        g = group(a[0])
        g["adds"].append(a)
        if a[1] != a[2]:                  # src == dst: local, zero traffic
            g["n_triples"] += int(sizes[a[0]])
    for d in plan.replica_drops:
        group(d[0])["drops"].append(d)    # retire in place: zero traffic

    feats = np.array(sorted(groups), dtype=np.int64)
    gbytes = np.array([groups[int(f)]["n_triples"] * TRIPLE_BYTES
                       for f in feats], dtype=np.int64)
    prio = (np.zeros(len(feats)) if priority is None
            else np.asarray(priority, dtype=np.float64)[feats])
    # lexsort: last key is primary — hottest, then biggest, then feature id
    order = np.lexsort((feats, -gbytes, -prio))
    budget = max(int(bytes_budget), 1)

    chunks: List[MigrationChunk] = []
    cur: List[int] = []
    cur_bytes = 0
    for i in order.tolist():
        b = int(gbytes[i])
        if cur and cur_bytes + b > budget:
            chunks.append(_make_chunk(groups, feats, cur, local))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    chunks.append(_make_chunk(groups, feats, cur, local))
    return chunks


def _make_chunk(groups: dict, feats: np.ndarray, idxs: List[int],
                local: set) -> MigrationChunk:
    gs = [groups[int(feats[i])] for i in idxs]
    n = sum(g["n_triples"] for g in gs)
    moves = [m for g in gs for m in g["moves"]]
    return MigrationChunk(
        moves=moves, n_triples=n, bytes=n * TRIPLE_BYTES,
        replica_adds=[a for g in gs for a in g["adds"]],
        replica_drops=[d for g in gs for d in g["drops"]],
        local_moves=[m[0] for m in moves if m[0] in local])


def plan(old: PartitionState, new: PartitionState,
         old_replicas=None, new_replicas=None) -> MigrationPlan:
    """Delta between two layouts: primary moves plus — when both replica
    maps are given (``repro.replicate.ReplicaMap``) — the replica adds and
    drops taking the old map (with primaries rebased onto the new layout,
    since the moves themselves carry the primary copies) to the new one.

    An op whose target shard already held a copy under the *old* map ships
    nothing: a replica add is marked ``src == dst`` (local promotion), and
    a primary move onto an existing replica is listed in ``local_moves``
    (primary re-designation only)."""
    assert len(old.feature_to_shard) == len(new.feature_to_shard), \
        "extend the old state before planning (new tracked PO features)"
    changed = np.where(old.feature_to_shard != new.feature_to_shard)[0]
    moves = [(int(f), int(old.feature_to_shard[f]), int(new.feature_to_shard[f]))
             for f in changed.tolist()]
    local_moves = ([] if old_replicas is None else
                   [f for f, _src, dst in moves if old_replicas.has(f, dst)])
    shipped = changed if old_replicas is None else \
        np.array([f for f, _s, d in moves if not old_replicas.has(f, d)],
                 dtype=np.int64)
    n_triples = int(new.feature_sizes[shipped].sum())
    out = MigrationPlan(moves=moves, n_triples=n_triples,
                        bytes=n_triples * TRIPLE_BYTES,
                        local_moves=local_moves)
    if old_replicas is None or new_replicas is None:
        return out

    one = np.uint64(1)
    rebased = old_replicas.masks.copy()
    for f, src, dst in moves:
        rebased[f] = (rebased[f] & ~(one << np.uint64(src))) \
            | (one << np.uint64(dst))
    diff = np.flatnonzero(rebased ^ new_replicas.masks)
    for f in diff.tolist():
        add_bits = int(new_replicas.masks[f] & ~rebased[f])
        drop_bits = int(rebased[f] & ~new_replicas.masks[f])
        primary = int(new.feature_to_shard[f])
        size = int(new.feature_sizes[f])
        for s in range(new.n_shards):
            if (add_bits >> s) & 1:
                local = bool((int(old_replicas.masks[f]) >> s) & 1)
                out.replica_adds.append((f, s if local else primary, s))
                if not local:
                    out.n_triples += size
                    out.bytes += size * TRIPLE_BYTES
            if (drop_bits >> s) & 1:
                out.replica_drops.append((f, s))
    return out


def extend_for_space(state: PartitionState, space,
                     ) -> Tuple[PartitionState, np.ndarray]:
    """Extend ``state`` to ``space``'s grown feature universe.

    The single place encoding the PO-split parent rule (a new PO feature
    inherits its parent P feature's shard) — both the controller's adapt
    round and the PartitionedKG facade go through it, so their extended
    states are identical by construction. New *P* features (a predicate the
    universe has never seen, born on the live write path before the owner
    of this state absorbed it) have no parent to inherit from: parent -1
    sends them to the least-loaded shard, matching the write path's own
    placement rule. Returns (state, triple owners)."""
    old_nf = len(state.feature_to_shard)
    owners = space.triple_owners()
    sizes = space.feature_sizes(owners)
    parents = []
    for i in range(old_nf, space.n_features):
        key = space.key(i)
        parents.append(space.p_index(key[1]) if key[0] == "PO" else -1)
    return extend_state(state, sizes, parents), owners


def extend_state(state: PartitionState, new_sizes: np.ndarray,
                 parent_of_new: List[int]) -> PartitionState:
    """Grow a state with newly-tracked features.

    A new PO feature's triples already live on its parent P feature's shard
    (tracking splits ownership without moving data), so it inherits that
    shard; the parent's size shrinks accordingly — handled by passing the
    re-computed ``new_sizes`` for the full (grown) feature universe. A
    parent may itself be new (a PO child of a predicate born in the same
    growth step): parents are resolved in creation order, so the child
    reads the shard its parent was just assigned. Parent ``-1`` (a new P
    feature, write path) places on the least-loaded shard."""
    f_old = len(state.feature_to_shard)
    f_new = len(new_sizes)
    assert f_new >= f_old and len(parent_of_new) == f_new - f_old
    sizes = np.asarray(new_sizes, np.int64)
    f2s = np.empty(f_new, dtype=np.int32)
    f2s[:f_old] = state.feature_to_shard
    loads = None
    for i, parent in enumerate(parent_of_new):
        if parent >= 0:
            f2s[f_old + i] = f2s[parent]
        else:
            if loads is None:
                loads = np.bincount(f2s[:f_old], weights=sizes[:f_old],
                                    minlength=state.n_shards)
            dst = int(np.argmin(loads))
            f2s[f_old + i] = dst
            loads[dst] += max(int(sizes[f_old + i]), 1)
    return PartitionState(f2s, sizes, state.n_shards)
