"""Triple-migration planning between shard layouts (Sec. III.B / IV).

Only triples of *re-assigned* features move — the incremental adjustment that
distinguishes AWAPart from full re-partitioning. A plan lists
(feature, src, dst) moves plus the migration traffic they imply.

A plan can additionally be *chunked* (``chunk_plan``) into prioritized
``MigrationChunk``s — hottest workload features first, each chunk bounded by
a per-step bytes budget — so an online ``repro.migrate.MigrationSession`` can
apply it incrementally while queries keep being served, instead of one
stop-the-world commit. ``migration_seconds`` prices the traffic of a plan or
chunk under the same network model the executors use, which is what the
controller's migration-cost-aware accept guard amortizes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import PartitionState

TRIPLE_BYTES = 12  # dictionary-encoded (s, p, o) int32


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Tuple[int, int, int]]        # (feature, src_shard, dst_shard)
    n_triples: int
    bytes: int

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    def summary(self) -> str:
        return (f"{self.n_moves} feature moves, {self.n_triples} triples, "
                f"{self.bytes / 1e6:.2f} MB migration traffic")


@dataclasses.dataclass
class MigrationChunk:
    """One bounded step of a chunked migration: a contiguous slice of a
    plan's moves whose total traffic fits the per-step bytes budget."""
    moves: List[Tuple[int, int, int]]        # (feature, src_shard, dst_shard)
    n_triples: int
    bytes: int

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    def summary(self) -> str:
        return (f"chunk: {self.n_moves} moves, {self.n_triples} triples, "
                f"{self.bytes / 1e3:.1f} KB")


def migration_seconds(plan_or_chunk, net) -> float:
    """Modeled wall time to ship a plan/chunk's triples between shards: one
    transfer-setup latency per distinct (src, dst) shard pair plus wire time
    for the payload. ``net`` is any object with ``latency_s`` /
    ``bandwidth_Bps`` (e.g. ``repro.query.exec.NetworkModel``)."""
    pairs = len({(src, dst) for _, src, dst in plan_or_chunk.moves})
    return pairs * net.latency_s + plan_or_chunk.bytes / net.bandwidth_Bps


def feature_heat(space, queries: Sequence) -> np.ndarray:
    """Frequency-weighted workload touch count per feature — the priority
    used to order migration chunks (hottest features migrate first, so the
    layout the workload actually hits converges earliest)."""
    heat = np.zeros(space.n_features, dtype=np.float64)
    for q in queries:
        heat[space.query_features(q)] += q.frequency
    return heat


def chunk_plan(plan: MigrationPlan, feature_sizes: np.ndarray,
               bytes_budget: int,
               priority: Optional[np.ndarray] = None) -> List[MigrationChunk]:
    """Split ``plan`` into ``MigrationChunk``s of at most ``bytes_budget``
    migration traffic each (a single move larger than the budget gets its own
    chunk — moves are atomic at feature granularity).

    Moves are ordered hottest-first by ``priority`` (per-feature workload
    heat; ties broken largest-first, then by feature id for determinism), so
    early chunks carry the features the workload is actually touching.
    """
    if not plan.moves:
        return []
    feats = np.array([m[0] for m in plan.moves], dtype=np.int64)
    sizes = np.asarray(feature_sizes, dtype=np.int64)[feats]
    prio = (np.zeros(len(feats)) if priority is None
            else np.asarray(priority, dtype=np.float64)[feats])
    # lexsort: last key is primary — hottest, then biggest, then feature id
    order = np.lexsort((feats, -sizes, -prio))
    budget = max(int(bytes_budget), 1)

    chunks: List[MigrationChunk] = []
    cur: List[int] = []
    cur_bytes = 0
    for i in order.tolist():
        b = int(sizes[i]) * TRIPLE_BYTES
        if cur and cur_bytes + b > budget:
            chunks.append(_make_chunk(plan, cur, sizes))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    chunks.append(_make_chunk(plan, cur, sizes))
    return chunks


def _make_chunk(plan: MigrationPlan, idxs: List[int],
                sizes: np.ndarray) -> MigrationChunk:
    n = int(sizes[idxs].sum())
    return MigrationChunk(moves=[plan.moves[i] for i in idxs],
                          n_triples=n, bytes=n * TRIPLE_BYTES)


def plan(old: PartitionState, new: PartitionState) -> MigrationPlan:
    assert len(old.feature_to_shard) == len(new.feature_to_shard), \
        "extend the old state before planning (new tracked PO features)"
    changed = np.where(old.feature_to_shard != new.feature_to_shard)[0]
    moves = [(int(f), int(old.feature_to_shard[f]), int(new.feature_to_shard[f]))
             for f in changed.tolist()]
    n_triples = int(new.feature_sizes[changed].sum())
    return MigrationPlan(moves=moves, n_triples=n_triples,
                         bytes=n_triples * TRIPLE_BYTES)


def extend_for_space(state: PartitionState, space,
                     ) -> Tuple[PartitionState, np.ndarray]:
    """Extend ``state`` to ``space``'s grown feature universe.

    The single place encoding the PO-split parent rule (a new PO feature
    inherits its parent P feature's shard) — both the controller's adapt
    round and the PartitionedKG facade go through it, so their extended
    states are identical by construction. Returns (state, triple owners)."""
    old_nf = len(state.feature_to_shard)
    owners = space.triple_owners()
    sizes = space.feature_sizes(owners)
    parents = [space.p_index(space.key(i)[1])
               for i in range(old_nf, space.n_features)]
    return extend_state(state, sizes, parents), owners


def extend_state(state: PartitionState, new_sizes: np.ndarray,
                 parent_of_new: List[int]) -> PartitionState:
    """Grow a state with newly-tracked PO features.

    A new PO feature's triples already live on its parent P feature's shard
    (tracking splits ownership without moving data), so it inherits that
    shard; the parent's size shrinks accordingly — handled by passing the
    re-computed ``new_sizes`` for the full (grown) feature universe.
    """
    f_old = len(state.feature_to_shard)
    f_new = len(new_sizes)
    assert f_new >= f_old and len(parent_of_new) == f_new - f_old
    f2s = np.empty(f_new, dtype=np.int32)
    f2s[:f_old] = state.feature_to_shard
    for i, parent in enumerate(parent_of_new):
        f2s[f_old + i] = state.feature_to_shard[parent]
    return PartitionState(f2s, np.asarray(new_sizes, np.int64), state.n_shards)
