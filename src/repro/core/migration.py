"""Triple-migration planning between shard layouts (Sec. III.B / IV).

Only triples of *re-assigned* features move — the incremental adjustment that
distinguishes AWAPart from full re-partitioning. A plan lists
(feature, src, dst) moves plus the migration traffic they imply.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.partition import PartitionState

TRIPLE_BYTES = 12  # dictionary-encoded (s, p, o) int32


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Tuple[int, int, int]]        # (feature, src_shard, dst_shard)
    n_triples: int
    bytes: int

    @property
    def n_moves(self) -> int:
        return len(self.moves)

    def summary(self) -> str:
        return (f"{self.n_moves} feature moves, {self.n_triples} triples, "
                f"{self.bytes / 1e6:.2f} MB migration traffic")


def plan(old: PartitionState, new: PartitionState) -> MigrationPlan:
    assert len(old.feature_to_shard) == len(new.feature_to_shard), \
        "extend the old state before planning (new tracked PO features)"
    changed = np.where(old.feature_to_shard != new.feature_to_shard)[0]
    moves = [(int(f), int(old.feature_to_shard[f]), int(new.feature_to_shard[f]))
             for f in changed.tolist()]
    n_triples = int(new.feature_sizes[changed].sum())
    return MigrationPlan(moves=moves, n_triples=n_triples,
                         bytes=n_triples * TRIPLE_BYTES)


def extend_for_space(state: PartitionState, space,
                     ) -> Tuple[PartitionState, np.ndarray]:
    """Extend ``state`` to ``space``'s grown feature universe.

    The single place encoding the PO-split parent rule (a new PO feature
    inherits its parent P feature's shard) — both the controller's adapt
    round and the PartitionedKG facade go through it, so their extended
    states are identical by construction. Returns (state, triple owners)."""
    old_nf = len(state.feature_to_shard)
    owners = space.triple_owners()
    sizes = space.feature_sizes(owners)
    parents = [space.p_index(space.key(i)[1])
               for i in range(old_nf, space.n_features)]
    return extend_state(state, sizes, parents), owners


def extend_state(state: PartitionState, new_sizes: np.ndarray,
                 parent_of_new: List[int]) -> PartitionState:
    """Grow a state with newly-tracked PO features.

    A new PO feature's triples already live on its parent P feature's shard
    (tracking splits ownership without moving data), so it inherits that
    shard; the parent's size shrinks accordingly — handled by passing the
    re-computed ``new_sizes`` for the full (grown) feature universe.
    """
    f_old = len(state.feature_to_shard)
    f_new = len(new_sizes)
    assert f_new >= f_old and len(parent_of_new) == f_new - f_old
    f2s = np.empty(f_new, dtype=np.int32)
    f2s[:f_old] = state.feature_to_shard
    for i, parent in enumerate(parent_of_new):
        f2s[f_old + i] = state.feature_to_shard[parent]
    return PartitionState(f2s, np.asarray(new_sizes, np.int64), state.n_shards)
