"""Mixture-of-Experts with expert-parallel dispatch over the ``model`` axis.

Two dispatch modes (``cfg.moe_dispatch``):

* ``"expert"`` — GShard-style baseline: every (token, routed-expert) pair is
  shipped to the expert's rank in per-expert capacity buffers.
* ``"rank"`` — **AWAPart-placed dispatch**: the paper's insight mapped to MoE.
  Experts are placed on ranks by workload-aware clustering (see
  ``core/placement.py``); a token is shipped **once per distinct rank**
  owning any of its top-k experts (the federated-query SERVICE-call dedup),
  so co-locating co-activated experts directly cuts all-to-all bytes —
  exactly as co-locating query features cuts distributed joins.

The logical→physical expert map lives in ``params["inv_perm"]`` (int32, not
trained); migration = permuting the stacked expert weights + updating the map
(the analogue of exchanging triples between shards + updating PMeta).

A dense reference path (``moe_apply_dense``) computes the identical function
without collectives for unit tests and 1-device smoke runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.models.layers import Axes, Params, _dtype, dense_init


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh context threaded through model apply fns."""
    mesh: Any                       # jax.sharding.Mesh
    dp_axes: Tuple[str, ...]        # batch axes, e.g. ("pod", "data")
    tp_axis: str = "model"
    seq_shard_moe: bool = True      # shard tokens over tp for dispatch

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]


def moe_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Params = {}
    a: Axes = {}
    p["wr"], a["wr"] = dense_init(ks[0], (d, e), ("embed", None), jnp.float32)
    p["wg"], a["wg"] = dense_init(ks[1], (e, d, f), ("experts", "embed", None), dt, fan_in=d)
    p["wi"], a["wi"] = dense_init(ks[2], (e, d, f), ("experts", "embed", None), dt, fan_in=d)
    p["wo"], a["wo"] = dense_init(ks[3], (e, f, d), ("experts", None, "embed"), dt, fan_in=f)
    # logical expert -> physical slot (identity until AWAPart placement runs)
    p["inv_perm"], a["inv_perm"] = jnp.arange(e, dtype=jnp.int32), (None,)
    return p, a


def _router(p: Params, x2d: jnp.ndarray, cfg: ArchConfig):
    """Top-k routing in f32. x2d: (T, d) -> weights/ids (T, k), aux loss."""
    logits = (x2d.astype(jnp.float32) @ p["wr"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)                 # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    e = cfg.n_experts
    frac = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / topi.size)
    aux = e * (frac * probs.mean(0)).sum()
    return topw, topi, aux


def _expert_ffn(wg, wi, wo, x, cfg: ArchConfig):
    """x: (E_loc, C, d) grouped tokens -> (E_loc, C, d)."""
    cd = _dtype(cfg.compute_dtype)
    h = jnp.einsum("ecd,edf->ecf", x, wi.astype(cd))
    if cfg.activation == "silu":
        g = jnp.einsum("ecd,edf->ecf", x, wg.astype(cd))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(cd))


# --------------------------------------------------------------------------- #
# dense reference (no collectives)
# --------------------------------------------------------------------------- #

def moe_apply_dense(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cd = _dtype(cfg.compute_dtype)
    b, s, d = x.shape
    x2 = x.reshape(-1, d).astype(cd)
    topw, topi, aux = _router(p, x2, cfg)
    y = jnp.zeros_like(x2)
    for e in range(cfg.n_experts):          # fine for reduced test configs
        w_e = (topw * (topi == e)).sum(-1)                     # (T,)
        slot = p["inv_perm"][e]             # logical expert -> physical slot
        h = x2 @ p["wi"][slot].astype(cd)
        if cfg.activation == "silu":
            h = jax.nn.silu(x2 @ p["wg"][slot].astype(cd)) * h
        else:
            h = jax.nn.gelu(h)
        y = y + (h @ p["wo"][slot].astype(cd)) * w_e[:, None].astype(cd)
    return y.reshape(b, s, d).astype(x.dtype), aux


# --------------------------------------------------------------------------- #
# sharded dispatch helpers
# --------------------------------------------------------------------------- #

def _positions_in_group(group_ids: jnp.ndarray, n_groups: int):
    """Stable sort pair ids by group; return order, sorted ids and intra-group
    positions (all static shapes)."""
    order = jnp.argsort(group_ids, stable=True)
    sorted_ids = group_ids[order]
    counts = jnp.zeros((n_groups,), jnp.int32).at[group_ids].add(
        1, mode="drop")
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(group_ids.shape[0], dtype=jnp.int32) - starts[sorted_ids]
    return order, sorted_ids, pos


def _capacity(tokens: int, k: int, n_groups: int, cf: float) -> int:
    c = int(np.ceil(tokens * k * cf / n_groups))
    return max(8, (c + 7) // 8 * 8)


def _moe_expert_dispatch_block(p: Params, x_loc: jnp.ndarray,
                               cfg: ArchConfig, tp: int, tp_axis: str):
    """Inside-shard_map body, expert-granularity (GShard baseline)."""
    cd = _dtype(cfg.compute_dtype)
    t_loc, d = x_loc.shape
    e, e_loc = cfg.n_experts, cfg.n_experts // tp
    topw, topi, aux = _router(p, x_loc, cfg)
    slots = p["inv_perm"][topi]                                   # physical
    cap = _capacity(t_loc, cfg.top_k, e, cfg.capacity_factor)

    pair_slot = slots.reshape(-1)
    pair_tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), cfg.top_k)
    order, sorted_slot, pos = _positions_in_group(pair_slot, e)
    sorted_tok = pair_tok[order]
    keep = pos < cap
    scat_e = jnp.where(keep, sorted_slot, e)                      # drop rows
    buf = jnp.zeros((e, cap, d), cd).at[scat_e, jnp.minimum(pos, cap - 1)] \
        .set(x_loc[sorted_tok].astype(cd), mode="drop")

    # ship: (E, C, d) -> all_to_all over tp -> (tp, E_loc, C, d) source-major
    recv = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=0,
                              tiled=True)
    recv = recv.reshape(tp, e_loc, cap, d).transpose(1, 0, 2, 3) \
        .reshape(e_loc, tp * cap, d)
    out = _expert_ffn(p["wg"], p["wi"], p["wo"], recv, cfg)
    out = out.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3) \
        .reshape(e, cap, d)
    back = jax.lax.all_to_all(out, tp_axis, split_axis=0, concat_axis=0,
                              tiled=True)

    vals = back[jnp.minimum(sorted_slot, e - 1), jnp.minimum(pos, cap - 1)]
    vals = jnp.where(keep[:, None], vals, 0)
    w_sorted = topw.reshape(-1)[order].astype(cd)
    y = jnp.zeros((t_loc, d), cd).at[sorted_tok].add(vals * w_sorted[:, None])
    return y, aux


def _moe_rank_dispatch_block(p: Params, x_loc: jnp.ndarray,
                             cfg: ArchConfig, tp: int, tp_axis: str):
    """AWAPart mode: one shipment per distinct destination *rank* per token."""
    cd = _dtype(cfg.compute_dtype)
    t_loc, d = x_loc.shape
    e, e_loc = cfg.n_experts, cfg.n_experts // tp
    k = cfg.top_k
    topw, topi, aux = _router(p, x_loc, cfg)
    slots = p["inv_perm"][topi]                                   # (T, k)
    ranks = slots // e_loc

    # distinct destination ranks per token
    rank_hit = jnp.zeros((t_loc, tp), bool).at[
        jnp.repeat(jnp.arange(t_loc), k), ranks.reshape(-1)].set(
        True, mode="drop")
    cap_r = _capacity(t_loc, min(k, tp), tp, cfg.capacity_factor)
    pos2d = jnp.cumsum(rank_hit.astype(jnp.int32), axis=0) - 1    # (T, tp)
    keep = rank_hit & (pos2d < cap_r)

    tok_ids = jnp.broadcast_to(jnp.arange(t_loc, dtype=jnp.int32)[:, None],
                               (t_loc, tp))
    r_ids = jnp.broadcast_to(jnp.arange(tp, dtype=jnp.int32)[None, :],
                             (t_loc, tp))
    scat_r = jnp.where(keep, r_ids, tp)
    scat_c = jnp.minimum(pos2d, cap_r - 1)
    xbuf = jnp.zeros((tp, cap_r, d), cd).at[scat_r, scat_c].set(
        jnp.broadcast_to(x_loc[:, None, :].astype(cd), (t_loc, tp, d)),
        mode="drop")
    slotbuf = jnp.full((tp, cap_r, k), -1, jnp.int32).at[scat_r, scat_c].set(
        jnp.broadcast_to(slots[:, None, :], (t_loc, tp, k)), mode="drop")
    wbuf = jnp.zeros((tp, cap_r, k), jnp.float32).at[scat_r, scat_c].set(
        jnp.broadcast_to(topw[:, None, :], (t_loc, tp, k)), mode="drop")
    tokbuf = jnp.full((tp, cap_r), -1, jnp.int32).at[scat_r, scat_c].set(
        tok_ids, mode="drop")

    a2a = functools.partial(jax.lax.all_to_all, axis_name=tp_axis,
                            split_axis=0, concat_axis=0, tiled=True)
    xr, slotr, wr_ = a2a(xbuf), a2a(slotbuf), a2a(wbuf)
    r_tot = tp * cap_r
    xr = xr.reshape(r_tot, d)
    my_rank = jax.lax.axis_index(tp_axis)
    local_slot = slotr.reshape(r_tot, k) - my_rank * e_loc
    wr2 = wr_.reshape(r_tot, k)
    valid = (local_slot >= 0) & (local_slot < e_loc) & (wr2 > 0)

    # second-level (local) dispatch: jobs = (received token, local expert)
    job_e = jnp.where(valid, local_slot, e_loc).reshape(-1)       # (R*k,)
    job_tok = jnp.repeat(jnp.arange(r_tot, dtype=jnp.int32), k)
    cap_e = _capacity(t_loc * tp, k, e, cfg.capacity_factor)      # jobs per expert
    order, sorted_e, pos = _positions_in_group(job_e, e_loc + 1)
    sorted_tok = job_tok[order]
    keep_j = (sorted_e < e_loc) & (pos < cap_e)
    scat_e = jnp.where(keep_j, sorted_e, e_loc)
    xe = jnp.zeros((e_loc, cap_e, d), cd).at[
        scat_e, jnp.minimum(pos, cap_e - 1)].set(
        xr[sorted_tok], mode="drop")
    he = _expert_ffn(p["wg"], p["wi"], p["wo"], xe, cfg)
    # local combine back to received-token rows, weighted
    w_sorted = wr2.reshape(-1)[order].astype(cd)
    vals = he[jnp.minimum(sorted_e, e_loc - 1), jnp.minimum(pos, cap_e - 1)]
    vals = jnp.where(keep_j[:, None], vals, 0)
    yr = jnp.zeros((r_tot, d), cd).at[sorted_tok].add(
        vals * w_sorted[:, None])

    ybuf = a2a(yr.reshape(tp, cap_r, d))                          # back to sources
    flat_tok = tokbuf.reshape(-1)
    y = jnp.zeros((t_loc, d), cd).at[jnp.where(flat_tok >= 0, flat_tok, t_loc)] \
        .add(ybuf.reshape(-1, d), mode="drop")
    return y, aux


# --------------------------------------------------------------------------- #
# public sharded apply
# --------------------------------------------------------------------------- #

def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
              ctx: Optional[ShardCtx]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). With a ShardCtx, runs the expert-parallel
    path under shard_map; without, the dense reference."""
    if ctx is None or ctx.tp * int(np.prod([ctx.mesh.shape[a] for a in ctx.dp_axes])) == 1:
        return moe_apply_dense(p, x, cfg)

    b, s, d = x.shape
    tp = ctx.tp
    block = (_moe_rank_dispatch_block if cfg.moe_dispatch == "rank"
             else _moe_expert_dispatch_block)

    # token sharding for dispatch: seq over tp when divisible (train/prefill),
    # else batch-only (decode)
    seq_tp = ctx.seq_shard_moe and (s % tp == 0) and s >= tp
    x_spec = (P(ctx.dp_axes, ctx.tp_axis, None) if seq_tp
              else P(ctx.dp_axes, None, None))
    w_spec = {"wr": P(None, None), "wg": P(ctx.tp_axis, None, None),
              "wi": P(ctx.tp_axis, None, None), "wo": P(ctx.tp_axis, None, None),
              "inv_perm": P(None)}

    def body(p_loc, x_loc):
        bl, sl, _ = x_loc.shape
        y, aux = block(p_loc, x_loc.reshape(bl * sl, d), cfg, tp, ctx.tp_axis)
        aux = jax.lax.pmean(aux, ctx.tp_axis)
        for ax in ctx.dp_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(bl, sl, d).astype(x.dtype), aux

    # check_vma=False: in decode (batch-only sharding) the tokens are
    # replicated over the tp axis; every rank reconstructs the identical
    # combined output after the return all_to_all, which the static
    # replication checker cannot infer.
    y, aux = compat.shard_map(
        body, mesh=ctx.mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p, x)
    return y, aux
