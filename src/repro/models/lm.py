"""LM-level step functions: train / prefill / decode, plus input specs.

These are the functions the launcher jits and the dry-run lowers for every
(architecture × input-shape × mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig
from repro.models import transformer
from repro.models.moe import ShardCtx
from repro.optim import AdamWConfig, adamw_init, adamw_update

AUX_WEIGHT = 0.01


# --------------------------------------------------------------------------- #
# loss
# --------------------------------------------------------------------------- #

def _cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Per-position CE via one-hot contraction.

    ``take_along_axis`` over a model-sharded vocab makes SPMD all-gather the
    full (B, S, V) logits on multi-axis meshes (measured 211 GB/step); the
    one-hot einsum partitions cleanly (contraction over the sharded vocab is
    a small psum)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = (targets[..., None] ==
              jnp.arange(logits.shape[-1])[None, None, :])
    picked = jnp.sum(logits.astype(jnp.float32) * onehot, axis=-1)
    return lse - picked


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            ctx: Optional[ShardCtx]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if cfg.embedding_inputs:
        # masked-prediction (HuBERT-style): CE on masked frames only
        logits, aux, _ = transformer.forward(params, batch["embeddings"],
                                             cfg, ctx)
        labels, mask = batch["labels"], batch["mask"]
        nll = _cross_entropy(logits, labels)
        # pin the per-token loss sharding: the mean's cotangent otherwise
        # re-enters the backward pass replicated on multi-axis meshes and
        # SPMD gathers every activation to full batch (measured 211 GB/step)
        nll = transformer.constrain_activations(nll, cfg)
        denom = jnp.maximum(mask.sum(), 1)
        loss = (nll * mask).sum() / denom
    else:
        tokens = batch["tokens"]
        logits, aux, _ = transformer.forward(params, tokens, cfg, ctx)
        nll = _cross_entropy(logits[:, :-1], tokens[:, 1:])   # next-token CE
        nll = transformer.constrain_activations(nll, cfg)
        loss = nll.mean()
    total = loss + AUX_WEIGHT * aux
    return total, {"loss": loss, "aux": aux}


# --------------------------------------------------------------------------- #
# steps
# --------------------------------------------------------------------------- #

def train_step(params, opt_state, batch, cfg: ArchConfig,
               ctx: Optional[ShardCtx], opt_cfg: AdamWConfig):
    # allow_int: integer leaves (MoE inv_perm placement) get float0 grads,
    # which the optimizer ignores
    grad_fn = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg, ctx),
                                 has_aux=True, allow_int=True)
    (total, metrics), grads = grad_fn(params)
    new_params, new_opt, opt_metrics = adamw_update(params, grads, opt_state,
                                                    opt_cfg)
    metrics = dict(metrics, total=total, **opt_metrics)
    return new_params, new_opt, metrics


def prefill_step(params, batch, cfg: ArchConfig, ctx: Optional[ShardCtx]):
    """Full-sequence forward producing logits for the last position and the
    decode-ready caches."""
    inputs = batch["embeddings"] if cfg.embedding_inputs else batch["tokens"]
    logits, _, caches = transformer.forward(params, inputs, cfg, ctx,
                                            collect_cache=cfg.has_decode)
    return logits[:, -1], caches


def decode_step(params, caches, batch, cfg: ArchConfig,
                ctx: Optional[ShardCtx]):
    """One new token against a KV/state cache of ``seq_len``."""
    return transformer.decode_step(params, caches, batch["token"],
                                   batch["pos"], cfg, ctx)


# --------------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# --------------------------------------------------------------------------- #

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str,
                batch_override: Optional[int] = None) -> Dict[str, Any]:
    """Model inputs for a shape cell. For ``[audio]``/``[vlm]`` archs the
    modality frontend is a stub: specs carry precomputed frame/patch
    embeddings (audio) or pre-tokenized VQ ids (vlm)."""
    info = SHAPES[shape_name]
    s, b = info["seq_len"], batch_override or info["global_batch"]
    kind = info["kind"]
    if kind == "train":
        if cfg.embedding_inputs:
            return {"embeddings": _sds((b, s, cfg.d_model), jnp.bfloat16),
                    "labels": _sds((b, s), jnp.int32),
                    "mask": _sds((b, s), jnp.bool_)}
        return {"tokens": _sds((b, s), jnp.int32)}
    if kind == "prefill":
        if cfg.embedding_inputs:
            return {"embeddings": _sds((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a cache of length s
    return {"token": _sds((b,), jnp.int32),
            "pos": _sds((), jnp.int32)}


def cache_specs(cfg: ArchConfig, shape_name: str,
                batch_override: Optional[int] = None):
    info = SHAPES[shape_name]
    s, b = info["seq_len"], batch_override or info["global_batch"]
    caches = jax.eval_shape(
        lambda: transformer.init_decode_caches(cfg, b, s))
    return caches


def make_batch(cfg: ArchConfig, shape_name: str, rng: np.random.Generator,
               batch_override: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Concrete random batch matching input_specs (for smoke tests/examples)."""
    specs = input_specs(cfg, shape_name, batch_override)
    out = {}
    for k, sd in specs.items():
        if sd.dtype == jnp.int32 and k in ("tokens", "labels", "token"):
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=sd.shape), jnp.int32)
        elif k == "pos":
            out[k] = jnp.asarray(0, jnp.int32)
        elif sd.dtype == jnp.bool_:
            out[k] = jnp.asarray(rng.random(sd.shape) < 0.3)
        else:
            out[k] = jnp.asarray(rng.normal(size=sd.shape), jnp.float32
                                 ).astype(sd.dtype)
    return out


def init_all(key, cfg: ArchConfig, opt: bool = True):
    params, axes = transformer.init_params(key, cfg)
    opt_state = adamw_init(params) if opt else None
    return params, axes, opt_state
