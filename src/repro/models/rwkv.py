"""RWKV-6 "Finch" layer: data-dependent decay WKV + channel mix.

Faithful to arXiv:2404.05892: ddlerp token-shift (LoRA-modulated mixing),
per-channel data-dependent decay ``w = exp(-exp(w0 + lora(x_w)))``, per-head
bonus ``u``, grouped head-norm, gated output. The training path runs the WKV
recurrence with ``lax.scan`` (reference); the Pallas chunked kernel
(``kernels/rwkv6_wkv``) is the performance path.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Axes, Params, _dtype, dense_init

MIX_LORA = 32
MIX_NAMES = ("w", "k", "v", "r", "g")


def n_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv6_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    nh = n_heads(cfg)
    dt = _dtype(cfg.param_dtype)
    ks = iter(jax.random.split(key, 16))
    p: Params = {}
    a: Axes = {}

    # ddlerp token-shift mixing
    p["mu_x"], a["mu_x"] = jnp.full((d,), 0.5, dt), ("embed",)
    p["mu"], a["mu"] = jnp.full((5, d), 0.5, dt), (None, "embed")
    p["mix_w1"], a["mix_w1"] = dense_init(next(ks), (d, 5 * MIX_LORA),
                                          ("embed", None), dt)
    p["mix_w2"], a["mix_w2"] = (jax.random.normal(
        next(ks), (5, MIX_LORA, d)) * 0.01).astype(dt), (None, None, "embed")

    # data-dependent decay lora
    p["w0"], a["w0"] = jnp.full((d,), -2.0, dt), ("embed",)
    p["decay_w1"], a["decay_w1"] = dense_init(next(ks), (d, cfg.rwkv_lora_dim),
                                              ("embed", None), dt)
    p["decay_w2"], a["decay_w2"] = (jax.random.normal(
        next(ks), (cfg.rwkv_lora_dim, d)) * 0.01).astype(dt), (None, "embed")

    p["u"], a["u"] = (jax.random.normal(next(ks), (nh, hd)) * 0.1).astype(dt), \
        ("heads", "head_dim")
    for name in ("wr", "wk", "wv", "wg", "wo"):
        p[name], a[name] = dense_init(next(ks), (d, d), ("embed", "heads_x_dim"), dt)
    p["ln_x_scale"], a["ln_x_scale"] = jnp.ones((d,), dt), ("embed",)
    p["ln_x_bias"], a["ln_x_bias"] = jnp.zeros((d,), dt), ("embed",)

    # channel mix
    p["cm_mu_k"], a["cm_mu_k"] = jnp.full((d,), 0.5, dt), ("embed",)
    p["cm_mu_r"], a["cm_mu_r"] = jnp.full((d,), 0.5, dt), ("embed",)
    p["cm_k"], a["cm_k"] = dense_init(next(ks), (d, f), ("embed", "ff"), dt)
    p["cm_v"], a["cm_v"] = dense_init(next(ks), (f, d), ("ff", "embed"), dt)
    p["cm_r"], a["cm_r"] = dense_init(next(ks), (d, d), ("embed", "embed2"), dt)
    return p, a


def _ddlerp(p: Params, x, xs, cd):
    """Data-dependent lerp between x and its shift xs -> (x_w, x_k, x_v, x_r, x_g)."""
    xx = (xs - x).astype(cd)
    xxx = x + xx * p["mu_x"].astype(cd)
    lora = jnp.tanh(xxx @ p["mix_w1"].astype(cd))
    lora = lora.reshape(*lora.shape[:-1], 5, MIX_LORA)
    delta = jnp.einsum("...nl,nld->...nd", lora, p["mix_w2"].astype(cd))
    mix = p["mu"].astype(cd) + delta                        # (..., 5, d)
    return tuple(x + xx * mix[..., i, :] for i in range(5))


def _decay(p: Params, x_w, cd):
    ww = p["w0"].astype(cd) + jnp.tanh(
        x_w @ p["decay_w1"].astype(cd)) @ p["decay_w2"].astype(cd)
    return jnp.exp(-jnp.exp(ww.astype(jnp.float32)))        # (..., d) in (0,1)


def _head_norm(p: Params, y, nh, hd):
    """GroupNorm over each head's hd channels."""
    shape = y.shape
    yf = y.astype(jnp.float32).reshape(*shape[:-1], nh, hd)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(shape)
    return yn * p["ln_x_scale"].astype(jnp.float32) + \
        p["ln_x_bias"].astype(jnp.float32)


def wkv_scan(r, k, v, w, u):
    """Reference WKV recurrence. r/k/v/w: (B, S, H, hd) f32; u: (H, hd).

    y_t = r_t . (diag(u) k_t v_t^T + S_{t-1});  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns y: (B, S, H, hd), final state (B, H, hd, hd)."""
    b, s, h, hd = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                 # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       u[None, :, :, None] * kv + state)
        state = w_t[..., :, None] * state + kv
        return state, y

    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final


def time_mix(p: Params, x, xs, state, cfg: ArchConfig, *, use_kernel=False):
    """x: (B,S,d), xs: shifted x, state: (B,H,hd,hd) or None."""
    cd = jnp.float32   # WKV runs in f32 (decay products)
    b, s, d = x.shape
    nh, hd = n_heads(cfg), cfg.rwkv_head_dim
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x.astype(cd), xs.astype(cd), cd)
    w = _decay(p, x_w, cd).reshape(b, s, nh, hd)
    r = (x_r @ p["wr"].astype(cd)).reshape(b, s, nh, hd)
    k = (x_k @ p["wk"].astype(cd)).reshape(b, s, nh, hd)
    v = (x_v @ p["wv"].astype(cd)).reshape(b, s, nh, hd)
    g = jax.nn.silu(x_g @ p["wg"].astype(cd))
    u = p["u"].astype(cd)
    if state is None:
        state = jnp.zeros((b, nh, hd, hd), jnp.float32)
    if use_kernel:
        from repro.kernels.rwkv6_wkv import ops as wkv_ops
        y, new_state = wkv_ops.wkv(r, k, v, w, u, state)
    else:
        # fold initial state by prepending a virtual step? state==0 in train.
        y, new_state = _wkv_with_state(r, k, v, w, u, state)
    y = _head_norm(p, y.reshape(b, s, d), nh, hd)
    y = (y * g) @ p["wo"].astype(cd)
    return y.astype(x.dtype), new_state


def _wkv_with_state(r, k, v, w, u, s0):
    b, s, h, hd = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       u[None, :, :, None] * kv + state)
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final


def channel_mix(p: Params, x, xs, cfg: ArchConfig):
    cd = _dtype(cfg.compute_dtype)
    xc, xsc = x.astype(cd), xs.astype(cd)
    x_k = xc + (xsc - xc) * p["cm_mu_k"].astype(cd)
    x_r = xc + (xsc - xc) * p["cm_mu_r"].astype(cd)
    k = jnp.square(jax.nn.relu(x_k @ p["cm_k"].astype(cd)))
    return (jax.nn.sigmoid(x_r @ p["cm_r"].astype(cd))
            * (k @ p["cm_v"].astype(cd))).astype(x.dtype)


# --------------------------------------------------------------------------- #
# block-level apply (shift handling for train vs decode)
# --------------------------------------------------------------------------- #

def shift_train(x):
    """xs[t] = x[t-1], zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def rwkv_block_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    from repro.models.layers import norm_init
    k1, k2 = jax.random.split(key)
    tm, tma = rwkv6_init(k1, cfg)
    n1, n1a = norm_init(cfg, cfg.d_model)
    n2, n2a = norm_init(cfg, cfg.d_model)
    return ({"ln1": n1, "tm": tm, "ln2": n2},
            {"ln1": n1a, "tm": tma, "ln2": n2a})


def rwkv_block_apply(p: Params, x, cfg: ArchConfig, *, use_kernel=False):
    """Training forward of one RWKV6 block (time-mix + channel-mix)."""
    from repro.models.layers import norm_apply
    h = norm_apply(p["ln1"], x, cfg)
    y, _ = time_mix(p["tm"], h, shift_train(h), None, cfg,
                    use_kernel=use_kernel)
    x = x + y
    h = norm_apply(p["ln2"], x, cfg)
    x = x + channel_mix(p["tm"], h, shift_train(h), cfg)
    return x


def rwkv_block_decode(p: Params, x, state: Dict, cfg: ArchConfig):
    """Single-token step. x: (B, d). state: {tm_shift, cm_shift, wkv}."""
    from repro.models.layers import norm_apply
    h = norm_apply(p["ln1"], x[:, None, :], cfg)
    y, new_wkv = time_mix(p["tm"], h, state["tm_shift"][:, None, :],
                          state["wkv"], cfg)
    x = x + y[:, 0]
    h2 = norm_apply(p["ln2"], x[:, None, :], cfg)
    y2 = channel_mix(p["tm"], h2, state["cm_shift"][:, None, :], cfg)
    x = x + y2[:, 0]
    return x, dict(tm_shift=h[:, 0], cm_shift=h2[:, 0], wkv=new_wkv)


def rwkv_init_state(cfg: ArchConfig, batch: int):
    nh, hd = n_heads(cfg), cfg.rwkv_head_dim
    return dict(
        tm_shift=jnp.zeros((batch, cfg.d_model), jnp.float32),
        cm_shift=jnp.zeros((batch, cfg.d_model), jnp.float32),
        wkv=jnp.zeros((batch, nh, hd, hd), jnp.float32),
    )
