"""Shared neural layers: norms, RoPE, GQA attention, dense MLP.

Parameter trees are plain nested dicts; every init function returns a
parallel *axes* tree of logical-axis-name tuples consumed by
``launch.sharding`` to derive PartitionSpecs (MaxText-style logical axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
               dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    w = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return w.astype(dtype), axes


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #

def norm_init(cfg: ArchConfig, dim: int, axis_name: str = "embed"):
    dt = _dtype(cfg.param_dtype)
    if cfg.norm == "layernorm":
        return ({"scale": jnp.ones((dim,), dt), "bias": jnp.zeros((dim,), dt)},
                {"scale": (axis_name,), "bias": (axis_name,)})
    return {"scale": jnp.ones((dim,), dt)}, {"scale": (axis_name,)}


def norm_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" and "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """qk-norm: RMS norm over the head_dim axis (qwen3 / chameleon style)."""
    xf = x.astype(jnp.float32)
    var = (xf ** 2).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6)
            * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]                          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #

def attention_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Params = {}
    a: Axes = {}
    p["wq"], a["wq"] = dense_init(ks[0], (d, h, hd), ("embed", "heads", "head_dim"), dt)
    p["wk"], a["wk"] = dense_init(ks[1], (d, k, hd), ("embed", "kv_heads", "head_dim"), dt)
    p["wv"], a["wv"] = dense_init(ks[2], (d, k, hd), ("embed", "kv_heads", "head_dim"), dt)
    p["wo"], a["wo"] = dense_init(ks[3], (h, hd, d), ("heads", "head_dim", "embed"),
                                  dt, fan_in=h * hd)
    if cfg.qkv_bias:
        p["bq"], a["bq"] = jnp.zeros((h, hd), dt), ("heads", "head_dim")
        p["bk"], a["bk"] = jnp.zeros((k, hd), dt), ("kv_heads", "head_dim")
        p["bv"], a["bv"] = jnp.zeros((k, hd), dt), ("kv_heads", "head_dim")
        p["bo"], a["bo"] = jnp.zeros((d,), dt), ("embed",)
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = jnp.ones((hd,), dt), ("head_dim",)
        p["k_norm"], a["k_norm"] = jnp.ones((hd,), dt), ("head_dim",)
    return p, a


def _masked_softmax(logits, ok_mask, v_dtype, *, f32: bool):
    """Numerically-stable softmax over the last axis.

    ``f32=False`` keeps the (huge) probability tensor in the compute dtype
    with only the row statistics in f32 — the flash-attention numerics,
    expressed in plain HLO. Halves+ the S x T attention-byte footprint."""
    if f32:
        logits = jnp.where(ok_mask, logits.astype(jnp.float32), -1e30)
        return jax.nn.softmax(logits, axis=-1).astype(v_dtype)
    neg = jnp.asarray(-3e38, logits.dtype)
    logits = jnp.where(ok_mask, logits, neg)
    m = jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True)
    p = jnp.exp(logits - m.astype(logits.dtype))
    p = jnp.where(ok_mask, p, 0)
    denom = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
    return (p / jnp.maximum(denom, 1e-30).astype(p.dtype)).astype(v_dtype)


def _sdpa_reference(q, k, v, *, causal: bool, q_offset=0,
                    softmax_f32: bool = True) -> jnp.ndarray:
    """Grouped-query attention. q: (B,S,H,D), k/v: (B,T,K,D)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k) / np.sqrt(d).astype(
        q.dtype)
    if causal:
        qpos = jnp.arange(s)[:, None] + q_offset
        kpos = jnp.arange(t)[None, :]
        ok = (qpos >= kpos)[None, None, None]
    else:
        ok = jnp.ones((1, 1, 1, s, t), bool)
    w = _masked_softmax(logits, ok, v.dtype, f32=softmax_f32)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, d)


def attention_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                    positions: jnp.ndarray,
                    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    cache_pos: Optional[jnp.ndarray] = None,
                    ) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """x: (B, S, d). With ``cache`` (k_cache, v_cache of (B, T_max, K, D)):
    decode/prefill mode — new k/v written at ``cache_pos`` offset."""
    cd = _dtype(cfg.compute_dtype)
    xq = x.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xq, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", xq, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        kc, vc = cache
        off = cache_pos if cache_pos is not None else 0
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, off, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, off, 0, 0))
        new_cache = (kc, vc)
        t = kc.shape[1]
        # mask out slots beyond the current position
        kpos = jnp.arange(t)
        valid = kpos < (off + x.shape[1])
        k_att, v_att = kc.astype(cd), vc.astype(cd)
        if cfg.use_flash:
            from repro.kernels.flash_attention import ops as flash
            out = flash.flash_attention(
                q, k_att, v_att, causal=cfg.causal, q_offset=off,
                kv_valid_len=off + x.shape[1])
        else:
            b, s = q.shape[:2]
            kh = k_att.shape[2]
            g = q.shape[2] // kh
            qg = q.reshape(b, s, kh, g, q.shape[-1])
            logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_att) / \
                np.sqrt(q.shape[-1]).astype(cd)
            qpos = jnp.arange(s)[:, None] + off
            causal_ok = (qpos >= kpos[None, :]) if cfg.causal else True
            ok = jnp.logical_and(valid[None, :], causal_ok)[
                None, None, None]
            w = _masked_softmax(logits, ok, cd, f32=cfg.softmax_f32)
            out = jnp.einsum("bkgst,btkd->bskgd", w, v_att)
            out = out.reshape(b, s, -1, q.shape[-1])
    else:
        if cfg.use_flash:
            from repro.kernels.flash_attention import ops as flash
            out = flash.flash_attention(q, k, v, causal=cfg.causal)
        else:
            out = _sdpa_reference(q, k, v, causal=cfg.causal,
                                  softmax_f32=cfg.softmax_f32)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    if cfg.qkv_bias:
        y = y + p["bo"].astype(cd)
    return y.astype(x.dtype), new_cache


# --------------------------------------------------------------------------- #
# dense MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------- #

def mlp_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p: Params = {}
    a: Axes = {}
    if cfg.activation == "silu":
        p["wg"], a["wg"] = dense_init(ks[0], (d, f), ("embed", "ff"), dt)
    p["wi"], a["wi"] = dense_init(ks[1], (d, f), ("embed", "ff"), dt)
    p["wo"], a["wo"] = dense_init(ks[2], (f, d), ("ff", "embed"), dt)
    if cfg.qkv_bias:   # starcoder2-style: biases everywhere
        p["bi"], a["bi"] = jnp.zeros((f,), dt), ("ff",)
        p["bo"], a["bo"] = jnp.zeros((d,), dt), ("embed",)
    return p, a


def mlp_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    cd = _dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    h = xc @ p["wi"].astype(cd)
    if "bi" in p:
        h = h + p["bi"].astype(cd)
    if cfg.activation == "silu":
        g = xc @ p["wg"].astype(cd)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = h @ p["wo"].astype(cd)
    if "bo" in p:
        y = y + p["bo"].astype(cd)
    return y.astype(x.dtype)
