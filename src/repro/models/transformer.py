"""Layer stacks for all assigned architectures.

One code path covers dense / MoE / VLM / audio-encoder transformers; the
hybrid (zamba2) and SSM (rwkv6) stacks plug their own block functions into
the same scan-over-layers skeleton. Parameters are stacked along a leading
``layers`` axis and consumed by ``lax.scan`` (fast compiles at 48–81 layers),
with configurable activation rematerialization.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (Axes, Params, _dtype, attention_apply,
                                 attention_init, dense_init, mlp_apply,
                                 mlp_init, norm_apply, norm_init)

ShardCtx = moe_mod.ShardCtx


def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(keys[0])
    axes = jax.tree.map(lambda t: ("layers",) + tuple(t),
                        axes, is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _scan_layers(body, carry, xs, cfg: ArchConfig):
    """lax.scan over stacked layers, or an unrolled python loop.

    The unrolled form (``cfg.scan_layers=False``) exists for the dry-run's
    cost accounting: XLA's HLO cost analysis counts a while-loop body once,
    so scanned models under-report flops/collectives by ~n_layers; the
    dry-run compiles small unrolled variants to extrapolate per-layer cost.
    """
    if cfg.scan_layers:
        return jax.lax.scan(_remat(body, cfg), carry, xs)
    n = cfg.n_layers
    ys = []
    fn = _remat(body, cfg)
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = fn(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a, axis=0), *ys)
    else:
        ys = None
    return carry, ys


# --------------------------------------------------------------------------- #
# attention-family block (dense / moe / vlm / audio)
# --------------------------------------------------------------------------- #

def attn_block_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {}
    a: Axes = {}
    p["ln1"], a["ln1"] = norm_init(cfg, cfg.d_model)
    p["attn"], a["attn"] = attention_init(k1, cfg)
    p["ln2"], a["ln2"] = norm_init(cfg, cfg.d_model)
    if cfg.is_moe:
        p["moe"], a["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"], a["mlp"] = mlp_init(k2, cfg)
    return p, a


def attn_block_apply(p: Params, x, cfg: ArchConfig, ctx: Optional[ShardCtx],
                     *, positions, cache=None, cache_pos=None):
    x = constrain_activations(x, cfg)
    h = norm_apply(p["ln1"], x, cfg)
    y, new_cache = attention_apply(p["attn"], h, cfg, positions=positions,
                                   cache=cache, cache_pos=cache_pos)
    x = x + y
    h = norm_apply(p["ln2"], x, cfg)
    if cfg.is_moe:
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg, ctx)
    else:
        y, aux = mlp_apply(p["mlp"], h, cfg), jnp.float32(0.0)
    return x + y, new_cache, aux


# --------------------------------------------------------------------------- #
# embedding / head
# --------------------------------------------------------------------------- #

def embed_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    dt = _dtype(cfg.param_dtype)
    p: Params = {}
    a: Axes = {}
    if not cfg.embedding_inputs:
        p["embed"], a["embed"] = dense_init(
            key, (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dt,
            fan_in=cfg.d_model)
    p["ln_f"], a["ln_f"] = norm_init(cfg, cfg.d_model)
    if not cfg.tied_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["head"], a["head"] = dense_init(
            k2, (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt)
    return p, a


def embed_tokens(p: Params, tokens, cfg: ArchConfig):
    cd = _dtype(cfg.compute_dtype)
    return jnp.take(p["embed"], tokens, axis=0).astype(cd)


BATCH_AXES = ("pod", "data")
BATCH_AXES_DP = ("pod", "data", "model")


def _batch_axes_for(cfg, dim_size):
    """Profile- and divisibility-aware batch axis tuple."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return BATCH_AXES
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        prefer = (BATCH_AXES_DP if cfg is not None
                  and cfg.sharding_profile == "dp" else BATCH_AXES)
        candidates = [prefer, ("data", "model"), ("pod", "data"), ("data",)]
        for cand in candidates:
            present = tuple(a for a in cand if a in sizes)
            if not present:
                continue
            total = 1
            for a in present:
                total *= sizes[a]
            if dim_size % total == 0:
                return present
        return ()
    except Exception:  # noqa: BLE001
        return BATCH_AXES


def _maybe_constrain(x, spec_names):
    """with_sharding_constraint if the ambient mesh has the named axes.

    Each entry is an axis name, a tuple of names (joint sharding), or None.
    Missing axes are dropped; with no mesh context this is a no-op."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        from jax.sharding import PartitionSpec as P
        parts = []
        for n in spec_names:
            if n is None:
                parts.append(None)
            elif isinstance(n, tuple):
                present = tuple(a for a in n if a in mesh.axis_names)
                parts.append(present if present else None)
            else:
                parts.append(n if n in mesh.axis_names else None)
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:  # noqa: BLE001 — no mesh context (plain CPU tests)
        return x


def constrain_activations(x, cfg=None):
    """Pin (B, S, d) activations to batch sharding at block boundaries.

    Without this, the sharded-embedding gather produces replicated
    activations and SPMD happily replicates every layer's compute
    (measured: per-layer flops == global flops on EVERY device).
    The dp profile spreads batch over the model axis too."""
    axes = _batch_axes_for(cfg, x.shape[0])
    if not axes:
        return x
    if x.ndim == 3:
        return _maybe_constrain(x, (axes, None, None))
    if x.ndim == 2:
        return _maybe_constrain(x, (axes, None))
    return x


def lm_head(p: Params, x, cfg: ArchConfig):
    cd = _dtype(cfg.compute_dtype)
    h = norm_apply(p["ln_f"], x, cfg)
    w = (p["embed"].T if cfg.tied_embeddings else p["head"]).astype(cd)
    if cfg.tied_embeddings:
        # embed is (vocab->model, embed->data)-sharded; contracting the
        # data-sharded embed dim would all-reduce full (B,S,V) logits.
        # Gathering the (small) table over data first keeps logits
        # batch x vocab sharded.
        w = _maybe_constrain(w, (None, "model"))
    logits = (h.astype(cd) @ w).astype(jnp.float32)
    if logits.ndim == 3:
        axes = _batch_axes_for(cfg, logits.shape[0])
        vocab_ax = None if (not axes or "model" in axes) else "model"
        logits = _maybe_constrain(logits, (axes or None, None, vocab_ax))
    return logits


# --------------------------------------------------------------------------- #
# full model init
# --------------------------------------------------------------------------- #

def init_params(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    k_emb, k_blocks, k_shared = jax.random.split(key, 3)
    p, a = embed_init(k_emb, cfg)

    if cfg.rwkv:
        blk = functools.partial(rwkv_mod.rwkv_block_init, cfg=cfg)
        p["blocks"], a["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda k: blk(k))
    elif cfg.family in ("ssm", "hybrid"):
        def mamba_blk(k):
            bp, ba = ssm_mod.mamba2_init(k, cfg)
            np_, na = norm_init(cfg, cfg.d_model)
            return {"ln": np_, "mamba": bp}, {"ln": na, "mamba": ba}
        p["blocks"], a["blocks"] = _stack_init(
            k_blocks, cfg.n_layers, lambda k: mamba_blk(k))
        if cfg.attn_every:
            p["shared"], a["shared"] = attn_block_init(k_shared, cfg)
    else:
        p["blocks"], a["blocks"] = _stack_init(
            k_blocks, cfg.n_layers,
            lambda k: attn_block_init(k, cfg))
    return p, a


def n_shared_apps(cfg: ArchConfig) -> int:
    if not cfg.attn_every:
        return 0
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


# --------------------------------------------------------------------------- #
# training/prefill forward
# --------------------------------------------------------------------------- #

def forward(p: Params, inputs, cfg: ArchConfig, ctx: Optional[ShardCtx],
            *, collect_cache: bool = False):
    """inputs: tokens (B, S) int32 or embeddings (B, S, d).

    Returns (logits, aux, caches). ``collect_cache`` materializes KV/state
    caches for prefill (attention archs get (L,B,S,K,hd) caches sized S).
    """
    cd = _dtype(cfg.compute_dtype)
    if cfg.embedding_inputs:
        x = inputs.astype(cd)
        b, s = x.shape[:2]
    else:
        x = embed_tokens(p, inputs, cfg)
        b, s = inputs.shape
    x = constrain_activations(x, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    if cfg.rwkv:
        def body(x, blk_p):
            x = constrain_activations(x, cfg)
            h = norm_apply(blk_p["ln1"], x, cfg)
            y, wkv = rwkv_mod.time_mix(blk_p["tm"], h,
                                       rwkv_mod.shift_train(h), None, cfg,
                                       use_kernel=cfg.use_flash)
            x = x + y
            h2 = norm_apply(blk_p["ln2"], x, cfg)
            x = x + rwkv_mod.channel_mix(blk_p["tm"], h2,
                                         rwkv_mod.shift_train(h2), cfg)
            st = (dict(tm_shift=h[:, -1].astype(jnp.float32),
                       cm_shift=h2[:, -1].astype(jnp.float32), wkv=wkv)
                  if collect_cache else None)
            return x, st

        x, states = _scan_layers(body, x, p["blocks"], cfg)
        return lm_head(p, x, cfg), jnp.float32(0.0), states

    if cfg.family in ("ssm", "hybrid"):
        shared = p.get("shared")
        napps = n_shared_apps(cfg)
        if collect_cache and cfg.attn_every:
            kshape = (napps, b, s, cfg.n_kv_heads, cfg.resolved_head_dim)
            kc0, vc0 = jnp.zeros(kshape, cd), jnp.zeros(kshape, cd)
        else:
            kc0 = vc0 = jnp.zeros((1, 1, 1, 1, 1), cd)

        def body(carry, inp):
            x, aux, kc, vc = carry
            x = constrain_activations(x, cfg)
            idx, blk_p = inp
            if shared is not None:
                app = idx // cfg.attn_every

                def with_attn(args):
                    x, kc, vc = args
                    if collect_cache:
                        kci = jax.lax.dynamic_index_in_dim(kc, app, 0, False)
                        vci = jax.lax.dynamic_index_in_dim(vc, app, 0, False)
                        y, ncache, _ = attn_block_apply(
                            shared, x, cfg, ctx, positions=positions,
                            cache=(kci, vci), cache_pos=0)
                        kc = jax.lax.dynamic_update_index_in_dim(
                            kc, ncache[0], app, 0)
                        vc = jax.lax.dynamic_update_index_in_dim(
                            vc, ncache[1], app, 0)
                    else:
                        y, _, _ = attn_block_apply(
                            shared, x, cfg, ctx, positions=positions)
                    return y, kc, vc

                x, kc, vc = jax.lax.cond(idx % cfg.attn_every == 0,
                                         with_attn, lambda a: a, (x, kc, vc))
            h = norm_apply(blk_p["ln"], x, cfg)
            y, st = ssm_mod.mamba2_apply(blk_p["mamba"], h, cfg,
                                         return_state=collect_cache)
            return (x + y, aux, kc, vc), st

        idxs = jnp.arange(cfg.n_layers)
        (x, aux, kc, vc), states = _scan_layers(
            body, (x, jnp.float32(0.0), kc0, vc0), (idxs, p["blocks"]), cfg)
        caches = None
        if collect_cache:
            caches = dict(conv=states["conv"], ssm=states["ssm"])
            if cfg.attn_every:
                caches["k"], caches["v"] = kc, vc
        return lm_head(p, x, cfg), aux, caches

    # attention family
    if collect_cache:
        kshape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.resolved_head_dim)
        kc = jnp.zeros(kshape, cd)
        vc = jnp.zeros(kshape, cd)

        def body(carry, inp):
            x, aux, li = carry
            blk_p, kcl, vcl = inp
            x, new_cache, a2 = attn_block_apply(
                blk_p, x, cfg, ctx, positions=positions,
                cache=(kcl, vcl), cache_pos=0)
            return (x, aux + a2, li + 1), new_cache

        (x, aux, _), caches = _scan_layers(
            body, (x, jnp.float32(0.0), 0), (p["blocks"], kc, vc), cfg)
        caches = {"k": caches[0], "v": caches[1]}
    else:
        def body(carry, blk_p):
            x, aux = carry
            x, _, a2 = attn_block_apply(blk_p, x, cfg, ctx,
                                        positions=positions)
            return (x, aux + a2), None

        (x, aux), _ = _scan_layers(body, (x, jnp.float32(0.0)),
                                   p["blocks"], cfg)
        caches = None
    return lm_head(p, x, cfg), aux, caches


# --------------------------------------------------------------------------- #
# decode (one token, cached)
# --------------------------------------------------------------------------- #

def init_decode_caches(cfg: ArchConfig, batch: int, max_len: int):
    cd = _dtype(cfg.compute_dtype)
    if cfg.rwkv:
        nh, hd = rwkv_mod.n_heads(cfg), cfg.rwkv_head_dim
        return dict(
            tm_shift=jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
            cm_shift=jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
            wkv=jnp.zeros((cfg.n_layers, batch, nh, hd, hd), jnp.float32))
    if cfg.family in ("ssm", "hybrid"):
        dm = ssm_mod.dims(cfg)
        caches = dict(
            conv=jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                            dm["conv_dim"]), cd),
            ssm=jnp.zeros((cfg.n_layers, batch, dm["n_heads"],
                           cfg.ssm_state, cfg.ssm_head_dim), jnp.float32))
        if cfg.attn_every:
            napps = n_shared_apps(cfg)
            kshape = (napps, batch, max_len, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
            caches["k"] = jnp.zeros(kshape, cd)
            caches["v"] = jnp.zeros(kshape, cd)
        return caches
    kshape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads,
              cfg.resolved_head_dim)
    return {"k": jnp.zeros(kshape, cd), "v": jnp.zeros(kshape, cd)}


def decode_step(p: Params, caches, token, pos, cfg: ArchConfig,
                ctx: Optional[ShardCtx]):
    """token: (B,) int32, pos: scalar int32 — returns (logits, new caches)."""
    cd = _dtype(cfg.compute_dtype)
    x = embed_tokens(p, token[:, None], cfg)        # (B, 1, d)
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    if cfg.rwkv:
        def body(x1, inp):
            x1 = constrain_activations(x1, cfg)
            blk_p, st = inp
            y, new_st = rwkv_mod.rwkv_block_decode(blk_p, x1, st, cfg)
            return y, new_st
        x1, new_states = _scan_layers(
            body, x[:, 0],
            (p["blocks"], {k: caches[k] for k in
                           ("tm_shift", "cm_shift", "wkv")}), cfg)
        logits = lm_head(p, x1[:, None, :], cfg)
        return logits[:, 0], new_states

    if cfg.family in ("ssm", "hybrid"):
        shared = p.get("shared")
        napps = n_shared_apps(cfg)

        def body(carry, inp):
            x1, kc, vc = carry                       # x1: (B, d)
            x1 = constrain_activations(x1, cfg)
            idx, blk_p, conv_st, ssm_st = inp
            if shared is not None:
                app = idx // cfg.attn_every

                def with_attn(args):
                    x1, kc, vc = args
                    kci = jax.lax.dynamic_index_in_dim(kc, app, 0, False)
                    vci = jax.lax.dynamic_index_in_dim(vc, app, 0, False)
                    y, new_cache, _ = attn_block_apply(
                        shared, x1[:, None, :], cfg, ctx,
                        positions=positions, cache=(kci, vci), cache_pos=pos)
                    kc = jax.lax.dynamic_update_index_in_dim(
                        kc, new_cache[0], app, 0)
                    vc = jax.lax.dynamic_update_index_in_dim(
                        vc, new_cache[1], app, 0)
                    return y[:, 0], kc, vc

                x1, kc, vc = jax.lax.cond(
                    idx % cfg.attn_every == 0, with_attn,
                    lambda a: a, (x1, kc, vc))
            h = norm_apply(blk_p["ln"], x1[:, None, :], cfg)[:, 0]
            y, new_st = ssm_mod.mamba2_decode(
                blk_p["mamba"], h, dict(conv=conv_st, ssm=ssm_st), cfg)
            return (x1 + y, kc, vc), (new_st["conv"], new_st["ssm"])

        idxs = jnp.arange(cfg.n_layers)
        kc = caches.get("k", jnp.zeros((max(napps, 1), b, 1, 1, 1), cd))
        vc = caches.get("v", jnp.zeros((max(napps, 1), b, 1, 1, 1), cd))
        (x1, kc, vc), (conv_new, ssm_new) = _scan_layers(
            body, (x[:, 0], kc, vc),
            (idxs, p["blocks"], caches["conv"], caches["ssm"]), cfg)
        new_caches = dict(conv=conv_new, ssm=ssm_new)
        if cfg.attn_every:
            new_caches["k"], new_caches["v"] = kc, vc
        logits = lm_head(p, x1[:, None, :], cfg)
        return logits[:, 0], new_caches

    # attention family
    def body(carry, inp):
        x1, aux = carry
        blk_p, kcl, vcl = inp
        y, new_cache, a2 = attn_block_apply(
            blk_p, x1, cfg, ctx, positions=positions,
            cache=(kcl, vcl), cache_pos=pos)
        return (y, aux + a2), new_cache

    (x, _), new_kv = _scan_layers(
        body, (x, jnp.float32(0.0)), (p["blocks"], caches["k"], caches["v"]),
        cfg)
    logits = lm_head(p, x, cfg)
    return logits[:, 0], {"k": new_kv[0], "v": new_kv[1]}
