"""Mamba2 (SSD — state-space duality) layer, chunked-scan formulation.

Used by zamba2-7b's backbone. The chunked algorithm (Dao & Gu, 2024) splits
the sequence into chunks: a quadratic intra-chunk term (MXU-friendly matmuls)
plus a linear inter-chunk state recurrence (``lax.scan`` over chunk states).
A single-token step (``mamba2_decode``) carries (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import Axes, Params, _dtype, dense_init

N_GROUPS = 1


def dims(cfg: ArchConfig) -> Dict[str, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    return dict(
        d_in=d_in,
        n_heads=d_in // cfg.ssm_head_dim,
        conv_dim=d_in + 2 * N_GROUPS * cfg.ssm_state,
    )


def mamba2_init(key, cfg: ArchConfig) -> Tuple[Params, Axes]:
    d = cfg.d_model
    dm = dims(cfg)
    d_in, nh, conv_dim = dm["d_in"], dm["n_heads"], dm["conv_dim"]
    proj_out = 2 * d_in + 2 * N_GROUPS * cfg.ssm_state + nh
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p: Params = {}
    a: Axes = {}
    p["in_proj"], a["in_proj"] = dense_init(ks[0], (d, proj_out),
                                            ("embed", "ff"), dt)
    p["conv_w"], a["conv_w"] = (
        jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim)) * 0.1
    ).astype(dt), (None, "ff")
    p["conv_b"], a["conv_b"] = jnp.zeros((conv_dim,), dt), ("ff",)
    # dt in [0.001, 0.1] via softplus-inverse init
    dt0 = np.exp(np.random.default_rng(0).uniform(
        np.log(1e-3), np.log(1e-1), nh)).astype(np.float32)
    p["dt_bias"], a["dt_bias"] = jnp.asarray(
        dt0 + np.log(-np.expm1(-dt0)), dt), ("heads",)
    p["A_log"], a["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dt), ("heads",)
    p["D"], a["D"] = jnp.ones((nh,), dt), ("heads",)
    p["norm"], a["norm"] = jnp.ones((d_in,), dt), ("ff",)
    p["out_proj"], a["out_proj"] = dense_init(ks[2], (d_in, d),
                                              ("ff", "embed"), dt)
    return p, a


def _split_proj(zxbcdt, cfg: ArchConfig):
    dm = dims(cfg)
    d_in, nh = dm["d_in"], dm["n_heads"]
    gs = N_GROUPS * cfg.ssm_state
    z, xbc, dt = jnp.split(zxbcdt, [d_in, d_in + d_in + 2 * gs], axis=-1)
    return z, xbc, dt  # z: (..., d_in), xbc: (..., d_in + 2gs), dt: (..., nh)


def _conv_train(xbc, w, b):
    """Causal depthwise conv over seq. xbc: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, scale):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = (yf ** 2).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def mamba2_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                 return_state: bool = False):
    """Training/prefill forward. x: (B, S, d) with S % ssm_chunk == 0.

    With ``return_state`` also returns decode-ready {conv, ssm} states."""
    cd = _dtype(cfg.compute_dtype)
    b, s, d = x.shape
    dm = dims(cfg)
    d_in, nh, hd, nstate = (dm["d_in"], dm["n_heads"], cfg.ssm_head_dim,
                            cfg.ssm_state)
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    zxbcdt = x.astype(cd) @ p["in_proj"].astype(cd)
    z, xbc_raw, dtr = _split_proj(zxbcdt, cfg)
    xbc = _conv_train(xbc_raw, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xs, bc = jnp.split(xbc, [d_in], axis=-1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)                  # (B,S,g*N) each
    xh = xs.reshape(b, nc, q, nh, hd)
    bmat = bmat.reshape(b, nc, q, N_GROUPS, nstate).astype(jnp.float32)
    cmat = cmat.reshape(b, nc, q, N_GROUPS, nstate).astype(jnp.float32)

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    da = (dt * a).reshape(b, nc, q, nh)                        # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                               # inclusive

    # ---- intra-chunk (quadratic in Q) --------------------------------- #
    # L[t, j] = exp(cum_t - cum_j) for t >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqgn,bcjgn->bcqj", cmat, bmat)       # g=1
    dtj = dt.reshape(b, nc, q, nh)
    att = scores[..., None] * lmat * dtj[:, :, None, :, :]     # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqjh,bcjhp->bcqhp",
                         att.astype(cd), xh)

    # ---- chunk states + inter-chunk recurrence ------------------------ #
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,Q,H)
    state_contrib = jnp.einsum(
        "bcjgn,bcjh,bcjhp->bchnp",
        bmat, (decay_to_end * dtj).astype(jnp.float32),
        xh.astype(jnp.float32))                                 # (B,nc,H,N,hd)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def scan_fn(s_prev, inp):
        contrib, dec = inp                                     # (B,H,N,hd),(B,H)
        s_new = s_prev * dec[:, :, None, None] + contrib
        return s_new, s_prev

    s0 = jnp.zeros((b, nh, nstate, hd), jnp.float32)
    s_final, s_before = jax.lax.scan(
        scan_fn, s0,
        (state_contrib.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)               # (B,nc,H,N,hd)

    y_inter = jnp.einsum(
        "bcqgn,bcqh,bchnp->bcqhp",
        cmat, jnp.exp(cum), s_before.astype(jnp.float32)).astype(cd)

    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    y = y + xh.reshape(b, s, nh, hd) * p["D"].astype(cd)[None, None, :, None]
    y = _gated_norm(y.reshape(b, s, d_in), z, p["norm"])
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    if return_state:
        tail = xbc_raw[:, -(cfg.ssm_conv - 1):, :]
        return out, dict(conv=tail, ssm=s_final)
    return out, None


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #

def mamba2_init_state(cfg: ArchConfig, batch: int):
    dm = dims(cfg)
    return dict(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, dm["conv_dim"]),
                       _dtype(cfg.compute_dtype)),
        ssm=jnp.zeros((batch, dm["n_heads"], cfg.ssm_state,
                       cfg.ssm_head_dim), jnp.float32),
    )


def mamba2_decode(p: Params, x: jnp.ndarray, state: Dict[str, jnp.ndarray],
                  cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token step. x: (B, d)."""
    cd = _dtype(cfg.compute_dtype)
    b, d = x.shape
    dm = dims(cfg)
    d_in, nh, hd, nstate = (dm["d_in"], dm["n_heads"], cfg.ssm_head_dim,
                            cfg.ssm_state)
    zxbcdt = x.astype(cd) @ p["in_proj"].astype(cd)
    z, xbc_new, dtr = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([state["conv"], xbc_new[:, None, :]], axis=1)
    w = p["conv_w"].astype(cd)
    xbc = jax.nn.silu((conv_in * w[None]).sum(1) + p["conv_b"].astype(cd))
    new_conv = conv_in[:, 1:, :]

    xs, bc = jnp.split(xbc, [d_in], axis=-1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    bmat = bmat.reshape(b, N_GROUPS, nstate).astype(jnp.float32)
    cmat = cmat.reshape(b, N_GROUPS, nstate).astype(jnp.float32)
    xh = xs.reshape(b, nh, hd).astype(jnp.float32)

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                       # (B,H)
    contrib = jnp.einsum("bgn,bh,bhp->bhnp", bmat, dt, xh)
    new_ssm = state["ssm"] * dec[:, :, None, None] + contrib
    y = jnp.einsum("bgn,bhnp->bhp", cmat, new_ssm)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = _gated_norm(y.reshape(b, d_in).astype(cd), z, p["norm"])
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    return out, dict(conv=new_conv, ssm=new_ssm)
