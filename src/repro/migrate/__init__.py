"""repro.migrate — online migration engine.

Turns an accepted adaptation into a :class:`MigrationSession`: the
``MigrationPlan`` is chunked (hottest workload features first, bounded by a
per-step bytes budget) and applied incrementally while queries keep being
served against the consistent hybrid layout in between. See
``docs/api.md`` ("Migration sessions") for the lifecycle.
"""
from repro.core.migration import (MigrationChunk, MigrationPlan, chunk_plan,
                                  feature_heat, migration_seconds)
from repro.migrate.session import MigrationSession

__all__ = [
    "MigrationChunk",
    "MigrationPlan",
    "MigrationSession",
    "chunk_plan",
    "feature_heat",
    "migration_seconds",
]
