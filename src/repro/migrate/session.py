"""MigrationSession — chunked, serving-friendly application of an accepted
migration (AdPart/xDGP-style incremental redistribution).

An accepted adaptation round no longer commits its ``MigrationPlan``
atomically. Instead it becomes a session: the plan is split into prioritized
``MigrationChunk``s (hottest workload features first, each bounded by a
per-step ``bytes_budget``) and each ``step()`` applies exactly one chunk to
the live ``PartitionedKG`` as an incremental delta. Between steps the facade
serves a consistent *hybrid* layout — some features already at their target
shard, the rest still at the source — which is a first-class epoch: queries
return exactly the same bindings as under any other layout (only federation
stats differ), cached plans are invalidated per epoch, and only the shards a
chunk actually touches are re-indexed.

    session, report = partitioner.adapt(kg, new_queries)   # nothing moved yet
    while not session.done:
        serve_a_window_of_queries()
        session.step()                  # one bounded chunk of migration I/O
    # kg.state is now byte-identical to the accepted target layout

``KGService`` owns the session lifecycle (``svc.step()`` / ``svc.drain()``,
interleaved with ``query_batch`` windows under the ``migration_budget``
knob); this module is the mechanism.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import migration
from repro.core.partition import PartitionState


class MigrationSession:
    """Drains one accepted ``MigrationPlan`` into a live ``PartitionedKG``
    in bounded chunks.

    Parameters
    ----------
    kg : PartitionedKG
        The live facade (its universe must already match ``target`` — the
        partitioner calls ``kg.sync_universe()`` before building a session).
    target : PartitionState
        The accepted destination layout; after ``drain()`` the facade's
        state is exactly this.
    plan : MigrationPlan, optional
        The delta to apply (derived from ``kg.state`` vs ``target`` when
        omitted).
    bytes_budget : int, optional
        Per-step migration-traffic bound; ``None`` = unbounded (one chunk —
        the old atomic commit).
    priority : np.ndarray, optional
        Per-feature heat (see ``migration.feature_heat``); hottest features
        migrate in the earliest chunks.
    net : NetworkModel-like, optional
        Used by ``step_seconds``/``total_seconds`` to price chunk traffic.
    target_replicas : ReplicaMap, optional
        The accepted destination replica layout (``repro.replicate``).
        Promotions/demotions ride the same chunks as moves — copy traffic
        drains under the same budget — and after ``drain()`` the facade's
        ``ReplicaMap`` equals this exactly.
    """

    def __init__(self, kg, target: PartitionState,
                 plan: Optional[migration.MigrationPlan] = None, *,
                 bytes_budget: Optional[int] = None,
                 priority: Optional[np.ndarray] = None,
                 net=None, target_replicas=None):
        self.kg = kg
        self.target = target
        self.target_replicas = target_replicas
        self.plan = plan if plan is not None \
            else migration.plan(kg.state, target,
                                getattr(kg, "replicas", None)
                                if target_replicas is not None else None,
                                target_replicas)
        self.net = net
        budget = self.plan.bytes if bytes_budget is None else bytes_budget
        self.chunks: List[migration.MigrationChunk] = migration.chunk_plan(
            self.plan, target.feature_sizes, budget, priority)
        self.applied = 0
        self.bytes_applied = 0
        # epoch trail: facade epoch at session start and after every step —
        # every entry is a layout the session actually served
        self.epochs: List[int] = [kg.epoch]

    @classmethod
    def noop(cls, kg) -> "MigrationSession":
        """An already-drained session (rejected round / nothing to move)."""
        return cls(kg, kg.state, migration.MigrationPlan([], 0, 0))

    # ------------------------------------------------------------------ #
    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def done(self) -> bool:
        return self.applied >= len(self.chunks)

    @property
    def remaining_bytes(self) -> int:
        return self.plan.bytes - self.bytes_applied

    def progress(self) -> float:
        """Fraction of migration traffic already applied, in [0, 1]."""
        return 1.0 if self.plan.bytes == 0 \
            else self.bytes_applied / self.plan.bytes

    def peek(self) -> Optional[migration.MigrationChunk]:
        """The chunk the next ``step()`` would apply (``None`` when drained)
        — without applying it. The streaming drainer (``repro.stream``) uses
        this to size the stall it is about to interleave into an idle gap
        before committing to it."""
        return None if self.done else self.chunks[self.applied]

    # ------------------------------------------------------------------ #
    def step(self) -> Optional[migration.MigrationChunk]:
        """Apply the next chunk as an incremental delta on the facade.
        Returns the applied chunk, or ``None`` when already drained. After
        the final step the facade's layout equals ``target`` exactly."""
        if self.done:
            return None
        chunk = self.chunks[self.applied]
        self.kg.apply_chunk(chunk)
        self.applied += 1
        self.bytes_applied += chunk.bytes
        self.epochs.append(self.kg.epoch)
        m = getattr(self.kg, "metrics", None)
        if m is not None:           # repro.obs: drain progress counters
            m.counter("migrate.chunks").inc()
            m.counter("migrate.bytes").inc(chunk.bytes)
            m.counter("migrate.moved_triples").inc(chunk.n_triples)
            m.gauge("migrate.progress").set(self.progress())
        if self.done:
            # compare the target's universe only: live writes during the
            # drain may have grown the feature universe (repro.write), and
            # write-born features stay wherever the write path placed them
            # — the session owns exactly the features its target knows
            nf = len(self.target.feature_to_shard)
            assert np.array_equal(self.kg.state.feature_to_shard[:nf],
                                  self.target.feature_to_shard), \
                "drained session must land exactly on the target layout"
            assert self.target_replicas is None or np.array_equal(
                self.kg.replicas.masks[:len(self.target_replicas.masks)],
                self.target_replicas.masks), \
                "drained session must land exactly on the target replicas"
        return chunk

    def drain(self) -> int:
        """Apply every remaining chunk; returns how many were applied."""
        n = 0
        while self.step() is not None:
            n += 1
        return n

    # ------------------------------------------------------------------ #
    def step_seconds(self, chunk: migration.MigrationChunk) -> float:
        """Modeled traffic time of one chunk under the session's net model."""
        return migration.migration_seconds(chunk, self._net())

    def total_seconds(self) -> float:
        """Modeled traffic time of the whole plan (the atomic-commit spike
        a chunked drain spreads across windows)."""
        return migration.migration_seconds(self.plan, self._net())

    def _net(self):
        if self.net is None:
            from repro.query.exec import NetworkModel
            self.net = NetworkModel()
        return self.net

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"MigrationSession({self.applied}/{self.n_chunks} chunks, "
                f"{self.bytes_applied}/{self.plan.bytes} bytes, "
                f"epoch={self.kg.epoch})")
