"""Central metrics registry: counters, gauges, histograms.

One registry per :class:`~repro.api.service.KGService`, threaded through
the facade, executors, ``repro.stream``, ``repro.migrate``,
``repro.replicate``, ``repro.write``, and ``kernels.dispatch`` — so the
signals the adaptation loop runs on (cross-shard joins, bytes shipped
vs. replica-served, cache hit rates, kernel tier picks, queue-vs-execute
split) are all visible in one ``svc.stats()["metrics"]`` snapshot.

Instruments are created on first use (``registry.counter(name).inc()``)
and named with dotted paths (``federation.bytes_shipped``,
``kernels.dispatch.join.pipeline.oracle``). Snapshots sort names so the
output is deterministic; ``to_csv`` emits a standalone file that
``results/make_table.py`` renders as a ``metrics_table``.

``kernels.dispatch`` has no service handle, so the module also keeps an
*ambient* registry hook: the most recently constructed service installs
its registry via :func:`set_ambient`, and dispatch-tier counters land
there. ``NULL_METRICS`` is the inert default for facades built outside
a service.
"""
from __future__ import annotations

import csv
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NullRegistry", "NULL_METRICS", "set_ambient", "ambient"]


class Counter:
    """Monotone count (events, rows, bytes)."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0

    def inc(self, v: int = 1) -> None:
        self.value += v


class Gauge:
    """Last-written (or max-tracked) level: headroom, epoch, depth."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def track_max(self, v: float) -> float:
        if v > self.value:
            self.value = float(v)
        return self.value


class Histogram:
    """Raw-sample histogram; summarized (p50/p95/p99) at snapshot time.
    Sample counts here are per-run and small (one per query/window), so
    keeping raw values stays cheap and exact."""

    __slots__ = ("values",)
    kind = "histogram"

    def __init__(self):
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def summary(self) -> Dict[str, float]:
        vals = self.values
        if not vals:
            return dict(n=0, mean=0.0, p50=0.0, p95=0.0, p99=0.0, max=0.0)
        arr = np.asarray(vals, dtype=np.float64)
        p50, p95, p99 = np.percentile(arr, (50.0, 95.0, 99.0))
        return dict(n=len(vals), mean=float(arr.mean()), p50=float(p50),
                    p95=float(p95), p99=float(p99), max=float(arr.max()))


class MetricsRegistry:
    """Name → instrument map with on-demand creation. A name is bound to
    one instrument kind for its lifetime (asking for a counter where a
    gauge lives is a bug, surfaced loudly)."""

    def __init__(self):
        self._instruments: Dict[str, Any] = {}

    def __len__(self) -> int:
        return len(self._instruments)

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls()
        elif type(inst) is not cls:
            raise TypeError(f"metric {name!r} is a {inst.kind}, "
                            f"not a {cls.kind}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, Any]:
        """Deterministic nested dict: ``counters`` / ``gauges`` map name
        to value, ``histograms`` map name to a percentile summary."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Counter):
                out["counters"][name] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][name] = inst.value
            else:
                out["histograms"][name] = inst.summary()
        return out

    def to_csv(self, path: str) -> int:
        """Standalone snapshot CSV (``metric,kind,value,mean,p50,p95,
        p99,max``) for ``results/make_table.py``. Returns rows written."""
        cols = ["metric", "kind", "value", "mean", "p50", "p95", "p99",
                "max"]
        rows = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            row = dict(metric=name, kind=inst.kind)
            if isinstance(inst, Histogram):
                s = inst.summary()
                row.update(value=s["n"], mean=s["mean"], p50=s["p50"],
                           p95=s["p95"], p99=s["p99"], max=s["max"])
            else:
                row["value"] = inst.value
            rows.append(row)
        with open(path, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=cols, restval="")
            w.writeheader()
            w.writerows(rows)
        return len(rows)


class _NullInstrument:
    """Shared inert counter/gauge/histogram."""

    __slots__ = ()
    value = 0
    kind = "null"

    def inc(self, v: int = 1) -> None:
        return None

    def set(self, v: float) -> None:
        return None

    def track_max(self, v: float) -> float:
        return 0.0

    def observe(self, v: float) -> None:
        return None

    def summary(self) -> Dict[str, float]:
        return dict(n=0, mean=0.0, p50=0.0, p95=0.0, p99=0.0, max=0.0)


class NullRegistry:
    """Inert registry: the default for facades constructed outside a
    service, so instrumentation sites never need a None-check."""

    _INST = _NullInstrument()

    def __len__(self) -> int:
        return 0

    def counter(self, name: str) -> _NullInstrument:
        return self._INST

    def gauge(self, name: str) -> _NullInstrument:
        return self._INST

    def histogram(self, name: str) -> _NullInstrument:
        return self._INST

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def to_csv(self, path: str) -> int:
        with open(path, "w", newline="") as fh:
            fh.write("metric,kind,value,mean,p50,p95,p99,max\n")
        return 0


NULL_METRICS = NullRegistry()

# Ambient registry for call sites with no service handle (kernel
# dispatch). The latest-constructed KGService owns it; None before any
# service exists.
_AMBIENT: Optional[MetricsRegistry] = None


def set_ambient(registry: Optional[MetricsRegistry]) -> None:
    global _AMBIENT
    _AMBIENT = registry


def ambient() -> Optional[MetricsRegistry]:
    return _AMBIENT
