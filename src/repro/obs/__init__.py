"""repro.obs — unified tracing + metrics for the serving, adaptation,
and kernel stack.

* :class:`Tracer` / :class:`Span`: structured spans on the modeled
  virtual clock (per-query plan→scan→join→federate→ship, window,
  migration-chunk, replica-promotion, write-batch, adaptation-round),
  exported as Chrome trace-event JSON (Perfetto-loadable) or JSONL.
  Byte-identical across runs for a fixed seed/executor.
* :class:`MetricsRegistry`: central counters/gauges/histograms threaded
  through the facade, executors, stream, migrate, replicate, write, and
  kernel dispatch; snapshot folded into ``KGService.stats()``.
* ``NULL_TRACER`` / ``NULL_METRICS``: inert defaults — observability is
  off unless asked for, at the cost of one attribute check per site.
"""
from repro.obs.metrics import (NULL_METRICS, Counter, Gauge, Histogram,
                               MetricsRegistry, NullRegistry, ambient,
                               set_ambient)
from repro.obs.tracer import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullRegistry",
    "NULL_METRICS", "ambient", "set_ambient",
    "Span", "Tracer", "NullTracer", "NULL_TRACER",
]
