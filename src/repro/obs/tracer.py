"""Structured span tracer on the modeled (virtual) clock.

Every timestamp comes from the deterministic cost model — the
``NetworkModel`` arithmetic that prices scans, joins, federation
round-trips, shipped bytes, migration chunks, and write fan-out — never
from the wall clock. Two runs with the same seed and executor therefore
produce *byte-identical* trace files, which makes traces first-class,
testable artifacts rather than best-effort diagnostics.

Layout: the tracer keeps a virtual-clock cursor ``now``. A span opens at
the cursor, and closing it moves the cursor to ``max(now, ts + dur)`` —
so sibling spans lay out sequentially and a parent's extent covers its
children (a parent opened with ``dur=0`` ends exactly where its last
child ended). ``advance_to`` lets the stream loop sync the cursor to its
own admission clock between windows.

Export targets:

* Chrome trace-event JSON (``{"traceEvents": [...]}``, "X" complete
  events) — loads directly in Perfetto / ``chrome://tracing``.
* JSONL — one event per line, for grep/jq pipelines.

The no-op path: ``NULL_TRACER`` shares one inert span, ``enabled`` is
False, and every method returns immediately — hot call sites guard span
construction with ``if tracer.enabled`` so tracing off-by-default costs
a single attribute check per site.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]


def _clean(value):
    """JSON-safe span attribute: numpy scalars to native, containers
    element-wise, everything else passed through for json to reject."""
    if isinstance(value, (bool, int, float, str)) or value is None:
        return value
    if isinstance(value, (list, tuple)):
        return [_clean(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _clean(v) for k, v in value.items()}
    if hasattr(value, "item"):  # numpy scalar
        return value.item()
    return str(value)


class Span:
    """One timed region on the modeled clock. Context manager; closing
    records the event and advances the tracer's cursor past it."""

    __slots__ = ("tracer", "name", "cat", "ts", "dur", "attrs", "seq",
                 "depth")

    def __init__(self, tracer, name, cat, ts, dur, attrs, seq, depth):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.ts = ts
        self.dur = dur
        self.attrs = attrs
        self.seq = seq
        self.depth = depth

    def annotate(self, **attrs) -> "Span":
        """Attach attributes discovered after the span opened (accept
        decisions, realized counts)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.tracer._close(self)


class Tracer:
    """Span recorder on a virtual clock, starting at ``clock0`` seconds."""

    enabled = True

    def __init__(self, clock0: float = 0.0):
        self.events: List[Dict[str, Any]] = []
        self.now = float(clock0)
        self._stack: List[Span] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self.events)

    # -- recording -----------------------------------------------------
    def span(self, name: str, cat: str = "serve", dur: float = 0.0,
             **attrs) -> Span:
        """Open a span at the cursor. ``dur`` is the modeled duration in
        seconds; children opened before the span closes extend it."""
        sp = Span(self, name, cat, self.now, float(dur),
                  {k: _clean(v) for k, v in attrs.items()},
                  self._seq, len(self._stack))
        self._seq += 1
        self._stack.append(sp)
        return sp

    def instant(self, name: str, cat: str = "mark", **attrs) -> None:
        """Zero-duration event at the cursor (drift triggers, rejects)."""
        with self.span(name, cat=cat, dur=0.0, **attrs):
            pass

    def _close(self, sp: Span) -> None:
        end = max(self.now, sp.ts + sp.dur)
        self.events.append(dict(seq=sp.seq, name=sp.name, cat=sp.cat,
                                ts=sp.ts, dur=end - sp.ts, depth=sp.depth,
                                args=sp.attrs))
        self.now = end
        if self._stack and self._stack[-1] is sp:
            self._stack.pop()

    # -- clock ---------------------------------------------------------
    def advance_to(self, t: float) -> None:
        """Monotone sync: move the cursor forward to the caller's clock
        (never backward — earlier spans already occupy that range)."""
        if t > self.now:
            self.now = float(t)

    # -- introspection (tests, smoke checks) ---------------------------
    def structure(self) -> List[Tuple[int, str]]:
        """(depth, name) pairs in span *open* order — the executor- and
        timing-independent shape of the trace."""
        return [(e["depth"], e["name"])
                for e in sorted(self.events, key=lambda e: e["seq"])]

    def span_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for e in self.events:
            counts[e["name"]] = counts.get(e["name"], 0) + 1
        return counts

    def find(self, name: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["name"] == name]

    # -- export --------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event dict: "X" complete events, microsecond
        timestamps, single pid/tid (the modeled system is one timeline)."""
        evs: List[Dict[str, Any]] = [
            dict(name="process_name", ph="M", pid=0, tid=0,
                 args=dict(name="repro.kg (modeled clock)")),
            dict(name="thread_name", ph="M", pid=0, tid=0,
                 args=dict(name="virtual")),
        ]
        for e in sorted(self.events, key=lambda e: e["seq"]):
            evs.append(dict(name=e["name"], cat=e["cat"], ph="X",
                            ts=round(e["ts"] * 1e6, 3),
                            dur=round(e["dur"] * 1e6, 3),
                            pid=0, tid=0, args=e["args"]))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        """Canonical serialization — sorted keys, no whitespace — so a
        fixed seed/executor yields a byte-identical file."""
        return json.dumps(self.chrome_trace(), sort_keys=True,
                          separators=(",", ":"))

    def to_jsonl(self) -> str:
        lines = [json.dumps(e, sort_keys=True, separators=(",", ":"))
                 for e in sorted(self.events, key=lambda e: e["seq"])]
        return "\n".join(lines) + ("\n" if lines else "")

    def export(self, path: str) -> int:
        """Write the trace to ``path`` (`.jsonl` → JSONL, else Chrome
        trace JSON). Returns the number of span events written."""
        text = self.to_jsonl() if path.endswith(".jsonl") else self.to_json()
        with open(path, "w") as fh:
            fh.write(text)
        return len(self.events)


class _NullSpan:
    """Shared inert span: context manager + annotate, records nothing."""

    __slots__ = ()

    def annotate(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None


class NullTracer:
    """Off-by-default tracer: every method is a no-op returning the one
    shared inert span. ``enabled`` is False so hot sites can skip even
    building attribute dicts."""

    enabled = False
    _SPAN = _NullSpan()

    events: List[Dict[str, Any]] = []
    now = 0.0

    def __len__(self) -> int:
        return 0

    def span(self, name, cat="serve", dur=0.0, **attrs):
        return self._SPAN

    def instant(self, name, cat="mark", **attrs):
        return None

    def advance_to(self, t):
        return None

    def structure(self):
        return []

    def span_counts(self):
        return {}

    def find(self, name):
        return []


NULL_TRACER = NullTracer()
