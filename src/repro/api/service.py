"""KGService — the master-node session API (paper Fig. 6).

One object owns the whole serving loop: bootstrap a partition with any
``Partitioner`` strategy, execute federated queries, monitor per-query
runtimes (TM), and — for adaptive strategies — trigger/apply the Fig.-5
adaptation. Drivers, examples, benchmarks, and tests orchestrate through
this facade only; controller internals are never reached into.

    svc = KGService.from_dataset(ds, n_shards=8)
    kg = svc.bootstrap(ds.base_workload())
    bindings, stats = svc.query(ds.queries["Q9"])
    report = svc.maybe_adapt(new_queries)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import AdaptConfig, AdaptReport, AWAPartController
from repro.core.features import FeatureSpace
from repro.graph.triples import TripleStore
from repro.query import engine
from repro.query.pattern import Query

from repro.api.facade import PartitionedKG
from repro.api.partitioners import AWAPartitioner, Partitioner


class KGService:
    """Session facade over store + feature space + partitioner + shard views."""

    def __init__(self, store: TripleStore, n_shards: int,
                 partitioner: Partitioner | None = None, *,
                 type_predicate: int | None = None,
                 config: AdaptConfig | None = None,
                 net: engine.NetworkModel | None = None):
        self.store = store
        self.n_shards = n_shards
        self.partitioner = partitioner or AWAPartitioner(config)
        self.space = FeatureSpace(store, type_predicate=type_predicate)
        self.net = net
        self.kg: Optional[PartitionedKG] = None
        self._times: Dict[str, List[float]] = {}   # TM for non-adaptive runs

    @classmethod
    def from_dataset(cls, ds, n_shards: int,
                     partitioner: Partitioner | None = None,
                     **kwargs) -> "KGService":
        """Build from a dataset exposing ``.store`` and ``.dictionary``
        (e.g. ``repro.graph.lubm.load``)."""
        return cls(ds.store, n_shards, partitioner,
                   type_predicate=ds.dictionary.lookup("rdf:type"), **kwargs)

    # ------------------------------------------------------------------ #
    @property
    def controller(self) -> Optional[AWAPartController]:
        """The adaptive control plane, if the strategy has one."""
        return getattr(self.partitioner, "controller", None)

    def bootstrap(self, workload: Sequence[Query] = ()) -> PartitionedKG:
        """Partition with the configured strategy and materialize the shard
        views (once — all later layout changes are incremental deltas)."""
        state = self.partitioner.partition(self.space, self.n_shards,
                                           list(workload))
        self.kg = PartitionedKG(self.store, self.space, state)
        return self.kg

    # ------------------------------------------------------------------ #
    # serving + monitoring (TM)
    # ------------------------------------------------------------------ #
    def query(self, q: Query) -> Tuple[Dict[int, np.ndarray],
                                       engine.ExecStats]:
        """Execute one federated query and record its runtime."""
        assert self.kg is not None, "bootstrap() first"
        bindings, stats = engine.execute(q, self.kg, self.net)
        self.observe(q, stats.modeled_time(self.net))
        return bindings, stats

    def run_workload(self, queries: Sequence[Query]):
        assert self.kg is not None, "bootstrap() first"
        return engine.run_workload(queries, self.kg, self.net)

    def workload_average_time(self, queries: Sequence[Query]) -> float:
        assert self.kg is not None, "bootstrap() first"
        return engine.workload_average_time(queries, self.kg, self.net)

    def observe(self, query: Query, runtime: float) -> None:
        ctrl = self.controller
        if ctrl is not None:
            ctrl.observe(query, runtime)
        else:
            self._times.setdefault(query.name, []).append(runtime)

    def avg_execution_time(self) -> float:
        ctrl = self.controller
        if ctrl is not None:
            return ctrl.avg_execution_time()
        per_q = [float(np.mean(v)) for v in self._times.values() if v]
        return float(np.mean(per_q)) if per_q else 0.0

    # ------------------------------------------------------------------ #
    # adaptation
    # ------------------------------------------------------------------ #
    def should_adapt(self) -> bool:
        ctrl = self.controller
        return ctrl is not None and ctrl.should_adapt()

    def adapt(self, new_queries: Sequence[Query] = ()) -> AdaptReport:
        """Run one adaptation round now (strategy must be adaptive). On
        acceptance the TM window restarts with the measured new baseline."""
        assert self.kg is not None, "bootstrap() first"
        if not hasattr(self.partitioner, "adapt"):
            raise TypeError(f"partitioner '{self.partitioner.name}' is not "
                            "adaptive; use AWAPartitioner")
        _, report = self.partitioner.adapt(self.kg, list(new_queries),
                                           net=self.net)
        ctrl = self.controller
        if report.accepted and ctrl is not None:
            ctrl.exec_times.clear()            # fresh TM window post-migration
            ctrl.reset_baseline(report.t_new)
        return report

    def maybe_adapt(self, new_queries: Sequence[Query] = (),
                    ) -> Optional[AdaptReport]:
        """Adapt only if the monitored average degraded past the threshold
        (or no baseline exists yet). Returns None when no round was run."""
        if not self.should_adapt():
            return None
        return self.adapt(new_queries)

    def reset_baseline(self, value: Optional[float] = None) -> None:
        """Public baseline control: clear (None) to force the next
        ``maybe_adapt`` to run a round, or pin to a measured average."""
        ctrl = self.controller
        if ctrl is not None:
            ctrl.reset_baseline(value)
