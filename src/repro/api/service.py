"""KGService — the master-node session API (paper Fig. 6).

One object owns the whole serving loop: bootstrap a partition with any
``Partitioner`` strategy, execute federated queries through a pluggable
``Executor`` backend, monitor per-query runtimes (TM), and — for adaptive
strategies — trigger/apply the Fig.-5 adaptation. Drivers, examples,
benchmarks, and tests orchestrate through this facade only; controller
internals are never reached into.

    svc = KGService.from_dataset(ds, n_shards=8, executor="jax",
                                 migration_budget=1 << 20)   # 1 MB per step
    kg = svc.bootstrap(ds.base_workload())
    bindings, stats = svc.query(ds.queries["Q9"])
    results = svc.query_batch(window)        # one dispatched batch per window
    svc.insert(new_triples)                  # live writes, served next epoch
    svc.delete(old_triples)                  # (safe mid-drain, fanned out
    report = svc.maybe_adapt(new_queries)    #  to replica holders)
    svc.step()                               # apply one migration chunk
    svc.drain()                              # or finish the whole drain

Every query is planned once per ``(query, store)`` (the ``PartitionedKG``
plan cache) and executed by the configured backend: ``executor="numpy"``
(default, reference semantics) or ``"jax"`` (batched; a whole TM window
executes in one dispatched batch). An ``Executor`` instance plugs in too.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import write as kgwrite
from repro.core.adaptive import AdaptConfig, AdaptReport, AWAPartController
from repro.core.features import FeatureSpace
from repro.core.migration import TRIPLE_BYTES, MigrationChunk
from repro.graph.triples import TripleStore
from repro.migrate import MigrationSession
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer, set_ambient
from repro.query import exec as qexec
from repro.query.pattern import Query

from repro.api.facade import PartitionedKG
from repro.api.partitioners import AWAPartitioner, Partitioner


class KGService:
    """Session facade over store + feature space + partitioner + shard views.

    ``migration_budget`` (bytes) throttles how an accepted adaptation is
    applied: ``None`` (default) drains the whole ``MigrationPlan`` inside
    ``adapt()`` — the old atomic commit — while a byte budget turns the
    round into a pending :class:`MigrationSession` whose chunks are applied
    one per ``query_batch`` window (or explicitly via ``step()``/``drain()``),
    so adaptation becomes a background process with bounded per-window cost
    instead of a latency cliff.

    ``replica_budget`` (bytes) enables workload-aware read replication
    (``repro.replicate``): each adaptation round promotes the hottest
    features onto the shards that read them remotely — up to this many
    bytes of extra copies — and demotes replicas that fell cold. Copy
    traffic drains through the same migration sessions as moves."""

    def __init__(self, store: TripleStore, n_shards: int,
                 partitioner: Partitioner | None = None, *,
                 type_predicate: int | None = None,
                 config: AdaptConfig | None = None,
                 executor: "str | qexec.Executor | None" = None,
                 net: qexec.NetworkModel | None = None,
                 migration_budget: int | None = None,
                 replica_budget: int | None = None,
                 trace: "bool | Tracer" = False):
        self.store = store
        self.n_shards = n_shards
        self.partitioner = partitioner or AWAPartitioner(config)
        self.space = FeatureSpace(store, type_predicate=type_predicate)
        self.executor = qexec.get_executor(executor)
        self.net = net
        self.migration_budget = migration_budget
        self.replica_budget = replica_budget
        if replica_budget is not None:
            # thread the knob into the adaptive strategy's config — on a
            # copy, never mutating a caller-owned AdaptConfig in place
            if not hasattr(self.partitioner, "adapt"):
                warnings.warn(
                    f"replica_budget has no effect: partitioner "
                    f"'{self.partitioner.name}' never runs an adaptation "
                    "round (replicas are promoted per round)", stacklevel=2)
            else:
                cfg = self.partitioner.config or AdaptConfig()
                self.partitioner.config = dataclasses.replace(
                    cfg, replica_budget=int(replica_budget))
        self.kg: Optional[PartitionedKG] = None
        self.session: Optional[MigrationSession] = None   # in-flight drain
        self._times: Dict[str, List[float]] = {}   # TM for non-adaptive runs
        self.write_log = kgwrite.WriteLog()        # applied-mutation history
        self._stream_recorder = None   # LatencyRecorder of the live stream
        # observability (repro.obs): one registry per service, always on
        # (counters are cheap); span tracing only when asked for. The
        # registry doubles as the ambient sink for kernel-dispatch tier
        # counters, which have no service handle.
        self.metrics = MetricsRegistry()
        set_ambient(self.metrics)
        if trace is True:
            self._tracer = Tracer()
        elif trace:
            self._tracer = trace            # caller-owned Tracer instance
        else:
            self._tracer = NULL_TRACER

    @classmethod
    def from_dataset(cls, ds, n_shards: int,
                     partitioner: Partitioner | None = None,
                     **kwargs) -> "KGService":
        """Build from a dataset exposing ``.store`` and ``.dictionary``
        (e.g. ``repro.graph.lubm.load``)."""
        return cls(ds.store, n_shards, partitioner,
                   type_predicate=ds.dictionary.lookup("rdf:type"), **kwargs)

    # ------------------------------------------------------------------ #
    @property
    def controller(self) -> Optional[AWAPartController]:
        """The adaptive control plane, if the strategy has one."""
        return getattr(self.partitioner, "controller", None)

    def bootstrap(self, workload: Sequence[Query] = ()) -> PartitionedKG:
        """Partition with the configured strategy and materialize the shard
        views (once — all later layout changes are incremental deltas)."""
        state = self.partitioner.partition(self.space, self.n_shards,
                                           list(workload))
        self.kg = PartitionedKG(
            self.store, self.space, state,
            max_join_rows=getattr(self.executor, "max_join_rows",
                                  qexec.DEFAULT_MAX_JOIN_ROWS),
            metrics=self.metrics)
        self.metrics.gauge("join.expand_cap").set(self.kg.max_join_rows)
        return self.kg

    # ------------------------------------------------------------------ #
    # serving + monitoring (TM)
    # ------------------------------------------------------------------ #
    def query(self, q: Query) -> Tuple[Dict[int, np.ndarray],
                                       qexec.ExecStats]:
        """Execute one federated query and record its runtime. A repeat of
        the same query at the same layout epoch is served from the facade's
        result cache without re-execution."""
        assert self.kg is not None, "bootstrap() first"
        hit = self.kg.cached_result(q)
        cached = hit is not None
        built0 = self.kg.plan_builds
        if hit is None:
            hit = self.executor.run(self.kg.plan(q), self.kg)
            self.kg.store_result(q, *hit)
        bindings, stats = hit
        self.observe(q, stats.modeled_time(self.net))
        self._note_query(q, stats, cached,
                         plan_built=self.kg.plan_builds > built0)
        return bindings, stats

    def query_batch(self, queries: Sequence[Query],
                    ) -> List[Tuple[Dict[int, np.ndarray], qexec.ExecStats]]:
        """Execute a whole window of queries as one backend batch (a single
        dispatched batch on the jax executor) and record every runtime.
        Queries already executed at the current layout epoch are served from
        the result cache; only the misses reach the backend.

        When a throttled migration is in flight, one chunk is applied ahead
        of the window — the window pays a bounded migration stall (at most
        ``migration_budget`` bytes of traffic) and then serves the updated
        hybrid layout, so the hottest features arrive earliest."""
        self.step()
        return self.serve_window(queries)[0]

    def serve_window(self, queries: Sequence[Query],
                     ) -> Tuple[List[Tuple[Dict[int, np.ndarray],
                                           qexec.ExecStats]], List[int]]:
        """The execution half of :meth:`query_batch`: serve one window at
        the *current* layout — cache check, one ``run_batch`` over the
        misses, TM observation — with no migration step. This is the seam
        the streaming loop (``repro.stream``) pumps windows through after
        interleaving its own writes/chunks; returns ``(results, miss)``
        where ``miss`` indexes the queries that actually reached the
        backend (the rest were epoch-valid result-cache hits)."""
        assert self.kg is not None, "bootstrap() first"
        results = [self.kg.cached_result(q) for q in queries]
        miss = [i for i, r in enumerate(results) if r is None]
        built = set()
        if miss:
            plans = []
            for i in miss:
                builds0 = self.kg.plan_builds
                plans.append(self.kg.plan(queries[i]))
                if self.kg.plan_builds > builds0:
                    built.add(i)
            for i, res in zip(miss, self.executor.run_batch(plans, self.kg)):
                results[i] = res
                self.kg.store_result(queries[i], *res)
        for q, (_, stats) in zip(queries, results):
            self.observe(q, stats.modeled_time(self.net))
        missed = set(miss)
        tr = self._tracer
        if tr.enabled:
            with tr.span("window", cat="serve", n=len(queries),
                         misses=len(miss), epoch=self.kg.epoch):
                for i, (q, (_, stats)) in enumerate(zip(queries, results)):
                    self._note_query(q, stats, cached=i not in missed,
                                     plan_built=i in built)
        else:
            for i, (q, (_, stats)) in enumerate(zip(queries, results)):
                self._note_query(q, stats, cached=i not in missed,
                                 plan_built=i in built)
        return results, miss

    def _note_query(self, q: Query, stats: qexec.ExecStats, cached: bool,
                    plan_built: bool) -> None:
        """Per-query observability: registry counters always; when tracing,
        one ``query`` span decomposed into plan→scan→join→federate→ship
        children whose durations are exactly the ``NetworkModel`` terms of
        ``stats.modeled_time`` — so the spans are emitted from plan+stats
        at the service layer and their *structure* is identical across
        executor backends (ExecStats.COMPARABLE is pinned by tests)."""
        net = self.net or qexec.NetworkModel()
        m = self.metrics
        m.counter("queries.served").inc()
        if cached:
            m.counter("queries.result_cache_hits").inc()
        else:
            m.counter("federation.messages").inc(stats.messages)
            m.counter("federation.rows_shipped").inc(stats.rows_shipped)
            m.counter("federation.bytes_shipped").inc(stats.bytes_shipped)
            m.counter("join.cross_shard").inc(stats.distributed_joins)
            m.counter("join.rows").inc(stats.join_rows)
            m.counter("join.expanded_rows").inc(stats.expanded_rows)
            peak = m.gauge("join.expanded_rows_peak").track_max(
                stats.expanded_rows)
            m.gauge("join.expand_cap_headroom").set(
                self.kg.max_join_rows - peak)
            m.histogram("query.modeled_s").observe(stats.modeled_time(net))
        tr = self._tracer
        if not tr.enabled:
            return
        with tr.span("query", cat="serve", query=q.name, cached=cached,
                     epoch=self.kg.epoch, rows=stats.rows):
            with tr.span("plan", cat="serve",
                         dur=net.plan_s if plan_built else 0.0,
                         built=plan_built):
                pass
            with tr.span("scan", cat="serve",
                         dur=stats.scan_rows_critical / net.scan_rows_per_s,
                         rows=stats.scan_rows_critical):
                pass
            with tr.span("join", cat="serve",
                         dur=stats.join_rows / net.join_rows_per_s,
                         rows=stats.join_rows,
                         cross_shard=stats.distributed_joins,
                         expanded_rows=stats.expanded_rows):
                pass
            with tr.span("federate", cat="serve",
                         dur=stats.messages * net.latency_s,
                         messages=stats.messages):
                pass
            with tr.span("ship", cat="serve",
                         dur=stats.rows_shipped * net.row_bytes
                             / net.bandwidth_Bps,
                         rows=stats.rows_shipped,
                         bytes=stats.bytes_shipped):
                pass

    # ------------------------------------------------------------------ #
    # live writes (repro.write)
    # ------------------------------------------------------------------ #
    def insert(self, triples) -> kgwrite.WriteReport:
        """Insert dictionary-encoded ``(s, p, o)`` triples into the live
        graph. Safe while serving, while replicated, and while a migration
        drain is in flight: rows are routed by the current primary
        assignment, fanned out to every replica holder, and served from the
        next epoch on (any cached plan/result of the old graph
        invalidates). Already-present triples are no-ops."""
        return self.write(kgwrite.WriteBatch(inserts=triples))

    def delete(self, triples) -> kgwrite.WriteReport:
        """Delete dictionary-encoded ``(s, p, o)`` triples from the live
        graph — the write path's mirror image of :meth:`insert` (absent
        triples are no-ops)."""
        return self.write(kgwrite.WriteBatch(deletes=triples))

    def fresh_ids(self, n: int = 1) -> np.ndarray:
        """Mint ``n`` entity ids unused by any triple in the live graph —
        subjects for new rows (``repro.write.fresh_entity_ids``; bulk
        entity ids live past the dictionary, so ``Dictionary.encode`` on a
        new term may collide with an existing entity)."""
        assert self.kg is not None, "bootstrap() first"
        return kgwrite.fresh_entity_ids(self.kg.store, n)

    def write(self, batch: kgwrite.WriteBatch) -> kgwrite.WriteReport:
        """Apply one :class:`repro.write.WriteBatch` (deletes first,
        inserts win) and log it. The report is folded into the adaptive
        controller's TM window (``note_writes``): write-born features join
        the tracked universe and per-feature write heat accumulates — the
        data-drift signal the next adaptation round's fanout pricing and
        replica proposal consume."""
        assert self.kg is not None, "bootstrap() first"
        report = self.kg.apply_write(batch)
        self.write_log.append(batch, report)
        ctrl = self.controller
        if ctrl is not None and report.effective:
            ctrl.note_writes(report)
        tr = self._tracer
        if tr.enabled:
            net = self.net or qexec.NetworkModel()
            traffic = (report.n_inserted + report.n_deleted) * TRIPLE_BYTES \
                + report.fanout_bytes
            with tr.span("write.batch", cat="write",
                         dur=traffic / net.bandwidth_Bps,
                         inserted=report.n_inserted,
                         deleted=report.n_deleted,
                         redundant=report.n_redundant,
                         touched_shards=len(report.touched_shards),
                         fanout_bytes=report.fanout_bytes,
                         epoch=report.epoch):
                pass
        return report

    # ------------------------------------------------------------------ #
    # streaming admission (repro.stream)
    # ------------------------------------------------------------------ #
    def stream(self, **kwargs) -> "object":
        """Open a continuous-admission serving loop over this service — a
        :class:`repro.stream.StreamService`. Queries and write batches are
        ``submit``-ted as they arrive, served in pipelined windows through
        the same :meth:`serve_window` seam (results stay byte-identical to
        a synchronous ``query_batch`` over the same admission order), and
        per-query admission→completion latency lands in the stream's
        :class:`repro.stream.LatencyRecorder` (surfaced via
        :meth:`stats`). Keyword arguments forward to ``StreamService``
        (``pipeline=``, ``max_window=``, ``hit_cost_s=``)."""
        from repro.stream import StreamService
        return StreamService(self, **kwargs)

    def tracer(self) -> Tracer:
        """The service's span tracer (``repro.obs.Tracer``) — inspect
        ``tracer().events`` or ``tracer().export(path)`` after a run."""
        if not self._tracer.enabled:
            raise RuntimeError(
                "tracing is disabled for this service: construct it with "
                "KGService(..., trace=True) (or pass a repro.obs.Tracer "
                "instance) to record spans")
        return self._tracer

    def stats(self) -> Dict[str, object]:
        """One dict of everything observable about the serving session:
        the facade's layout/cache telemetry, write-log and migration-drain
        progress, the metrics-registry snapshot, and the latency aggregates
        (overall / per-window / per-shard p50/p95/p99 — a well-formed
        all-zero block when no stream has recorded anything yet)."""
        if self.kg is None:
            raise RuntimeError(
                "KGService.stats() before bootstrap(): call "
                "svc.bootstrap(workload) to partition the graph and "
                "materialize the shard views first")
        out = self.kg.telemetry()
        out.update(
            executor=self.executor.name,
            partitioner=self.partitioner.name,
            writes_applied=len(self.write_log.entries),
            rows_inserted=self.write_log.n_inserted,
            rows_deleted=self.write_log.n_deleted,
            migration_in_flight=self.session is not None,
            migration_progress=(self.session.progress()
                                if self.session is not None else 1.0),
        )
        from repro.stream.telemetry import LatencyRecorder
        rec = self._stream_recorder
        if rec is not None and len(rec):
            out["latency"] = rec.summary()
            out["latency_per_shard"] = rec.per_shard()
        else:
            out["latency"] = LatencyRecorder.empty_summary()
            out["latency_per_shard"] = {}
        out["metrics"] = self.metrics.snapshot()
        return out

    def run_workload(self, queries: Sequence[Query],
                     ) -> Tuple[Dict[str, float], Dict[str, qexec.ExecStats]]:
        """Batched measurement sweep (no TM recording): per-query modeled
        times and stats, keyed by query name."""
        assert self.kg is not None, "bootstrap() first"
        return qexec.run_workload(queries, self.kg, self.executor, self.net)

    def workload_average_time(self, queries: Sequence[Query]) -> float:
        assert self.kg is not None, "bootstrap() first"
        return qexec.workload_average_time(queries, self.kg, self.executor,
                                           self.net)

    def observe(self, query: Query, runtime: float) -> None:
        ctrl = self.controller
        if ctrl is not None:
            ctrl.observe(query, runtime)
        else:
            self._times.setdefault(query.name, []).append(runtime)

    def avg_execution_time(self) -> float:
        ctrl = self.controller
        if ctrl is not None:
            return ctrl.avg_execution_time()
        per_q = [float(np.mean(v)) for v in self._times.values() if v]
        return float(np.mean(per_q)) if per_q else 0.0

    # ------------------------------------------------------------------ #
    # adaptation
    # ------------------------------------------------------------------ #
    def should_adapt(self) -> bool:
        """Adaptation trigger — False while a migration drain is in flight:
        the TM is observing transient hybrid-layout times, and a fresh round
        would finish the drain atomically, re-introducing the stop-the-world
        stall the ``migration_budget`` knob exists to prevent."""
        if self.session is not None:
            return False
        ctrl = self.controller
        return ctrl is not None and ctrl.should_adapt()

    def adapt(self, new_queries: Sequence[Query] = (), *,
              _trigger: str = "explicit") -> AdaptReport:
        """Run one adaptation round now (strategy must be adaptive). On
        acceptance the TM window restarts with the measured new baseline.

        Any still-draining previous migration is finished first. With
        ``migration_budget=None`` the accepted plan is drained atomically
        before returning (the classic stop-the-world commit); with a budget
        it is left pending as ``self.session`` and applied chunk-by-chunk by
        subsequent ``query_batch`` windows / ``step()`` calls."""
        assert self.kg is not None, "bootstrap() first"
        if not hasattr(self.partitioner, "adapt"):
            raise TypeError(f"partitioner '{self.partitioner.name}' is not "
                            "adaptive; use AWAPartitioner")
        m = self.metrics
        m.counter("adapt.rounds").inc()
        # adapt is a cold path: span bookkeeping runs unconditionally (the
        # null tracer's span is a shared no-op), so the atomic drain's chunk
        # spans nest inside the round span without duplicated control flow
        with self._tracer.span("adapt.round", cat="adapt",
                               trigger=_trigger) as sp:
            self.drain()                       # finish any in-flight drain
            session, report = self.partitioner.adapt(
                self.kg, list(new_queries), net=self.net,
                bytes_budget=self.migration_budget)
            ctrl = self.controller
            if report.accepted and ctrl is not None:
                ctrl.clear_window()            # fresh TM window post-migration
                ctrl.reset_baseline(report.t_new)
            sp.annotate(accepted=report.accepted, reason=report.reason,
                        t_base=report.t_base, t_new=report.t_new,
                        migration_s=report.migration_s,
                        amortize_window=report.amortize_window,
                        fanout_bytes=report.fanout_bytes,
                        moves=report.plan.n_moves,
                        chosen_cut=report.chosen_cut,
                        n_clusters=report.n_clusters)
            m.counter("adapt.accepted" if report.accepted
                      else "adapt.rejected").inc()
            if report.accepted:
                m.gauge("replicate.copy_bytes_held").set(report.replica_bytes)
            if report.accepted and report.plan.n_replica_ops:
                m.counter("replicate.planned_adds").inc(
                    len(report.plan.replica_adds))
                m.counter("replicate.planned_drops").inc(
                    len(report.plan.replica_drops))
                with self._tracer.span(
                        "replica.promotion", cat="replicate",
                        adds=len(report.plan.replica_adds),
                        drops=len(report.plan.replica_drops),
                        replica_bytes=report.replica_bytes):
                    pass
            if self.migration_budget is None:
                session.drain()                # atomic: commit-now behaviour
        self.session = None if session.done else session
        return report

    def step(self) -> Optional[MigrationChunk]:
        """Apply one chunk of the pending migration session (if any).
        Returns the applied ``MigrationChunk`` or ``None`` when idle."""
        if self.session is None:
            return None
        sess = self.session
        chunk = sess.step()
        if chunk is not None and self._tracer.enabled:
            net = self.net or qexec.NetworkModel()
            with self._tracer.span(
                    "migration.chunk", cat="migrate",
                    dur=chunk.bytes / net.bandwidth_Bps,
                    moves=len(chunk.moves), bytes=chunk.bytes,
                    replica_adds=len(chunk.replica_adds),
                    replica_drops=len(chunk.replica_drops),
                    progress=sess.progress(), epoch=self.kg.epoch):
                pass
        if self.session.done:
            self.session = None
            # the TM observed hybrid-layout times while draining; restart the
            # window so the pinned t_new baseline is compared against the
            # fully-migrated layout only (no spurious post-drain round)
            ctrl = self.controller
            if ctrl is not None:
                ctrl.clear_window()
            self._times.clear()
        return chunk

    def drain(self) -> int:
        """Finish the pending migration session; returns chunks applied."""
        n = 0
        while self.step() is not None:
            n += 1
        return n

    def maybe_adapt(self, new_queries: Sequence[Query] = (),
                    ) -> Optional[AdaptReport]:
        """Adapt only if the monitored average degraded past the threshold
        (or no baseline exists yet and at least one query was observed).
        Returns None when no round was run."""
        if not self.should_adapt():
            return None
        ctrl = self.controller
        if ctrl is not None and ctrl.write_drift():
            trigger = "write_drift"
        elif ctrl is not None and ctrl._baseline_avg is None:
            trigger = "no_baseline"
        else:
            trigger = "degradation"
        return self.adapt(new_queries, _trigger=trigger)

    def reset_baseline(self, value: Optional[float] = None) -> None:
        """Public baseline control: clear (None) to force the next
        ``maybe_adapt`` to run a round, or pin to a measured average. Resets
        the whole TM window — the non-adaptive ``_times`` log included, so
        ``avg_execution_time()`` restarts consistently across strategies."""
        ctrl = self.controller
        if ctrl is not None:
            ctrl.reset_baseline(value)
        self._times.clear()
