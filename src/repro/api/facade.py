"""PartitionedKG — the partitioned-knowledge-graph facade.

Owns the global ``TripleStore``, the ``FeatureSpace`` and the current
``PartitionState``, and materializes per-shard ``TripleStore`` views **once**.
Thereafter every layout change arrives as a ``MigrationPlan``-shaped delta
(a candidate ``PartitionState`` over the same feature universe) and only the
shards actually touched by moved features are re-indexed; untouched shard
views are reused as-is.

The facade is also the plan cache: ``kg.plan(q)`` builds the
``repro.query.plan.QueryPlan`` IR once per ``(query, store)`` and serves it
to every executor until the layout changes (``commit`` / ``sync_universe``
invalidate, because the PPN choice and federation annotations are
layout-dependent). Layout-invariant ``QueryProfile``s are derived from the
plan and cached separately — they survive commits, which is what makes
candidate evaluation (``measure_candidate``) pure bincount re-accounting
with no joins re-executed and no views touched.

The object is duck-compatible with ``repro.query.engine.ShardedStore``
(``.space`` / ``.state`` / ``.shards`` / ``.store`` / ``.triple_shard``), so
any ``Executor`` runs against it unchanged.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import migration
from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState
from repro.graph.triples import TripleStore
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.pattern import Query


class PartitionedKG:
    """Per-shard views of a feature-partitioned KG with incremental updates."""

    def __init__(self, store: TripleStore, space: FeatureSpace,
                 state: PartitionState, owners: np.ndarray | None = None,
                 max_join_rows: int = qexec.DEFAULT_MAX_JOIN_ROWS):
        self.store = store
        self.space = space
        self.state = state
        # profiling honors the serving executor's cartesian-join cap
        self.max_join_rows = max_join_rows
        self.owners = space.triple_owners() if owners is None else owners
        self._triple_shard = state.triple_shards(self.owners).astype(np.int32)
        self._rows: List[np.ndarray] = [
            np.flatnonzero(self._triple_shard == s)
            for s in range(state.n_shards)]
        self._views: List[Optional[TripleStore]] = [None] * state.n_shards
        self.view_rebuilds = 0         # telemetry: shard views (re)built
        # layout epoch: bumped whenever the served layout actually changes
        # (a delta that moves features, or universe growth). Cached plans are
        # valid for exactly one epoch; a mid-migration hybrid layout is a
        # first-class epoch like any other.
        self.epoch = 0
        # query plans, cached per (query, store) until the layout changes;
        # keyed by query name (+ patterns, so a re-defined query under the
        # same name is re-planned)
        self._plans: Dict[str, Tuple[tuple, qplan.QueryPlan]] = {}
        self.plan_builds = 0           # telemetry: plans built / cache hits
        self.plan_hits = 0
        # layout-invariant query profiles (derived from plans; survive
        # commits — join results don't depend on the layout)
        self._profiles: Dict[str, Tuple[tuple, qplan.QueryProfile]] = {}
        self._rebuild_feature_index()

    # ------------------------------------------------------------------ #
    # executor compatibility
    # ------------------------------------------------------------------ #
    @property
    def n_shards(self) -> int:
        return self.state.n_shards

    @property
    def triple_shard(self) -> np.ndarray:
        """Current shard of every global triple row, (N,) int32."""
        return self._triple_shard

    @property
    def shards(self) -> List[TripleStore]:
        """Materialized per-shard views (lazily built, cached until a delta
        touches the shard)."""
        for s in range(self.state.n_shards):
            if self._views[s] is None:
                self._views[s] = TripleStore(
                    self.store.triples[self._rows[s]], self.store.dictionary)
                self.view_rebuilds += 1
        return list(self._views)

    def shard_sizes(self) -> List[int]:
        return [len(r) for r in self._rows]

    # ------------------------------------------------------------------ #
    # owner-feature row index (CSR over triples grouped by owner feature)
    # ------------------------------------------------------------------ #
    def _rebuild_feature_index(self) -> None:
        order = np.argsort(self.owners, kind="stable").astype(np.int64)
        nf = len(self.state.feature_to_shard)
        self._feat_order = order
        self._feat_starts = np.searchsorted(
            self.owners[order], np.arange(nf + 1))

    def _rows_of(self, feats: np.ndarray) -> np.ndarray:
        parts = [self._feat_order[self._feat_starts[f]:self._feat_starts[f + 1]]
                 for f in feats.tolist()]
        return (np.concatenate(parts) if parts
                else np.empty(0, dtype=np.int64))

    # ------------------------------------------------------------------ #
    # feature-universe growth (adaptive PO-split tracking)
    # ------------------------------------------------------------------ #
    def sync_universe(self) -> None:
        """Absorb newly-tracked PO features from the FeatureSpace.

        A split PO feature's triples stay on the parent's shard (ownership
        split, no data movement), so the triple->shard mapping — and every
        shard view — is unchanged; only owners/sizes/state are re-derived.
        Cached plans are invalidated: feature sizes feed the PPN vote."""
        if self.space.n_features == len(self.state.feature_to_shard):
            return
        self.state, self.owners = migration.extend_for_space(self.state,
                                                             self.space)
        self.epoch += 1
        self._plans.clear()
        self._rebuild_feature_index()

    # ------------------------------------------------------------------ #
    # incremental deltas
    # ------------------------------------------------------------------ #
    def _apply(self, new_state: PartitionState) -> None:
        assert len(new_state.feature_to_shard) == \
            len(self.state.feature_to_shard), \
            "sync_universe() before applying a delta over a grown universe"
        changed = np.flatnonzero(
            self.state.feature_to_shard != new_state.feature_to_shard)
        if len(changed) == 0:              # no-op delta: the served layout is
            self.state = new_state         # unchanged — keep plans/views/epoch
            return
        rows = self._rows_of(changed)
        old_shards = self._triple_shard[rows]
        new_shards = new_state.feature_to_shard[self.owners[rows]] \
            .astype(np.int32)
        touched = (np.unique(np.concatenate([old_shards, new_shards])).tolist()
                   if len(rows) else [])
        self._triple_shard[rows] = new_shards
        for s in touched:
            self._rows[s] = np.flatnonzero(self._triple_shard == s)
            self._views[s] = None          # re-indexed lazily on next access
        self.state = new_state
        self.epoch += 1
        self._plans.clear()                # PPN/federation annotations changed

    def apply_chunk(self, chunk: migration.MigrationChunk) -> None:
        """Apply one ``MigrationChunk`` of an in-flight migration as an
        incremental delta. The resulting partially-migrated layout is served
        as-is (a new epoch): only shards touched by the chunk's moves are
        re-indexed, and cached plans are invalidated because the PPN vote and
        federation annotations may have shifted."""
        state = self.state.copy()
        for f, _src, dst in chunk.moves:
            state.feature_to_shard[f] = dst
        self._apply(state)

    # ------------------------------------------------------------------ #
    # plans, profiles, candidate pricing
    # ------------------------------------------------------------------ #
    def plan(self, q: Query) -> qplan.QueryPlan:
        """The query's execution plan under the current layout (cached per
        ``(query, store)``; invalidated by ``commit``/``sync_universe``)."""
        pats = tuple(q.patterns)
        entry = self._plans.get(q.name)
        if entry is None or entry[0] != pats:
            entry = (pats, qplan.plan(q, self))
            self._plans[q.name] = entry
            self.plan_builds += 1
        else:
            self.plan_hits += 1
        return entry[1]

    def profile(self, q: Query) -> qplan.QueryProfile:
        """Layout-invariant execution profile of ``q``, derived from its plan
        (cached; one real execution against the global store on first use)."""
        pats = tuple(q.patterns)
        entry = self._profiles.get(q.name)
        if entry is None or entry[0] != pats:
            entry = (pats, qexec.profile_from_plan(self.plan(q), self.store,
                                                   self.max_join_rows))
            self._profiles[q.name] = entry
        return entry[1]

    def measure_candidate(self, cand: PartitionState,
                          queries: Sequence[Query], net=None) -> float:
        """Average modeled workload time under ``cand`` — pure federation
        re-accounting over cached query profiles. No joins are re-executed,
        no shard view is touched: only the candidate's triple->shard map is
        derived (one gather) and each profiled pattern re-priced."""
        self.sync_universe()
        triple_shard = cand.feature_to_shard[self.owners].astype(np.int32)
        net = net or qexec.NetworkModel()
        num = den = 0.0
        for q in queries:
            st = qplan.stats_from_profile(q, self.profile(q), self.space,
                                          cand, triple_shard)
            num += st.modeled_time(net) * q.frequency
            den += q.frequency
        return num / max(den, 1e-12)

    def commit(self, new_state: PartitionState) -> migration.MigrationPlan:
        """Adopt ``new_state``; returns the migration delta that was applied.
        Only shards touched by moved features are re-indexed."""
        self.sync_universe()
        plan = migration.plan(self.state, new_state)
        self._apply(new_state)
        return plan

    # ------------------------------------------------------------------ #
    def imbalance(self) -> float:
        return self.state.imbalance()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PartitionedKG(n_triples={self.store.n_triples}, "
                f"n_shards={self.n_shards}, "
                f"n_features={len(self.state.feature_to_shard)}, "
                f"epoch={self.epoch})")
