"""PartitionedKG — the partitioned-knowledge-graph facade.

Owns the global ``TripleStore``, the ``FeatureSpace`` and the current
``PartitionState``, and materializes per-shard ``TripleStore`` views **once**.
Thereafter every layout change arrives as a ``MigrationPlan``-shaped delta
(a candidate ``PartitionState`` over the same feature universe) and only the
shards actually touched by moved features are re-indexed; untouched shard
views are reused as-is.

The facade is also the plan cache: ``kg.plan(q)`` builds the
``repro.query.plan.QueryPlan`` IR once per ``(query, store)`` and serves it
to every executor until the layout changes (``commit`` / ``sync_universe``
invalidate, because the PPN choice and federation annotations are
layout-dependent). Layout-invariant ``QueryProfile``s are derived from the
plan and cached separately — they survive commits, which is what makes
candidate evaluation (``measure_candidate``) pure bincount re-accounting
with no joins re-executed and no views touched.

Beside the primary assignment the facade carries a
``repro.replicate.ReplicaMap``: shard views additionally materialize any
read copies pinned onto them, ``read_shard(ppn)`` resolves every triple's
serving shard for a query (nearest replica: the PPN when a local copy
exists, else the primary), and replica promotions/demotions arrive through
the same ``MigrationChunk`` deltas as moves. An epoch-keyed result cache
(``cached_result``/``store_result``) sits beside the plan cache so repeated
``(query, epoch)`` pairs in hot TM windows skip re-execution entirely.

Live mutation arrives through ``apply_write`` (``repro.write``): writes are
routed by the primary assignment, fanned out to replica holders, re-index
only the touched shard views, and bump both the epoch and a separate
``data_version`` — the invalidation key for the profiles, which survive
layout changes but not graph changes. Every cache entry carries the
epoch/version it was built at and serving asserts the tag, so a mutating
path that forgets to invalidate fails loudly instead of serving stale
results.

The object is duck-compatible with ``repro.query.engine.ShardedStore``
(``.space`` / ``.state`` / ``.shards`` / ``.store`` / ``.triple_shard``), so
any ``Executor`` runs against it unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import migration
from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState
from repro.graph.triples import TripleStore
from repro.obs.metrics import NULL_METRICS
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.pattern import Query
from repro.replicate import ReplicaMap


class PartitionedKG:
    """Per-shard views of a feature-partitioned KG with incremental updates."""

    def __init__(self, store: TripleStore, space: FeatureSpace,
                 state: PartitionState, owners: np.ndarray | None = None,
                 max_join_rows: int = qexec.DEFAULT_MAX_JOIN_ROWS,
                 replicas: ReplicaMap | None = None,
                 metrics=None):
        self.store = store
        self.space = space
        self.state = state
        # repro.obs registry (the owning KGService's); inert by default so
        # facades built directly — tests, rebuild twins — need no checks
        self.metrics = metrics if metrics is not None else NULL_METRICS
        # profiling honors the serving executor's cartesian-join cap
        self.max_join_rows = max_join_rows
        self.owners = space.triple_owners() if owners is None else owners
        self._triple_shard = state.triple_shards(self.owners).astype(np.int32)
        self._rows: List[np.ndarray] = [
            np.flatnonzero(self._triple_shard == s)
            for s in range(state.n_shards)]
        self._views: List[Optional[TripleStore]] = [None] * state.n_shards
        self.view_rebuilds = 0         # telemetry: shard views (re)built
        # layout epoch: bumped whenever the served layout actually changes
        # (a delta that moves features or replicas, or universe growth).
        # Cached plans/results are valid for exactly one epoch; a
        # mid-migration hybrid layout is a first-class epoch like any other.
        self.epoch = 0
        # data version: bumped by every effective write (repro.write) — the
        # invalidation key for caches that survive layout epochs but NOT
        # graph mutations (the layout-invariant profiles below)
        self.data_version = 0
        # query plans, cached per (query, store) until the layout changes;
        # keyed by query name (+ patterns, so a re-defined query under the
        # same name is re-planned). Entries are tagged with the epoch they
        # were built at; serving asserts the tag — any mutating path that
        # forgot to bump the epoch before a cached entry is served trips an
        # assertion instead of returning stale federation annotations.
        self._plans: Dict[str, Tuple[tuple, qplan.QueryPlan, int]] = {}
        self.plan_builds = 0           # telemetry: plans built / cache hits
        self.plan_hits = 0
        # epoch-keyed result cache beside the plan cache: bindings+stats of
        # repeated (query, epoch) pairs in hot TM windows are served without
        # re-execution; invalidated together with the plans on epoch bumps
        # (entries carry their epoch under the same stale-serving assert)
        self._results: Dict[str, Tuple[tuple, dict, qexec.ExecStats,
                                       int]] = {}
        self.result_hits = 0
        # layout-invariant query profiles (derived from plans; survive
        # commits — join results don't depend on the layout, but they DO
        # depend on the triples: entries are tagged with the data version)
        self._profiles: Dict[str, Tuple[tuple, qplan.QueryProfile, int]] = {}
        # read replication (repro.replicate): which shards hold a copy of
        # each feature; the primary assignment above stays authoritative
        self.replicas = replicas or ReplicaMap.primary_only(state)
        assert self.replicas.n_features == len(state.feature_to_shard)
        self._replica_rows: List[np.ndarray] = [
            np.empty(0, np.int64)] * state.n_shards
        self._shard_rows: List[Optional[np.ndarray]] = [None] * state.n_shards
        self._read_cache: Dict[int, np.ndarray] = {}   # ppn -> read shards
        self._rebuild_feature_index()
        if self.replicas.has_replicas:
            for s in range(state.n_shards):
                self._refresh_replica_rows(s, state.feature_to_shard)

    # ------------------------------------------------------------------ #
    # executor compatibility
    # ------------------------------------------------------------------ #
    @property
    def n_shards(self) -> int:
        return self.state.n_shards

    @property
    def triple_shard(self) -> np.ndarray:
        """Current shard of every global triple row, (N,) int32."""
        return self._triple_shard

    @property
    def shards(self) -> List[TripleStore]:
        """Materialized per-shard views (lazily built, cached until a delta
        touches the shard). A shard's view holds its primary slice plus any
        replica copies pinned onto it (``self.replicas``)."""
        for s in range(self.state.n_shards):
            if self._views[s] is None:
                self._views[s] = TripleStore(
                    self.store.triples[self.shard_rows(s)],
                    self.store.dictionary)
                self.view_rebuilds += 1
                self.metrics.counter("cache.view_rebuilds").inc()
        return list(self._views)

    def shard_rows(self, s: int) -> np.ndarray:
        """Global triple rows materialized on shard ``s`` — primary rows
        first, then replica-copy rows. ``shards[s]`` view row ``i`` is
        global row ``shard_rows(s)[i]``."""
        if self._shard_rows[s] is None:
            rep = self._replica_rows[s]
            self._shard_rows[s] = (self._rows[s] if len(rep) == 0 else
                                   np.concatenate([self._rows[s], rep]))
        return self._shard_rows[s]

    def shard_sizes(self) -> List[int]:
        """Primary (owned) triples per shard — replica copies not counted;
        this is the balance quantity the partitioner optimizes."""
        return [len(r) for r in self._rows]

    # ------------------------------------------------------------------ #
    # replica-aware read layout
    # ------------------------------------------------------------------ #
    def read_shard(self, ppn: int) -> np.ndarray:
        """Per-triple serving shard for a query homed at ``ppn``: the PPN
        itself when the triple's owner feature holds a copy there (local
        read — nothing shipped), else the primary. Cached per PPN for the
        current epoch."""
        cached = self._read_cache.get(ppn)
        if cached is None:
            on = self.replicas.on_shard(ppn)
            cached = np.where(on[self.owners], np.int32(ppn),
                              self._triple_shard)
            self._read_cache[ppn] = cached
            # replica-served volume: triples a query homed at this PPN
            # reads from local copies instead of shipping (vs. the
            # federation.bytes_shipped counter's actual wire traffic)
            local = int(np.count_nonzero((cached == ppn)
                                         & (self._triple_shard != ppn)))
            self.metrics.gauge(
                f"replicate.local_read_rows.ppn{ppn}").set(local)
        return cached

    def _refresh_replica_rows(self, s: int,
                              feature_to_shard: np.ndarray) -> bool:
        """Recompute shard ``s``'s replica-copy rows (owner features holding
        a copy on ``s`` whose primary is elsewhere). Returns True when the
        set changed (the shard's view must be re-materialized)."""
        on = self.replicas.on_shard(s)
        on[feature_to_shard == s] = False
        rows = self._rows_of(np.flatnonzero(on))
        changed = not np.array_equal(rows, self._replica_rows[s])
        self._replica_rows[s] = rows
        if changed:
            self._views[s] = None
            self._shard_rows[s] = None
        return changed

    def _invalidate_caches(self) -> None:
        """Epoch-scoped caches: plans, results and read layouts are valid
        for exactly one served layout."""
        self._plans.clear()
        self._results.clear()
        self._read_cache.clear()

    # ------------------------------------------------------------------ #
    # owner-feature row index (CSR over triples grouped by owner feature)
    # ------------------------------------------------------------------ #
    def _rebuild_feature_index(self) -> None:
        order = np.argsort(self.owners, kind="stable").astype(np.int64)
        nf = len(self.state.feature_to_shard)
        self._feat_order = order
        self._feat_starts = np.searchsorted(
            self.owners[order], np.arange(nf + 1))

    def _rows_of(self, feats: np.ndarray) -> np.ndarray:
        parts = [self._feat_order[self._feat_starts[f]:self._feat_starts[f + 1]]
                 for f in feats.tolist()]
        return (np.concatenate(parts) if parts
                else np.empty(0, dtype=np.int64))

    # ------------------------------------------------------------------ #
    # feature-universe growth (adaptive PO-split tracking)
    # ------------------------------------------------------------------ #
    def sync_universe(self) -> None:
        """Absorb newly-tracked PO features from the FeatureSpace.

        A split PO feature's triples stay on the parent's shard (ownership
        split, no data movement), so the triple->shard mapping — and every
        shard view — is unchanged; only owners/sizes/state are re-derived.
        Cached plans are invalidated: feature sizes feed the PPN vote."""
        if self.space.n_features == len(self.state.feature_to_shard):
            return
        self.state, self.owners = migration.extend_for_space(self.state,
                                                             self.space)
        self.epoch += 1
        self._invalidate_caches()
        self._rebuild_feature_index()
        # new (split) PO features start primary-only; a split parent's
        # replica copies keep only the rows the parent still owns
        self.replicas.extend(self.state.feature_to_shard)
        if self.replicas.has_replicas:
            for s in range(self.state.n_shards):
                self._refresh_replica_rows(s, self.state.feature_to_shard)

    # ------------------------------------------------------------------ #
    # incremental deltas
    # ------------------------------------------------------------------ #
    def _apply(self, new_state: PartitionState,
               replica_adds: Sequence[Tuple[int, int, int]] = (),
               replica_drops: Sequence[Tuple[int, int]] = ()) -> None:
        assert len(new_state.feature_to_shard) == \
            len(self.state.feature_to_shard), \
            "sync_universe() before applying a delta over a grown universe"
        changed = np.flatnonzero(
            self.state.feature_to_shard != new_state.feature_to_shard)
        # replica ops first (drops, then — after the moves below — adds),
        # tracking which shards' copy sets actually change
        rep_touched: set = set()
        dropped = 0
        for f, s in replica_drops:
            if int(new_state.feature_to_shard[f]) != s \
                    and self.replicas.has(f, s):
                self.replicas.remove(f, s)
                rep_touched.add(s)
                dropped += 1
        # an add is effective unless the target IS the feature's new primary
        # or will still hold a copy after the moves below run: a retained
        # copy at a moving feature's OLD primary is effective (the move
        # clears that bit), an add onto any other existing copy is not.
        # One predicate drives both no-op detection and application.
        moving = set(changed.tolist())

        def _add_effective(f: int, dst: int) -> bool:
            if int(new_state.feature_to_shard[f]) == dst:
                return False
            if f in moving and dst == int(self.state.feature_to_shard[f]):
                return True
            return not self.replicas.has(f, dst)

        effective_adds = [(f, dst) for f, _src, dst in replica_adds
                          if _add_effective(f, dst)]
        if len(changed) == 0 and not rep_touched and not effective_adds:
            self.state = new_state         # no-op delta: the served layout is
            return                         # unchanged — keep plans/views/epoch
        rows = self._rows_of(changed)
        old_shards = self._triple_shard[rows]
        new_shards = new_state.feature_to_shard[self.owners[rows]] \
            .astype(np.int32)
        touched = (np.unique(np.concatenate([old_shards, new_shards])).tolist()
                   if len(rows) else [])
        for f in changed.tolist():         # the move carries the primary copy
            self.replicas.move_primary(
                f, int(self.state.feature_to_shard[f]),
                int(new_state.feature_to_shard[f]))
        for f, dst in effective_adds:      # after the moves, so a retained
            self.replicas.add(f, dst)      # old-primary copy sticks
            rep_touched.add(dst)
        self._triple_shard[rows] = new_shards
        for s in set(touched) | rep_touched:
            if s in touched:
                self._rows[s] = np.flatnonzero(self._triple_shard == s)
                self._views[s] = None      # re-indexed lazily on next access
                self._shard_rows[s] = None
            self._refresh_replica_rows(s, new_state.feature_to_shard)
        self.state = new_state
        self.epoch += 1
        self._invalidate_caches()          # PPN/federation annotations changed
        m = self.metrics
        m.counter("migrate.features_moved").inc(len(changed))
        m.counter("replicate.promotions").inc(len(effective_adds))
        m.counter("replicate.demotions").inc(dropped)
        m.gauge("layout.epoch").set(self.epoch)

    def apply_chunk(self, chunk: migration.MigrationChunk) -> None:
        """Apply one ``MigrationChunk`` of an in-flight migration as an
        incremental delta. The resulting partially-migrated layout is served
        as-is (a new epoch): only shards touched by the chunk's moves and
        replica ops are re-indexed, and cached plans/results are invalidated
        because the PPN vote and federation annotations may have shifted.

        The delta is derived from the **live** state, so a chunk moving a
        feature whose triples changed since the session was planned (live
        writes, ``apply_write``) carries the post-write rows — the row set
        shipped is whatever the owner feature holds *now*."""
        state = self.state.copy()
        for f, _src, dst in chunk.moves:
            state.feature_to_shard[f] = dst
        self._apply(state, getattr(chunk, "replica_adds", ()),
                    getattr(chunk, "replica_drops", ()))

    # ------------------------------------------------------------------ #
    # live writes (repro.write)
    # ------------------------------------------------------------------ #
    def apply_write(self, batch) -> "object":
        """Apply a ``repro.write.WriteBatch`` to the served graph: effective
        rows are routed by the current primary assignment of their owner
        feature, fanned out to every ``ReplicaMap`` holder, and only the
        touched shard views are re-indexed. An effective write is a new
        epoch AND a new data version (plans, results and layout-invariant
        profiles all invalidate); a fully-redundant batch changes nothing.
        Returns the ``repro.write.WriteReport``."""
        from repro import write as kgwrite
        return kgwrite.apply_batch(self, batch)

    # ------------------------------------------------------------------ #
    # plans, profiles, candidate pricing
    # ------------------------------------------------------------------ #
    def plan(self, q: Query) -> qplan.QueryPlan:
        """The query's execution plan under the current layout (cached per
        ``(query, store)``; invalidated by ``commit``/``sync_universe``/
        ``apply_write``)."""
        pats = tuple(q.patterns)
        entry = self._plans.get(q.name)
        if entry is None or entry[0] != pats:
            entry = (pats, qplan.plan(q, self), self.epoch)
            self._plans[q.name] = entry
            self.plan_builds += 1
            self.metrics.counter("cache.plan_builds").inc()
        else:
            assert entry[2] == self.epoch, \
                f"stale plan served for {q.name}: cached at epoch " \
                f"{entry[2]}, layout is at {self.epoch} — a mutating path " \
                "bumped the epoch without invalidating"
            self.plan_hits += 1
            self.metrics.counter("cache.plan_hits").inc()
        return entry[1]

    def profile(self, q: Query) -> qplan.QueryProfile:
        """Layout-invariant execution profile of ``q``, derived from its plan
        (cached; one real execution against the global store on first use).
        Survives layout epochs but not writes — profiles hold global row
        ids of the triples the query matched."""
        pats = tuple(q.patterns)
        entry = self._profiles.get(q.name)
        if entry is None or entry[0] != pats:
            entry = (pats, qexec.profile_from_plan(self.plan(q), self.store,
                                                   self.max_join_rows),
                     self.data_version)
            self._profiles[q.name] = entry
        else:
            assert entry[2] == self.data_version, \
                f"stale profile served for {q.name}: cached at data " \
                f"version {entry[2]}, store is at {self.data_version} — a " \
                "write path skipped profile invalidation"
        return entry[1]

    def cached_result(self, q: Query,
                      ) -> Optional[Tuple[dict, qexec.ExecStats]]:
        """Bindings+stats of ``q`` if already executed at the current epoch
        (bindings are layout-invariant under moves/replication — NOT under
        writes, which bump the epoch too; stats are valid per epoch). None
        on a miss — the caller executes and ``store_result``s. Binding
        columns and the stats are copied both into and out of the cache, so
        callers mutating their result (or the original executor objects)
        can never corrupt a later hit — a memcpy per column, still far
        below a re-execution."""
        entry = self._results.get(q.name)
        if entry is not None and entry[0] == tuple(q.patterns):
            assert entry[3] == self.epoch, \
                f"stale result served for {q.name}: cached at epoch " \
                f"{entry[3]}, layout is at {self.epoch} — a mutating path " \
                "bumped the epoch without invalidating"
            self.result_hits += 1
            self.metrics.counter("cache.result_hits").inc()
            return ({v: c.copy() for v, c in entry[1].items()},
                    dataclasses.replace(entry[2]))
        return None

    def store_result(self, q: Query, bindings: dict,
                     stats: qexec.ExecStats) -> None:
        self._results[q.name] = (tuple(q.patterns),
                                 {v: c.copy() for v, c in bindings.items()},
                                 dataclasses.replace(stats), self.epoch)

    def measure_candidate(self, cand: PartitionState,
                          queries: Sequence[Query], net=None,
                          replicas=None) -> float:
        """Average modeled workload time under ``cand`` — pure federation
        re-accounting over cached query profiles. No joins are re-executed,
        no shard view is touched: only the candidate's triple->shard map is
        derived (one gather) and each profiled pattern re-priced. With a
        candidate ``ReplicaMap``, shipping is charged against the nearest
        replica (``stats_from_profile``) — how replica-served savings enter
        the adaptation guard's benefit side."""
        self.sync_universe()
        triple_shard = cand.feature_to_shard[self.owners].astype(np.int32)
        net = net or qexec.NetworkModel()
        num = den = 0.0
        for q in queries:
            st = qplan.stats_from_profile(q, self.profile(q), self.space,
                                          cand, triple_shard,
                                          replicas=replicas,
                                          owners=self.owners)
            num += st.modeled_time(net) * q.frequency
            den += q.frequency
        return num / max(den, 1e-12)

    def commit(self, new_state: PartitionState) -> migration.MigrationPlan:
        """Adopt ``new_state``; returns the migration delta that was applied.
        Only shards touched by moved features are re-indexed."""
        self.sync_universe()
        plan = migration.plan(self.state, new_state)
        self._apply(new_state)
        return plan

    # ------------------------------------------------------------------ #
    def imbalance(self) -> float:
        return self.state.imbalance()

    def telemetry(self) -> dict:
        """Serving-counter snapshot — layout identity plus the cache/view
        telemetry the facade accumulates. Folded into ``KGService.stats()``
        next to the streaming layer's latency aggregates."""
        return dict(epoch=self.epoch, data_version=self.data_version,
                    n_triples=self.store.n_triples, n_shards=self.n_shards,
                    n_features=len(self.state.feature_to_shard),
                    n_replicated=len(self.replicas.replicated()),
                    imbalance=self.imbalance(),
                    plan_builds=self.plan_builds, plan_hits=self.plan_hits,
                    result_hits=self.result_hits,
                    view_rebuilds=self.view_rebuilds)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PartitionedKG(n_triples={self.store.n_triples}, "
                f"n_shards={self.n_shards}, "
                f"n_features={len(self.state.feature_to_shard)}, "
                f"n_replicated={len(self.replicas.replicated())}, "
                f"epoch={self.epoch})")
