"""repro.api — the public partitioning-service surface.

Everything callers need to serve a partitioned knowledge graph:

* strategies: :class:`Partitioner` protocol with :class:`HashPartitioner`,
  :class:`WawPartitioner`, :class:`AWAPartitioner`;
* :class:`PartitionedKG` — shard-view facade with incremental delta updates;
* :class:`KGService` — the Fig.-6 session loop
  (``bootstrap / query / observe / maybe_adapt / reset_baseline``).

See ``docs/api.md`` for the quickstart.
"""
from repro.api.facade import PartitionedKG
from repro.api.partitioners import (AWAPartitioner, HashPartitioner,
                                    Partitioner, WawPartitioner)
from repro.api.service import KGService

__all__ = [
    "AWAPartitioner",
    "HashPartitioner",
    "KGService",
    "PartitionedKG",
    "Partitioner",
    "WawPartitioner",
]
