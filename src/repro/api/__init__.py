"""repro.api — the public partitioning-service surface.

Everything callers need to serve a partitioned knowledge graph:

* strategies: :class:`Partitioner` protocol with :class:`HashPartitioner`,
  :class:`WawPartitioner`, :class:`AWAPartitioner`;
* :class:`PartitionedKG` — shard-view facade with incremental delta updates
  and the per-``(query, store)`` plan cache;
* :class:`KGService` — the Fig.-6 session loop (``bootstrap / query /
  query_batch / observe / maybe_adapt / step / drain / reset_baseline``);
* :class:`MigrationSession` — chunked online application of an accepted
  migration (``repro.migrate``), throttled by the service's
  ``migration_budget`` knob;
* :class:`ReplicaMap` — workload-aware read replication of hot features
  (``repro.replicate``), budgeted by the service's ``replica_budget`` knob;
* :class:`WriteBatch` / :class:`WriteReport` — the live write path
  (``repro.write``): ``svc.insert(...)`` / ``svc.delete(...)`` served
  concurrently with queries, replication, and an in-flight drain;
* :class:`StreamService` / :class:`LatencyRecorder` — continuous
  admission (``repro.stream``): ``svc.stream()`` serves submitted
  queries/writes in pipelined windows, byte-identical to ``query_batch``
  over the same admission order, with p50/p95/p99 tail telemetry on
  ``svc.stats()``;
* executors: :class:`Executor` protocol with :class:`NumpyExecutor`
  (reference) and :class:`JaxExecutor` (batched; ``pallas=True`` — the
  ``executor="jax-pallas"`` knob — probes joins through the
  ``repro.kernels.join`` Pallas kernel family), re-exported from
  ``repro.query.exec``;
* observability: :class:`Tracer` / :class:`MetricsRegistry`
  (``repro.obs``) — ``KGService(trace=True)`` records per-query
  plan→scan→join→federate→ship spans plus window / migration-chunk /
  write-batch / adaptation-round spans on the modeled clock
  (``svc.tracer().export("out.json")`` is Perfetto-loadable), and every
  service folds its metrics snapshot into ``stats()["metrics"]``.

See ``docs/api.md`` for the quickstart.
"""
from repro.api.facade import PartitionedKG
from repro.api.partitioners import (AWAPartitioner, HashPartitioner,
                                    Partitioner, WawPartitioner)
from repro.api.service import KGService
from repro.migrate import MigrationSession
from repro.obs import MetricsRegistry, Tracer
from repro.query.exec import Executor, JaxExecutor, NumpyExecutor
from repro.replicate import ReplicaMap
from repro.stream import LatencyRecorder, StreamService
from repro.write import WriteBatch, WriteLog, WriteReport

__all__ = [
    "AWAPartitioner",
    "Executor",
    "HashPartitioner",
    "JaxExecutor",
    "KGService",
    "LatencyRecorder",
    "MetricsRegistry",
    "MigrationSession",
    "NumpyExecutor",
    "PartitionedKG",
    "Partitioner",
    "ReplicaMap",
    "StreamService",
    "Tracer",
    "WawPartitioner",
    "WriteBatch",
    "WriteLog",
    "WriteReport",
]
