"""Pluggable partitioning strategies behind one ``Partitioner`` protocol.

Three interchangeable strategies cover the paper's comparison axes:

* ``HashPartitioner``  — workload-oblivious feature hashing (the baseline
  non-workload-aware systems use),
* ``WawPartitioner``   — WawPart-style workload-aware *initial* partition
  ([21] in the paper), no adaptivity,
* ``AWAPartitioner``   — the full adaptive Fig.-5 loop; ``adapt`` prices
  candidate cuts against a live ``PartitionedKG``'s cached query profiles
  instead of re-materializing a ShardedStore and re-executing the workload
  per candidate.
"""
from __future__ import annotations

from typing import Optional, Protocol, Sequence, Tuple, runtime_checkable

from repro.core import migration
from repro.core.adaptive import AdaptConfig, AdaptReport, AWAPartController
from repro.core.features import FeatureSpace
from repro.core.partition import (PartitionState, balanced_partition,
                                  hash_partition)
from repro.migrate import MigrationSession
from repro.query import exec as qexec
from repro.query.pattern import Query

from repro.api.facade import PartitionedKG


@runtime_checkable
class Partitioner(Protocol):
    """Strategy protocol: map (feature space, shard count, workload) to a
    ``PartitionState``. Adaptive strategies additionally expose
    ``adapt(kg, new_queries)`` and a ``controller``."""

    name: str

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        ...


class HashPartitioner:
    """Feature-hash baseline; ignores the workload entirely."""

    name = "hash"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        return hash_partition(space.feature_sizes(), n_shards, self.seed)


class WawPartitioner:
    """Workload-aware initial partition (WawPart [21]): cluster the workload
    once and co-locate each cluster's features; never re-adapts."""

    name = "wawpart"

    def __init__(self, config: AdaptConfig | None = None):
        self.config = config

    def _initial(self, space: FeatureSpace, n_shards: int,
                 workload: Sequence[Query]) -> Tuple[PartitionState,
                                                     AWAPartController]:
        ctrl = AWAPartController(space, n_shards, self.config)
        workload = list(workload)
        if not workload:     # nothing to be aware of: balanced round-robin
            ctrl.state = balanced_partition(space.feature_sizes(), n_shards)
            return ctrl.state, ctrl
        space.track_workload(workload)
        return ctrl.initial_partition(workload), ctrl

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        state, _ = self._initial(space, n_shards, workload)
        return state


class AWAPartitioner(WawPartitioner):
    """WawPart initial partition + the adaptive Fig.-5 control loop."""

    name = "awapart"

    def __init__(self, config: AdaptConfig | None = None):
        super().__init__(config)
        self.controller: Optional[AWAPartController] = None

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        state, self.controller = self._initial(space, n_shards, workload)
        return state

    def adapt(self, kg: PartitionedKG, new_queries: Sequence[Query] = (),
              net=None, measure=None, bytes_budget: Optional[int] = None,
              ) -> Tuple[MigrationSession, AdaptReport]:
        """One adaptation round against the live facade — returns a
        :class:`MigrationSession` instead of mutating the served layout.

        Each candidate cut is priced via the facade's cached query profiles
        (no joins re-executed, no views touched); the controller's
        migration-cost-aware guard then accepts the winner only if the
        modeled savings amortize the plan's traffic over the expected TM
        window. The round is replica-aware end to end: the live facade's
        ``ReplicaMap`` feeds the controller, which promotes hot features /
        demotes cold replicas under ``config.replica_budget`` and prices
        both the copy traffic (cost) and the nearest-replica shipping
        savings (benefit). Nothing is committed here: the accepted plan
        comes back as a session whose chunks (hottest workload features
        first, each at most ``bytes_budget`` of traffic; ``None`` = one
        chunk) the caller drains while serving. A rejected round returns an
        already-drained noop session. ``measure`` overrides the objective
        (``None`` = modeled workload-average time from the profiles)."""
        assert self.controller is not None, "partition() first"
        ctrl = self.controller
        net_model = net or qexec.NetworkModel()
        if measure is None:
            def measure(cand: PartitionState, replicas=None) -> float:
                return kg.measure_candidate(
                    cand, list(ctrl.workload.values()), net,
                    replicas=replicas)
        state, report = ctrl.adapt(list(new_queries), measure=measure,
                                   net=net_model, replicas=kg.replicas)
        kg.sync_universe()     # align the served universe with the round's
        if not (report.accepted
                and (report.plan.n_moves or report.plan.n_replica_ops)):
            return MigrationSession.noop(kg), report
        heat = report.heat if report.heat is not None else \
            migration.feature_heat(ctrl.space, list(ctrl.workload.values()))
        # the session's delta is derived from the *live* facade state (which
        # may be a mid-drain hybrid), so draining always lands exactly on the
        # accepted target — report.plan stays the guard's priced plan
        session = MigrationSession(kg, state, bytes_budget=bytes_budget,
                                   priority=heat, net=net_model,
                                   target_replicas=report.replicas)
        return session, report
