"""Pluggable partitioning strategies behind one ``Partitioner`` protocol.

Three interchangeable strategies cover the paper's comparison axes:

* ``HashPartitioner``  — workload-oblivious feature hashing (the baseline
  non-workload-aware systems use),
* ``WawPartitioner``   — WawPart-style workload-aware *initial* partition
  ([21] in the paper), no adaptivity,
* ``AWAPartitioner``   — the full adaptive Fig.-5 loop; ``adapt`` prices
  candidate cuts against a live ``PartitionedKG``'s cached query profiles
  instead of re-materializing a ShardedStore and re-executing the workload
  per candidate.
"""
from __future__ import annotations

from typing import Optional, Protocol, Sequence, Tuple, runtime_checkable

from repro.core.adaptive import AdaptConfig, AdaptReport, AWAPartController
from repro.core.features import FeatureSpace
from repro.core.partition import (PartitionState, balanced_partition,
                                  hash_partition)
from repro.query.pattern import Query

from repro.api.facade import PartitionedKG


@runtime_checkable
class Partitioner(Protocol):
    """Strategy protocol: map (feature space, shard count, workload) to a
    ``PartitionState``. Adaptive strategies additionally expose
    ``adapt(kg, new_queries)`` and a ``controller``."""

    name: str

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        ...


class HashPartitioner:
    """Feature-hash baseline; ignores the workload entirely."""

    name = "hash"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        return hash_partition(space.feature_sizes(), n_shards, self.seed)


class WawPartitioner:
    """Workload-aware initial partition (WawPart [21]): cluster the workload
    once and co-locate each cluster's features; never re-adapts."""

    name = "wawpart"

    def __init__(self, config: AdaptConfig | None = None):
        self.config = config

    def _initial(self, space: FeatureSpace, n_shards: int,
                 workload: Sequence[Query]) -> Tuple[PartitionState,
                                                     AWAPartController]:
        ctrl = AWAPartController(space, n_shards, self.config)
        workload = list(workload)
        if not workload:     # nothing to be aware of: balanced round-robin
            ctrl.state = balanced_partition(space.feature_sizes(), n_shards)
            return ctrl.state, ctrl
        space.track_workload(workload)
        return ctrl.initial_partition(workload), ctrl

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        state, _ = self._initial(space, n_shards, workload)
        return state


class AWAPartitioner(WawPartitioner):
    """WawPart initial partition + the adaptive Fig.-5 control loop."""

    name = "awapart"

    def __init__(self, config: AdaptConfig | None = None):
        super().__init__(config)
        self.controller: Optional[AWAPartController] = None

    def partition(self, space: FeatureSpace, n_shards: int,
                  workload: Sequence[Query] = ()) -> PartitionState:
        state, self.controller = self._initial(space, n_shards, workload)
        return state

    def adapt(self, kg: PartitionedKG, new_queries: Sequence[Query] = (),
              net=None, measure=None) -> Tuple[PartitionState, AdaptReport]:
        """One adaptation round against the live facade.

        Each candidate cut is priced via the facade's cached query profiles
        (no joins re-executed, no views touched); the controller's
        accept/revert guard then commits the winner (or nothing) as an
        incremental delta. ``measure`` overrides the objective (``None`` =
        modeled workload-average time from the profiles)."""
        assert self.controller is not None, "partition() first"
        ctrl = self.controller
        if measure is None:
            def measure(cand: PartitionState) -> float:
                return kg.measure_candidate(
                    cand, list(ctrl.workload.values()), net)
        state, report = ctrl.adapt(list(new_queries), measure=measure)
        kg.commit(state)
        return state, report
