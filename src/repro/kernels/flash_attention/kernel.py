"""Pallas TPU flash attention (forward), GQA-aware, cache/offset-aware.

Tiling: grid = (B, H, S/BQ, T/BK); the last (KV) grid axis is sequential and
carries the online-softmax state in VMEM scratch (acc (BQ, D) f32, plus row
max m and row sum l). Each program loads a (BQ, D) query tile and a (BK, D)
key/value tile for its head — MXU-aligned when BQ/BK/D are multiples of 128
(D=64 archs still lower; the MXU pads). KV tiles fully beyond the causal
horizon are skipped with ``pl.when`` so causal attention does half the work.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, q_offset: int, kv_valid_len: Optional[int],
                  bq: int, bk: int, n_kv_blocks: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq + q_offset
    k_start = ki * bk
    # skip KV tiles entirely above the causal diagonal
    needed = jnp.logical_or(not causal, k_start <= q_start + bq - 1)
    if kv_valid_len is not None:
        needed = jnp.logical_and(needed, k_start < kv_valid_len)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok = qpos >= kpos
        if kv_valid_len is not None:
            ok = jnp.logical_and(ok, kpos < kv_valid_len)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_offset", "kv_valid_len",
                              "block_q", "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, q_offset: int = 0,
                        kv_valid_len: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, S, H, D); k/v: (B, T, K, D). Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(block_q, s)
    bk = min(block_k, t)
    # pad S and T to block multiples
    sp = (s + bq - 1) // bq * bq
    tp = (t + bk - 1) // bk * bk
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    if tp != t:
        k = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
        # padded kv slots must be masked out
        kv_valid_len = t if kv_valid_len is None else min(kv_valid_len, t)
    n_kv = tp // bk

    kernel = functools.partial(
        _flash_kernel, causal=causal, q_offset=q_offset,
        kv_valid_len=kv_valid_len, bq=bq, bk=bk, n_kv_blocks=n_kv,
        scale=1.0 / np.sqrt(d))

    out = pl.pallas_call(
        kernel,
        grid=(b, h, sp // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h_, qi, ki, g_=g: (b_, ki, h_ // g_, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h_, qi, ki, g_=g: (b_, ki, h_ // g_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),      # acc
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running sum
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
