"""Pure-jnp oracle for GQA flash attention (incl. cache masking)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, q_offset: int = 0,
              kv_valid_len: Optional[int] = None) -> jnp.ndarray:
    """q: (B, S, H, D); k/v: (B, T, K, D) with H % K == 0. f32 softmax.

    ``q_offset`` shifts query positions (decode against a cache);
    ``kv_valid_len`` masks cache slots >= that length."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(d)
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        qpos = jnp.arange(s)[:, None] + q_offset
        ok = qpos >= kpos
    if kv_valid_len is not None:
        ok = jnp.logical_and(ok, (kpos < kv_valid_len))
    logits = jnp.where(ok[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, d)
