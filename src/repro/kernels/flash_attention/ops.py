"""Public flash-attention op: Pallas forward + chunked flash-style backward.

The backward pass recomputes attention per query chunk (never materializing
the full (S, T) matrix for more than one chunk) using the standard flash
gradient identities:

    D  = rowsum(dO ∘ O)
    dS = P ∘ (dP − D),  dP = dO Vᵀ
    dQ = dS K·scale,  dK = dSᵀ Q·scale,  dV = Pᵀ dO

It is pure jnp (XLA fuses it well on TPU); the forward is the Pallas kernel
(compiled on TPU, ``interpret=True`` on this CPU container). This is a
deliberate engineering choice documented in DESIGN.md — fwd owns the memory
win (no S×T materialization at 32k prefill), bwd chunking bounds the train
peak the same way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, q_offset, kv_valid_len, interpret):
    return kernel.flash_attention_fwd(
        q, k, v, causal=causal, q_offset=q_offset,
        kv_valid_len=kv_valid_len, interpret=interpret)


def _fwd(q, k, v, causal, q_offset, kv_valid_len, interpret):
    o = kernel.flash_attention_fwd(
        q, k, v, causal=causal, q_offset=q_offset,
        kv_valid_len=kv_valid_len, interpret=interpret)
    return o, (q, k, v, o)


def _bwd(causal, q_offset, kv_valid_len, interpret, res, do):
    q, k, v, o = res
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = 1.0 / np.sqrt(d)
    chunk = min(128, s)
    sp = (s + chunk - 1) // chunk * chunk
    pad = sp - s

    qf = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    dof = jnp.pad(do, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    of = jnp.pad(o, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    d_row = (dof * of).sum(-1)                         # (B, SP, H)

    kpos = jnp.arange(t)[None, :]

    def q_chunk(carry, idx):
        dk_acc, dv_acc = carry
        lo = idx * chunk
        qc = jax.lax.dynamic_slice_in_dim(qf, lo, chunk, 1)
        doc = jax.lax.dynamic_slice_in_dim(dof, lo, chunk, 1)
        drc = jax.lax.dynamic_slice_in_dim(d_row, lo, chunk, 1)
        qg = qc.reshape(b, chunk, kh, g, d)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * scale
        qpos = lo + jnp.arange(chunk)[:, None] + q_offset
        ok = jnp.ones((chunk, t), bool)
        if causal:
            ok = qpos >= kpos
        if kv_valid_len is not None:
            ok = jnp.logical_and(ok, kpos < kv_valid_len)
        # rows past the real sequence end are fully masked; guard softmax
        logits = jnp.where(ok[None, None, None], logits, -1e30)
        mx = logits.max(-1, keepdims=True)
        p = jnp.exp(logits - mx)
        p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
        dog = doc.reshape(b, chunk, kh, g, d)
        dp = jnp.einsum("bskgd,btkd->bkgst", dog, vf)
        drg = drc.reshape(b, chunk, kh, g).transpose(0, 2, 3, 1)
        ds = p * (dp - drg[..., None])
        dq_c = jnp.einsum("bkgst,btkd->bskgd", ds, kf).reshape(
            b, chunk, h, d) * scale
        dk_acc = dk_acc + jnp.einsum("bkgst,bskgd->btkd", ds, qg) * scale
        dv_acc = dv_acc + jnp.einsum("bkgst,bskgd->btkd", p, dog)
        return (dk_acc, dv_acc), dq_c

    zeros_kv = jnp.zeros((b, t, kh, d), jnp.float32)
    (dk, dv), dq_chunks = jax.lax.scan(
        q_chunk, (zeros_kv, zeros_kv), jnp.arange(sp // chunk))
    dq = jnp.moveaxis(dq_chunks, 0, 1).reshape(b, sp, h, d)[:, :s]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    kv_valid_len: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Differentiable flash attention. q: (B,S,H,D); k/v: (B,T,K,D)."""
    if interpret is None:
        interpret = not _on_tpu()
    if isinstance(q_offset, jnp.ndarray):
        q_offset = int(q_offset)           # static for kernel specialization
    return _flash(q, k, v, causal, q_offset, kv_valid_len, interpret)
