"""Shared kernel/fallback dispatch policy for ``repro.kernels``.

Every op module under ``src/repro/kernels`` answers the same three-way
question — compiled Pallas kernel, ``interpret=True`` kernel, or jnp
oracle — and until this module existed each ``ops.py`` hard-coded its own
size threshold (``jaccard`` shipped a literal ``>= 256``). The policy now
lives in one place:

* :func:`on_tpu` — are we on a real TPU backend (compiled kernels)?
* :func:`kernel_threshold` — the problem-size floor below which the jnp
  oracle wins (no tiling/pad overhead). Overridable per-process via the
  ``REPRO_KERNEL_THRESHOLD`` environment variable or per-call via the
  ``threshold=`` argument.
* :func:`resolve` — turn a caller's ``use_kernel``/``interpret`` pair
  (``None`` = auto) into concrete booleans.

Two auto policies exist, selected by ``hot_path``:

* ``hot_path=False`` (analysis ops, e.g. ``jaccard``): the kernel runs for
  any large-enough problem, *including* ``interpret=True`` on CPU — these
  ops fire once per adaptation round, so the interpreter cost is an
  acceptable price for exercising the real kernel everywhere.
* ``hot_path=True`` (serving ops, e.g. ``join``): interpret mode is never
  chosen automatically — on CPU the jnp oracle serves (XLA-compiled, fast),
  and the Pallas kernel runs only on TPU or when explicitly forced
  (``use_kernel=True``, how the equivalence tests pin it).
"""
from __future__ import annotations

import os

import jax

DEFAULT_KERNEL_THRESHOLD = 256
_ENV_VAR = "REPRO_KERNEL_THRESHOLD"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_threshold(threshold: int | None = None) -> int:
    """The dispatch size floor: explicit argument > env override > default."""
    if threshold is not None:
        return threshold
    env = os.environ.get(_ENV_VAR)
    if env is not None:
        return int(env)
    return DEFAULT_KERNEL_THRESHOLD


def resolve(use_kernel: bool | None, interpret: bool | None, size: int, *,
            hot_path: bool = False,
            threshold: int | None = None) -> tuple[bool, bool]:
    """Resolve a ``(use_kernel, interpret)`` pair for a problem of ``size``.

    ``None`` means auto; explicit booleans pass through untouched (tests
    force ``use_kernel=True`` to pin the kernel path bit-exactly on CPU).
    """
    floor = kernel_threshold(threshold)
    if use_kernel is None:
        if hot_path:
            use_kernel = on_tpu() and size >= floor
        else:
            use_kernel = on_tpu() or size >= floor
    if interpret is None:
        interpret = not on_tpu()
    return use_kernel, interpret
