"""Shared kernel/fallback dispatch policy for ``repro.kernels``.

Every op module under ``src/repro/kernels`` answers the same three-way
question — compiled Pallas kernel, ``interpret=True`` kernel, or jnp
oracle — and until this module existed each ``ops.py`` hard-coded its own
size threshold (``jaccard`` shipped a literal ``>= 256``). The policy now
lives in one place:

* :func:`on_tpu` — are we on a real TPU backend (compiled kernels)?
* :func:`kernel_threshold` — the problem-size floor below which the jnp
  oracle wins (no tiling/pad overhead). Overridable per-process via the
  ``REPRO_KERNEL_THRESHOLD`` environment variable or per-call via the
  ``threshold=`` argument.
* :func:`resolve` — turn a caller's ``use_kernel``/``interpret`` pair
  (``None`` = auto) into concrete booleans.
* :func:`envelope` / :func:`load_profile` — per-op scaling-envelope values
  (the join family's probe-work / gather-residency / expand-work caps).
  Resolution order: process env var > a loaded **dispatch profile** >
  the op's hard-coded default. Profiles are recorded empirically by
  ``repro.kernels.autotune`` (kernel-vs-fallback crossover sweeps) and
  installed either programmatically (:func:`load_profile`) or via the
  ``REPRO_DISPATCH_PROFILE`` environment variable naming a profile JSON —
  so the envelopes reflect measured hardware, not guesses.

Two auto policies exist, selected by ``hot_path``:

* ``hot_path=False`` (analysis ops, e.g. ``jaccard``): the kernel runs for
  any large-enough problem, *including* ``interpret=True`` on CPU — these
  ops fire once per adaptation round, so the interpreter cost is an
  acceptable price for exercising the real kernel everywhere.
* ``hot_path=True`` (serving ops, e.g. ``join``): interpret mode is never
  chosen automatically — on CPU the jnp oracle serves (XLA-compiled, fast),
  and the Pallas kernel runs only on TPU or when explicitly forced
  (``use_kernel=True``, how the equivalence tests pin it).
"""
from __future__ import annotations

import os

import jax

DEFAULT_KERNEL_THRESHOLD = 256
_ENV_VAR = "REPRO_KERNEL_THRESHOLD"
_PROFILE_ENV = "REPRO_DISPATCH_PROFILE"

# the installed dispatch profile: {envelope name -> value}. Explicit
# load_profile() wins; otherwise lazily loaded from $REPRO_DISPATCH_PROFILE
# (re-read when the env var points somewhere new, so tests can monkeypatch).
_profile: "dict[str, int] | None" = None
_profile_src: "str | None" = None


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def load_profile(profile) -> "dict[str, int]":
    """Install a recorded dispatch profile: a path to an autotune JSON, a
    ``repro.kernels.autotune.DispatchProfile``, or a plain mapping of
    envelope names to values. Returns the installed envelope dict."""
    global _profile, _profile_src
    if hasattr(profile, "envelopes"):                  # DispatchProfile
        data, src = dict(profile.envelopes), "<object>"
    elif isinstance(profile, dict):
        data, src = profile.get("envelopes", profile), "<dict>"
    else:                                              # a JSON path
        import json
        with open(profile) as fh:
            raw = json.load(fh)
        data, src = raw.get("envelopes", raw), str(profile)
    _profile = {str(k): int(v) for k, v in data.items()}
    _profile_src = src
    return dict(_profile)


def clear_profile() -> None:
    global _profile, _profile_src
    _profile = None
    _profile_src = None


def _active_profile() -> "dict[str, int] | None":
    env_path = os.environ.get(_PROFILE_ENV)
    if env_path and _profile_src != env_path and _profile_src not in (
            "<object>", "<dict>"):
        load_profile(env_path)
    return _profile


def envelope(name: str, default: int) -> int:
    """Resolve a dispatch envelope: env var > loaded profile > default."""
    env = os.environ.get(name)
    if env is not None:
        return int(env)
    prof = _active_profile()
    if prof is not None and name in prof:
        return prof[name]
    return default


def kernel_threshold(threshold: int | None = None) -> int:
    """The dispatch size floor: explicit argument > env override > loaded
    profile > default."""
    if threshold is not None:
        return threshold
    return envelope(_ENV_VAR, DEFAULT_KERNEL_THRESHOLD)


def resolve(use_kernel: bool | None, interpret: bool | None, size: int, *,
            hot_path: bool = False,
            threshold: int | None = None) -> tuple[bool, bool]:
    """Resolve a ``(use_kernel, interpret)`` pair for a problem of ``size``.

    ``None`` means auto; explicit booleans pass through untouched (tests
    force ``use_kernel=True`` to pin the kernel path bit-exactly on CPU).
    """
    floor = kernel_threshold(threshold)
    if use_kernel is None:
        if hot_path:
            use_kernel = on_tpu() and size >= floor
        else:
            use_kernel = on_tpu() or size >= floor
    if interpret is None:
        interpret = not on_tpu()
    return use_kernel, interpret


def note_tier(op: str, tier: str, reason: str = "") -> None:
    """Record one dispatch decision in the ambient ``repro.obs`` metrics
    registry (the owning ``KGService``'s): counters
    ``kernels.dispatch.<op>.<tier>`` and, when given, a companion
    ``...<tier>.<reason>`` — so tier picks (pallas/oracle/host) and their
    fallback reasons (size floor, work caps, int32 envelopes, VMEM
    residency) are attributable per op. No-op when no registry is
    installed; called once per op dispatch, never per row."""
    from repro.obs import metrics as obs_metrics
    m = obs_metrics.ambient()
    if m is None:
        return
    m.counter(f"kernels.dispatch.{op}.{tier}").inc()
    if reason:
        m.counter(f"kernels.dispatch.{op}.{tier}.{reason}").inc()
