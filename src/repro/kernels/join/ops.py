"""Public ops: hash-join pack/probe/gather with kernel/oracle dispatch.

Three layers, same math (see ``docs/kernels.md`` for the idiom):

* :mod:`repro.kernels.join.kernel` — Pallas kernels, int64 keys split into
  32-bit word pairs (TPU has no int64). Compiled on TPU, ``interpret=True``
  on CPU.
* :mod:`repro.kernels.join.ref` — the jnp oracle (int64 under
  ``enable_x64``). Jitted with power-of-two shape buckets, this *is* the
  ``JaxExecutor``'s original jitted probe path — the baseline the Pallas
  kernels are benchmarked against.
* this module — the dispatch seam the executor calls. The join sits on the
  per-query serving hot path, so the auto policy is ``hot_path=True``
  (``repro.kernels.dispatch``) plus two scaling guards (the quadratic
  probe-work cap and the gather VMEM-residency cap below): compiled
  kernels on TPU for large-enough in-envelope problems, the jitted oracle
  for the rest of the device cases, and plain host numpy
  (:func:`hash_probe_numpy`) when there is no device at all;
  ``use_kernel=True`` forces the kernel (interpret mode on CPU — how the
  equivalence tests pin bit-equality), ``use_kernel=False`` forces the
  oracle.

:func:`hash_probe` is the composite the executor uses: pack both sides,
stable-sort the build side **on the host** (XLA's CPU sort is
comparator-based and loses badly to ``np.argsort``; on TPU the sort is the
one stage left on the host by design), probe every packed key. Returns
``(order, lo, counts)`` exactly like the numpy reference's searchsorted
probe, so the executors' ragged pair expansion is backend-agnostic.
"""
from __future__ import annotations

import os
from typing import List, Sequence, Tuple

import numpy as np

from repro.kernels import dispatch
from repro.kernels.join import kernel, ref

_INT64_MAX = np.iinfo(np.int64).max
_oracle_cache: dict = {}

# Auto-dispatch scalability guards (forced use_kernel=True bypasses both —
# that's how tests pin the kernels at any shape). Read per call, like
# dispatch.kernel_threshold, so env overrides work after import:
#
# * the count-probe kernel does O(nl * nr) word-pair compares — a win over
#   binary search only while the compare budget is small; past the cap the
#   log-depth oracle is asymptotically faster even with its device hops.
# * the gather kernel keeps the whole value table resident in one VMEM
#   panel; past ~2M int32 rows (8 MB of the ~16 MB VMEM) it cannot tile.

def _probe_work_cap() -> int:
    return int(os.environ.get("REPRO_JOIN_PROBE_WORK_CAP", str(1 << 32)))


def _gather_resident_rows() -> int:
    return int(os.environ.get("REPRO_JOIN_GATHER_RESIDENT_ROWS",
                              str(1 << 21)))


def _pad_pow2(a: np.ndarray, fill=0, min_size: int = 16) -> np.ndarray:
    """Pad axis 0 to the next power of two (stable jit shape buckets)."""
    n = a.shape[0]
    m = max(min_size, 1 << max(n - 1, 0).bit_length())
    if m == n:
        return a
    out = np.full((m,) + a.shape[1:], fill, a.dtype)
    out[:n] = a
    return out


def _oracle_fns():
    """Jitted oracle pack/search, shared by every join of every batch."""
    import jax

    if not _oracle_cache:
        _oracle_cache.update(pack=jax.jit(ref.pack_keys),
                             search=jax.jit(ref.probe_sorted))
    return _oracle_cache["pack"], _oracle_cache["search"]


def _split_words(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Nonnegative int64 keys < 2^62 -> (hi int32, lo uint32) word pair.

    The bound is the K<=2 base-2^31 packing envelope and what keeps the
    probe kernel's +inf padding sentinel (hi = 2^31-1) strictly above every
    real key; a key at or past 2^62 would compare equal to padding and
    inflate the hi counts past the build length."""
    if keys.size and (keys >> 62).any():
        raise ValueError("word-pair kernels require nonnegative packed keys "
                         "< 2^62 (the K<=2 base-2^31 packing envelope)")
    return ((keys >> 32).astype(np.int32),
            (keys & np.int64(0xFFFFFFFF)).astype(np.uint32))


def _join_words(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    return (hi.astype(np.int64) << 32) | lo.astype(np.int64)


# --------------------------------------------------------------------------- #
# granular ops (bench / tests / docs surface)
# --------------------------------------------------------------------------- #

def pack_keys(cols: np.ndarray, *, use_kernel: bool | None = None,
              interpret: bool | None = None) -> np.ndarray:
    """(N, K<=2) key columns (values < 2^31) -> (N,) packed int64 keys."""
    cols = np.asarray(cols)
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             cols.shape[0], hot_path=True)
    if not use_kernel:
        from jax.experimental import enable_x64
        with enable_x64():
            pack, _ = _oracle_fns()
            return np.asarray(pack(cols.astype(np.int64)))
    hi, lo = kernel.pack_keys_pallas(cols.astype(np.int32),
                                     interpret=interpret)
    return _join_words(np.asarray(hi), np.asarray(lo))


def probe_sorted(build_sorted: np.ndarray, probe: np.ndarray, *,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """searchsorted left/right of nonnegative int64 ``probe`` keys over the
    ascending ``build_sorted`` keys; returns ``(lo, hi)`` index arrays."""
    build_sorted = np.asarray(build_sorted, np.int64)
    probe = np.asarray(probe, np.int64)
    size = max(build_sorted.shape[0], probe.shape[0])
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret, size,
                                             hot_path=True)
    if (use_kernel and auto
            and build_sorted.shape[0] * probe.shape[0] > _probe_work_cap()):
        use_kernel = False             # quadratic compare budget exceeded
    if not use_kernel:
        from jax.experimental import enable_x64
        with enable_x64():
            _, search = _oracle_fns()
            lo, hi = search(build_sorted, probe)
            return np.asarray(lo), np.asarray(hi)
    bh, bl = _split_words(build_sorted)
    ph, pl_ = _split_words(probe)
    lo, hi = kernel.probe_sorted_pallas(bh, bl, ph, pl_, interpret=interpret)
    return np.asarray(lo, np.int64), np.asarray(hi, np.int64)


def gather_rows(values: np.ndarray, idx: np.ndarray, *, fill: int = 0,
                use_kernel: bool | None = None,
                interpret: bool | None = None,
                assume_inbounds: bool = False,
                bounded_by_len: bool = False) -> np.ndarray:
    """Masked gather ``values[idx]`` (out-of-range -> ``fill``); the host
    gather is its own oracle — a one-op kernel needs no jnp round trip.

    ``assume_inbounds=True`` lets a caller that guarantees valid indices
    (the executor's expansion positions are constructed in range) skip the
    host tier's masking passes; the kernel tier masks either way (the mask
    is inert for valid indices).

    ``bounded_by_len=True`` declares every value nonnegative and bounded by
    ``len(values)`` — true of permutation tables like a build-side sort
    order — so the int32-envelope check on the kernel tier is the O(1)
    proof ``len(values) <= 2^31`` instead of a min/max scan over the whole
    int64 table (two host passes per join on the TPU path)."""
    values = np.asarray(values)
    idx = np.asarray(idx)
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             idx.shape[0], hot_path=True)
    if use_kernel and auto and values.shape[0] > _gather_resident_rows():
        use_kernel = False             # table would not fit one VMEM panel
    if use_kernel and values.size:
        # the kernel carries values as int32 words; out-of-envelope tables
        # would silently truncate, so auto falls back and forced raises.
        # A length-bounded table (e.g. a sort permutation: values are
        # indices into itself) is proven in-envelope in O(1).
        in_envelope = (values.shape[0] <= (1 << 31) if bounded_by_len
                       else (values.min() >= -(1 << 31)
                             and values.max() < 1 << 31))
        if not in_envelope:
            if not auto:
                raise ValueError("gather kernel requires int32-range values")
            use_kernel = False
    if not use_kernel:
        if assume_inbounds:
            return values[idx]
        valid = (idx >= 0) & (idx < len(values))
        out = np.full(idx.shape, fill,
                      values.dtype if len(values) else np.int32)
        if len(values):
            out[valid] = values[np.clip(idx, 0, len(values) - 1)][valid]
        return out
    got = kernel.gather_rows_pallas(values.astype(np.int32),
                                    idx.astype(np.int32), fill=fill,
                                    interpret=interpret)
    return np.asarray(got).astype(values.dtype if values.size else np.int32)


# --------------------------------------------------------------------------- #
# the executor's composite probe
# --------------------------------------------------------------------------- #

def hash_probe_numpy(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray],
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The host probe: the same base-2^31 pack + stable sort + searchsorted
    with no device round trip. This is what auto dispatch serves on CPU —
    per-join jnp dispatches lose to host numpy there (measured ~1.8x on the
    LUBM(3) window), so the device tiers engage only on TPU or when
    forced."""
    lk = _pack_np(lcs)
    rk = _pack_np(rcs)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    return order, lo, hi - lo


def _pack_np(cols: Sequence[np.ndarray]) -> np.ndarray:
    key = cols[0]
    for c in cols[1:]:
        key = key * np.int64(1 << 31) + c
    return key


def hash_probe_oracle(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray],
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The jitted-jnp probe (the pre-Pallas ``JaxExecutor`` hot path):
    pow2-padded pack + searchsorted under ``enable_x64``, host build sort.
    Padding keys are int64-max so they never binary-search below a real
    key; results are clamped back to the true build size."""
    from jax.experimental import enable_x64

    nl, nr = len(lcs[0]), len(rcs[0])
    with enable_x64():
        pack, search = _oracle_fns()
        lk = np.asarray(pack(_pad_pow2(np.stack(lcs, axis=1))))[:nl]
        rk = np.asarray(pack(_pad_pow2(np.stack(rcs, axis=1))))[:nr]
        order = np.argsort(rk, kind="stable")
        lo_j, hi_j = search(_pad_pow2(rk[order], fill=_INT64_MAX),
                            _pad_pow2(lk, fill=_INT64_MAX))
    lo = np.minimum(np.asarray(lo_j)[:nl], nr)
    hi = np.minimum(np.asarray(hi_j)[:nl], nr)
    return order, lo, hi - lo


def hash_probe(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray], *,
               use_kernel: bool | None = None,
               interpret: bool | None = None,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Full hash-probe of probe-side key columns ``lcs`` against build-side
    ``rcs`` (each a list of <= 2 int columns with values < 2^31). Returns
    ``(order, lo, counts)``: the build side's stable sort permutation and,
    per probe row, the start/length of its match run in that order."""
    assert len(lcs) <= 2 and len(rcs) <= 2, "reduce key columns first"
    nl, nr = len(lcs[0]), len(rcs[0])
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             max(nl, nr), hot_path=True)
    if use_kernel and auto and nl * nr > _probe_work_cap():
        use_kernel = False             # quadratic compare budget exceeded
    if not use_kernel:
        # three tiers: auto on CPU stays on the host (no device round trip);
        # the jnp oracle runs when explicitly forced (use_kernel=False) or
        # when a TPU is present but the problem is under the size floor
        if auto and not dispatch.on_tpu():
            return hash_probe_numpy(lcs, rcs)
        return hash_probe_oracle(lcs, rcs)
    lh, ll = kernel.pack_keys_pallas(
        np.stack(lcs, axis=1).astype(np.int32), interpret=interpret)
    rh, rl = kernel.pack_keys_pallas(
        np.stack(rcs, axis=1).astype(np.int32), interpret=interpret)
    lh, ll = np.asarray(lh), np.asarray(ll)
    rh, rl = np.asarray(rh), np.asarray(rl)
    # stable build-side sort on the host, by the recombined int64 key
    order = np.argsort(_join_words(rh, rl), kind="stable")
    lo, hi = kernel.probe_sorted_pallas(rh[order], rl[order], lh, ll,
                                        interpret=interpret)
    lo = np.asarray(lo, np.int64)
    return order, lo, np.asarray(hi, np.int64) - lo
