"""Public ops: hash-join pack/probe/gather with kernel/oracle dispatch.

Three layers, same math (see ``docs/kernels.md`` for the idiom):

* :mod:`repro.kernels.join.kernel` — Pallas kernels, int64 keys split into
  32-bit word pairs (TPU has no int64). Compiled on TPU, ``interpret=True``
  on CPU.
* :mod:`repro.kernels.join.ref` — the jnp oracle (int64 under
  ``enable_x64``). Jitted with power-of-two shape buckets, this *is* the
  ``JaxExecutor``'s original jitted probe path — the baseline the Pallas
  kernels are benchmarked against.
* this module — the dispatch seam the executor calls. The join sits on the
  per-query serving hot path, so the auto policy is ``hot_path=True``
  (``repro.kernels.dispatch``) plus two scaling guards (the quadratic
  probe-work cap and the gather VMEM-residency cap below): compiled
  kernels on TPU for large-enough in-envelope problems, the jitted oracle
  for the rest of the device cases, and plain host numpy
  (:func:`hash_probe_numpy`) when there is no device at all;
  ``use_kernel=True`` forces the kernel (interpret mode on CPU — how the
  equivalence tests pin bit-equality), ``use_kernel=False`` forces the
  oracle.

:func:`hash_probe` is the staged composite: pack both sides, stable-sort
the build side **on the host** (XLA's CPU sort is comparator-based and
loses badly to ``np.argsort``; on TPU the sort is the one stage left on
the host by design), probe every packed key. Returns ``(order, lo,
counts)`` exactly like the numpy reference's searchsorted probe, so the
executors' ragged pair expansion is backend-agnostic.

:func:`expand_pairs` is the segmented ragged expansion that used to live
as host ``np.repeat``/``np.cumsum`` arithmetic inside the executor: ``(lo,
counts)`` match runs -> flat ``(li, pos)`` pair indices, same three tiers.

:func:`hash_join_pipeline` fuses the whole probe→expand→gather chain:
packed keys, ``lo/counts``, expanded positions, and the gathered
permutation rows stay device-resident between stages — the host sees the
build sort key mid-pipeline (the sort stays on the host by design), the
expansion-total scalar (a data-dependent output size must be known to
allocate), and ONE final ``(li, ri)`` materialization, instead of a full
host round trip after every stage. :func:`track_transfers` counts the
boundary crossings so benchmarks can report them per path.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.kernels import dispatch
from repro.kernels.join import kernel, ref

_INT64_MAX = np.iinfo(np.int64).max
_oracle_cache: dict = {}

# Auto-dispatch scalability guards (forced use_kernel=True bypasses all —
# that's how tests pin the kernels at any shape). Resolved per call through
# dispatch.envelope (env var > recorded autotune profile > default), so env
# overrides and loaded profiles work after import:
#
# * the count-probe kernel does O(nl * nr) word-pair compares — a win over
#   binary search only while the compare budget is small; past the cap the
#   log-depth oracle is asymptotically faster even with its device hops.
# * the gather kernel keeps the whole value table resident in one VMEM
#   panel; past ~2M int32 rows (8 MB of the ~16 MB VMEM) it cannot tile.
# * the expand kernel broadcast-tests O(total * n_segments) ownership
#   pairs (the expansion-total threshold): past the cap the log-depth
#   searchsorted oracle wins, exactly like the probe.

def _probe_work_cap() -> int:
    return dispatch.envelope("REPRO_JOIN_PROBE_WORK_CAP", 1 << 32)


def _gather_resident_rows() -> int:
    return dispatch.envelope("REPRO_JOIN_GATHER_RESIDENT_ROWS", 1 << 21)


def _expand_work_cap() -> int:
    return dispatch.envelope("REPRO_JOIN_EXPAND_WORK_CAP", 1 << 32)


class ExpansionCapExceeded(RuntimeError):
    """A ragged pair expansion would materialize more rows than the
    caller's ``max_total`` cap (the executor maps this onto its
    ``JoinCapExceeded``, mirroring the cartesian-product cap)."""


# --------------------------------------------------------------------------- #
# host-transfer accounting
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class TransferStats:
    """Host<->device array crossings noted by the ops in this module while a
    :func:`track_transfers` scope is active. Counts are structural (one per
    array materialized across the boundary, scalars included) — the honest,
    platform-independent currency of the fused pipeline's claim, measurable
    even on a CPU container where 'device' is the XLA host backend."""
    h2d: int = 0
    d2h: int = 0

    @property
    def total(self) -> int:
        return self.h2d + self.d2h


_transfer_scopes: List[TransferStats] = []


@contextlib.contextmanager
def track_transfers():
    """Count host<->device crossings performed by ops in this scope."""
    ts = TransferStats()
    _transfer_scopes.append(ts)
    try:
        yield ts
    finally:
        _transfer_scopes.remove(ts)


def _note(h2d: int = 0, d2h: int = 0) -> None:
    for ts in _transfer_scopes:
        ts.h2d += h2d
        ts.d2h += d2h


def _pad_pow2(a: np.ndarray, fill=0, min_size: int = 16) -> np.ndarray:
    """Pad axis 0 to the next power of two (stable jit shape buckets)."""
    n = a.shape[0]
    m = max(min_size, 1 << max(n - 1, 0).bit_length())
    if m == n:
        return a
    out = np.full((m,) + a.shape[1:], fill, a.dtype)
    out[:n] = a
    return out


def _pow2_len(n: int, min_size: int = 16) -> int:
    return max(min_size, 1 << max(n - 1, 0).bit_length())


def _oracle_fns():
    """Jitted oracle pack/search, shared by every join of every batch."""
    import jax

    if not _oracle_cache:
        _oracle_cache.update(pack=jax.jit(ref.pack_keys),
                             search=jax.jit(ref.probe_sorted))
    return _oracle_cache["pack"], _oracle_cache["search"]


_pipe_cache: dict = {}


def _pipe_fns():
    """Jitted device helpers for the fused pipeline (and the oracle tiers
    of the granular expand op) — tiny glue ops that keep intermediates on
    the device between kernel stages instead of punting to host numpy."""
    import functools

    import jax
    import jax.numpy as jnp

    if not _pipe_cache:
        @functools.partial(jax.jit, static_argnames=("n", "fill"))
        def pad_to(a, *, n, fill):
            if n <= a.shape[0]:
                return a
            return jnp.concatenate(
                [a, jnp.full((n - a.shape[0],), fill, a.dtype)])

        _pipe_cache.update(
            take=jax.jit(lambda a, i: a[i]),
            sub=jax.jit(lambda a, b: a - b),
            clamp=jax.jit(lambda x, n: jnp.minimum(x, n)),
            total64=jax.jit(lambda c: jnp.sum(c.astype(jnp.int64))),
            starts=jax.jit(lambda c: jnp.cumsum(c) - c),
            join_words=jax.jit(lambda hi, lo: (hi.astype(jnp.int64) << 32)
                               | lo.astype(jnp.uint32).astype(jnp.int64)),
            expand=jax.jit(ref.expand_pairs, static_argnames=("total",)),
            pad_to=pad_to,
        )
    return _pipe_cache


def _split_words(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Nonnegative int64 keys < 2^62 -> (hi int32, lo uint32) word pair.

    The bound is the K<=2 base-2^31 packing envelope and what keeps the
    probe kernel's +inf padding sentinel (hi = 2^31-1) strictly above every
    real key; a key at or past 2^62 would compare equal to padding and
    inflate the hi counts past the build length."""
    if keys.size and (keys >> 62).any():
        raise ValueError("word-pair kernels require nonnegative packed keys "
                         "< 2^62 (the K<=2 base-2^31 packing envelope)")
    return ((keys >> 32).astype(np.int32),
            (keys & np.int64(0xFFFFFFFF)).astype(np.uint32))


def _join_words(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    return (hi.astype(np.int64) << 32) | lo.astype(np.int64)


# --------------------------------------------------------------------------- #
# granular ops (bench / tests / docs surface)
# --------------------------------------------------------------------------- #

def pack_keys(cols: np.ndarray, *, use_kernel: bool | None = None,
              interpret: bool | None = None) -> np.ndarray:
    """(N, K<=2) key columns (values < 2^31) -> (N,) packed int64 keys."""
    cols = np.asarray(cols)
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             cols.shape[0], hot_path=True)
    if not use_kernel:
        dispatch.note_tier("join.pack_keys", "oracle",
                           "auto" if auto else "forced_off")
        from jax.experimental import enable_x64
        with enable_x64():
            pack, _ = _oracle_fns()
            _note(h2d=1, d2h=1)
            return np.asarray(pack(cols.astype(np.int64)))
    dispatch.note_tier("join.pack_keys", "pallas",
                       "auto" if auto else "forced")
    hi, lo = kernel.pack_keys_pallas(cols.astype(np.int32),
                                     interpret=interpret)
    _note(h2d=1, d2h=2)
    return _join_words(np.asarray(hi), np.asarray(lo))


def probe_sorted(build_sorted: np.ndarray, probe: np.ndarray, *,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """searchsorted left/right of nonnegative int64 ``probe`` keys over the
    ascending ``build_sorted`` keys; returns ``(lo, hi)`` index arrays."""
    build_sorted = np.asarray(build_sorted, np.int64)
    probe = np.asarray(probe, np.int64)
    size = max(build_sorted.shape[0], probe.shape[0])
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret, size,
                                             hot_path=True)
    capped = (use_kernel and auto
              and build_sorted.shape[0] * probe.shape[0] > _probe_work_cap())
    if capped:
        use_kernel = False             # quadratic compare budget exceeded
    if not use_kernel:
        dispatch.note_tier("join.probe_sorted", "oracle",
                           "work_cap" if capped
                           else "auto" if auto else "forced_off")
        from jax.experimental import enable_x64
        with enable_x64():
            _, search = _oracle_fns()
            _note(h2d=2, d2h=2)
            lo, hi = search(build_sorted, probe)
            return np.asarray(lo), np.asarray(hi)
    dispatch.note_tier("join.probe_sorted", "pallas",
                       "auto" if auto else "forced")
    bh, bl = _split_words(build_sorted)
    ph, pl_ = _split_words(probe)
    lo, hi = kernel.probe_sorted_pallas(bh, bl, ph, pl_, interpret=interpret)
    _note(h2d=4, d2h=2)
    return np.asarray(lo, np.int64), np.asarray(hi, np.int64)


def gather_rows(values: np.ndarray, idx: np.ndarray, *, fill: int = 0,
                use_kernel: bool | None = None,
                interpret: bool | None = None,
                assume_inbounds: bool = False,
                bounded_by_len: bool = False) -> np.ndarray:
    """Masked gather ``values[idx]`` (out-of-range -> ``fill``); the host
    gather is its own oracle — a one-op kernel needs no jnp round trip.

    ``assume_inbounds=True`` lets a caller that guarantees valid indices
    (the executor's expansion positions are constructed in range) skip the
    host tier's masking passes; the kernel tier masks either way (the mask
    is inert for valid indices).

    ``bounded_by_len=True`` declares every value nonnegative and bounded by
    ``len(values)`` — true of permutation tables like a build-side sort
    order — so the int32-envelope check on the kernel tier is the O(1)
    proof ``len(values) <= 2^31`` instead of a min/max scan over the whole
    int64 table (two host passes per join on the TPU path)."""
    values = np.asarray(values)
    idx = np.asarray(idx)
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             idx.shape[0], hot_path=True)
    fallback_reason = "auto" if auto else "forced_off"
    if use_kernel and auto and values.shape[0] > _gather_resident_rows():
        use_kernel = False             # table would not fit one VMEM panel
        fallback_reason = "vmem_residency"
    if use_kernel and values.size:
        # the kernel carries values as int32 words; out-of-envelope tables
        # would silently truncate, so auto falls back and forced raises.
        # A length-bounded table (e.g. a sort permutation: values are
        # indices into itself) is proven in-envelope in O(1).
        in_envelope = (values.shape[0] <= (1 << 31) if bounded_by_len
                       else (values.min() >= -(1 << 31)
                             and values.max() < 1 << 31))
        if not in_envelope:
            if not auto:
                raise ValueError("gather kernel requires int32-range values")
            use_kernel = False
            fallback_reason = "int32_envelope"
    if not use_kernel:
        dispatch.note_tier("join.gather_rows", "host", fallback_reason)
        if assume_inbounds:
            return values[idx]
        valid = (idx >= 0) & (idx < len(values))
        out = np.full(idx.shape, fill,
                      values.dtype if len(values) else np.int32)
        if len(values):
            out[valid] = values[np.clip(idx, 0, len(values) - 1)][valid]
        return out
    dispatch.note_tier("join.gather_rows", "pallas",
                       "auto" if auto else "forced")
    got = kernel.gather_rows_pallas(values.astype(np.int32),
                                    idx.astype(np.int32), fill=fill,
                                    interpret=interpret)
    _note(h2d=2, d2h=1)
    return np.asarray(got).astype(values.dtype if values.size else np.int32)


# --------------------------------------------------------------------------- #
# the executor's composite probe
# --------------------------------------------------------------------------- #

def hash_probe_numpy(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray],
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The host probe: the same base-2^31 pack + stable sort + searchsorted
    with no device round trip. This is what auto dispatch serves on CPU —
    per-join jnp dispatches lose to host numpy there (measured ~1.8x on the
    LUBM(3) window), so the device tiers engage only on TPU or when
    forced."""
    lk = _pack_np(lcs)
    rk = _pack_np(rcs)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    return order, lo, hi - lo


def _pack_np(cols: Sequence[np.ndarray]) -> np.ndarray:
    key = cols[0]
    for c in cols[1:]:
        key = key * np.int64(1 << 31) + c
    return key


def hash_probe_oracle(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray],
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The jitted-jnp probe (the pre-Pallas ``JaxExecutor`` hot path):
    pow2-padded pack + searchsorted under ``enable_x64``, host build sort.
    Padding keys are int64-max so they never binary-search below a real
    key; results are clamped back to the true build size."""
    from jax.experimental import enable_x64

    nl, nr = len(lcs[0]), len(rcs[0])
    with enable_x64():
        pack, search = _oracle_fns()
        _note(h2d=2, d2h=2)
        lk = np.asarray(pack(_pad_pow2(np.stack(lcs, axis=1))))[:nl]
        rk = np.asarray(pack(_pad_pow2(np.stack(rcs, axis=1))))[:nr]
        order = np.argsort(rk, kind="stable")
        _note(h2d=2, d2h=2)
        lo_j, hi_j = search(_pad_pow2(rk[order], fill=_INT64_MAX),
                            _pad_pow2(lk, fill=_INT64_MAX))
    lo = np.minimum(np.asarray(lo_j)[:nl], nr)
    hi = np.minimum(np.asarray(hi_j)[:nl], nr)
    return order, lo, hi - lo


def hash_probe(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray], *,
               use_kernel: bool | None = None,
               interpret: bool | None = None,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Full hash-probe of probe-side key columns ``lcs`` against build-side
    ``rcs`` (each a list of <= 2 int columns with values < 2^31). Returns
    ``(order, lo, counts)``: the build side's stable sort permutation and,
    per probe row, the start/length of its match run in that order."""
    assert len(lcs) <= 2 and len(rcs) <= 2, "reduce key columns first"
    nl, nr = len(lcs[0]), len(rcs[0])
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             max(nl, nr), hot_path=True)
    capped = use_kernel and auto and nl * nr > _probe_work_cap()
    if capped:
        use_kernel = False             # quadratic compare budget exceeded
    if not use_kernel:
        # three tiers: auto on CPU stays on the host (no device round trip);
        # the jnp oracle runs when explicitly forced (use_kernel=False) or
        # when a TPU is present but the problem is under the size floor
        if auto and not capped and not dispatch.on_tpu():
            dispatch.note_tier("join.hash_probe", "host", "cpu_auto")
            return hash_probe_numpy(lcs, rcs)
        dispatch.note_tier("join.hash_probe", "oracle",
                           "work_cap" if capped
                           else "below_floor" if auto else "forced_off")
        return hash_probe_oracle(lcs, rcs)
    dispatch.note_tier("join.hash_probe", "pallas",
                       "auto" if auto else "forced")
    lh, ll = kernel.pack_keys_pallas(
        np.stack(lcs, axis=1).astype(np.int32), interpret=interpret)
    rh, rl = kernel.pack_keys_pallas(
        np.stack(rcs, axis=1).astype(np.int32), interpret=interpret)
    _note(h2d=2, d2h=4)
    lh, ll = np.asarray(lh), np.asarray(ll)
    rh, rl = np.asarray(rh), np.asarray(rl)
    # stable build-side sort on the host, by the recombined int64 key
    order = np.argsort(_join_words(rh, rl), kind="stable")
    lo, hi = kernel.probe_sorted_pallas(rh[order], rl[order], lh, ll,
                                        interpret=interpret)
    _note(h2d=4, d2h=2)
    lo = np.asarray(lo, np.int64)
    return order, lo, np.asarray(hi, np.int64) - lo


# --------------------------------------------------------------------------- #
# segmented ragged expansion
# --------------------------------------------------------------------------- #

def expand_pairs_numpy(lo: np.ndarray, counts: np.ndarray,
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """The host expansion — the executor's original addressing arithmetic:
    ``li`` repeats each segment id ``counts[i]`` times; ``pos`` walks
    ``lo[i], lo[i]+1, ...`` within each run."""
    lo = np.asarray(lo, np.int64)
    counts = np.asarray(counts, np.int64)
    n = counts.shape[0]
    total = int(counts.sum())
    li = np.repeat(np.arange(n, dtype=np.int64), counts)
    starts = np.cumsum(counts) - counts
    offs = np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
    pos = np.repeat(lo, counts) + offs
    return li, pos


def _expand_pairs_oracle(lo: np.ndarray, counts: np.ndarray, total: int,
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """The jitted searchsorted expansion, pow2-padded for stable jit
    buckets. Zero-fill padding segments own no output index, and padded
    output indices past ``total`` resolve to the last padding segment —
    both sliced off on the way out."""
    from jax.experimental import enable_x64

    n = counts.shape[0]
    with enable_x64():
        fns = _pipe_fns()
        _note(h2d=2, d2h=2)
        li, pos = fns["expand"](_pad_pow2(lo), _pad_pow2(counts),
                                total=_pow2_len(total))
        return (np.asarray(li)[:total].astype(np.int64),
                np.asarray(pos)[:total].astype(np.int64))


def expand_pairs(lo: np.ndarray, counts: np.ndarray, *,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Segmented ragged expansion of per-probe-row ``(lo, counts)`` match
    runs into flat ``(li, pos)`` pair indices (``li[j]`` = probe row owning
    output ``j``; ``pos[j]`` = its match's position in the build sort
    order). Same three tiers as the probe; the kernel's ownership test is
    O(total * n_segments), so auto dispatch falls back to the log-depth
    searchsorted oracle past the expand work cap."""
    lo = np.asarray(lo, np.int64)
    counts = np.asarray(counts, np.int64)
    n = counts.shape[0]
    total = int(counts.sum())
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             max(total, n), hot_path=True)
    reason = ""
    if use_kernel and auto and total * max(n, 1) > _expand_work_cap():
        use_kernel = False             # ownership-test budget exceeded
        reason = "work_cap"
    if use_kernel:
        # the kernel carries runs as int32; out-of-envelope runs would
        # silently truncate, so auto falls back and forced raises.
        in_envelope = (total < 1 << 31 and n < 1 << 31
                       and (n == 0 or (int((lo + counts).max()) <= 1 << 31
                                       and int(lo.min()) >= 0)))
        if not in_envelope:
            if not auto:
                raise ValueError("expand kernel requires int32-range runs")
            use_kernel = False
            reason = "int32_envelope"
    if not use_kernel:
        if auto and not reason and not dispatch.on_tpu():
            dispatch.note_tier("join.expand_pairs", "host", "cpu_auto")
            return expand_pairs_numpy(lo, counts)
        dispatch.note_tier("join.expand_pairs", "oracle",
                           reason or ("below_floor" if auto
                                      else "forced_off"))
        return _expand_pairs_oracle(lo, counts, total)
    dispatch.note_tier("join.expand_pairs", "pallas",
                       "auto" if auto else "forced")
    if total == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    starts = np.cumsum(counts) - counts
    _note(h2d=3, d2h=2)
    li, pos = kernel.expand_pairs_pallas(
        _pad_pow2(starts.astype(np.int32)), _pad_pow2(counts.astype(np.int32)),
        _pad_pow2(lo.astype(np.int32)), total=_pow2_len(total),
        interpret=interpret)
    return (np.asarray(li)[:total].astype(np.int64),
            np.asarray(pos)[:total].astype(np.int64))


def expand_segment_ids(counts: np.ndarray, *, use_kernel: bool | None = None,
                       interpret: bool | None = None) -> np.ndarray:
    """``np.repeat(np.arange(len(counts)), counts)`` through the same
    dispatch seam — the segment-id half of the expansion, used by the
    executor's federation bincount build."""
    counts = np.asarray(counts, np.int64)
    li, _ = expand_pairs(np.zeros_like(counts), counts,
                         use_kernel=use_kernel, interpret=interpret)
    return li


# --------------------------------------------------------------------------- #
# the fused probe -> expand -> gather pipeline
# --------------------------------------------------------------------------- #

def _check_total(total: int, max_total: "int | None") -> None:
    if max_total is not None and total > max_total:
        raise ExpansionCapExceeded(
            f"hash-join ragged expansion would materialize {total} rows, "
            f"above the {max_total}-row cap")


_EMPTY_PAIR = (np.empty(0, np.int64), np.empty(0, np.int64), 0)


def _pipeline_numpy(lcs, rcs, max_total):
    """Pure-host pipeline: zero boundary crossings, what auto serves on
    CPU. The cap check sits between probe and expansion, exactly where the
    device tiers check it — nothing is materialized past the cap."""
    order, lo, counts = hash_probe_numpy(lcs, rcs)
    total = int(counts.sum())
    _check_total(total, max_total)
    if total == 0:
        return _EMPTY_PAIR
    li, pos = expand_pairs_numpy(lo, counts)
    return li, order[pos], total


def _pipeline_oracle(lcs, rcs, max_total):
    """Device-resident jitted-jnp pipeline. Boundary crossings: two key
    uploads, the build sort key down + the order back up (the sort stays
    on the host by design), the expansion-total scalar down, and the final
    ``(li, ri)`` pair down — 7, vs the staged oracle composite's 12 plus
    its full intermediate arrays."""
    from jax.experimental import enable_x64

    import jax.numpy as jnp

    nl, nr = len(lcs[0]), len(rcs[0])
    with enable_x64():
        pack, search = _oracle_fns()
        fns = _pipe_fns()
        _note(h2d=2)
        lk_d = pack(_pad_pow2(np.stack(lcs, axis=1)))          # (nl pow2,)
        rk_d = pack(_pad_pow2(np.stack(rcs, axis=1)))          # (nr pow2,)
        _note(d2h=1)
        rk = np.asarray(rk_d)[:nr]
        order = np.argsort(rk, kind="stable")
        _note(h2d=1)
        order_d = jnp.asarray(order)
        build_d = fns["pad_to"](fns["take"](rk_d[:nr], order_d),
                                n=_pow2_len(nr), fill=int(_INT64_MAX))
        lo_j, hi_j = search(build_d, lk_d)
        lo_d = fns["clamp"](lo_j[:nl], nr)
        counts_d = fns["sub"](fns["clamp"](hi_j[:nl], nr), lo_d)
        _note(d2h=1)
        total = int(fns["total64"](counts_d))
        _check_total(total, max_total)
        if total == 0:
            return _EMPTY_PAIR
        mp = _pow2_len(nl)
        li_d, pos_d = fns["expand"](fns["pad_to"](lo_d, n=mp, fill=0),
                                    fns["pad_to"](counts_d, n=mp, fill=0),
                                    total=_pow2_len(total))
        ri_d = fns["take"](order_d, pos_d[:total])
        _note(d2h=2)
        return (np.asarray(li_d[:total]).astype(np.int64),
                np.asarray(ri_d).astype(np.int64), total)


def _pipeline_pallas(lcs, rcs, use_kernel, interpret, max_total):
    """Kernel pipeline: pack/probe/expand/gather as Pallas kernels with
    device-resident word-pair intermediates; per-stage scaling-envelope
    fallbacks swap in the jitted jnp form of that one stage *on device*
    instead of dropping the whole join to the host. Boundary crossings:
    two key-column uploads, the recombined sort key down + the order back
    up, the total scalar down, the final pair down — 7, vs the staged
    all-kernel composite's 20."""
    from jax.experimental import enable_x64

    import jax.numpy as jnp

    nl, nr = len(lcs[0]), len(rcs[0])
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             max(nl, nr), hot_path=True)
    if not use_kernel:
        if auto and not dispatch.on_tpu():
            dispatch.note_tier("join.pipeline", "host", "cpu_auto")
            return _pipeline_numpy(lcs, rcs, max_total)
        dispatch.note_tier("join.pipeline", "oracle",
                           "below_floor" if auto else "forced_off")
        return _pipeline_oracle(lcs, rcs, max_total)
    dispatch.note_tier("join.pipeline", "pallas",
                       "auto" if auto else "forced")
    fns = _pipe_fns()
    _note(h2d=2)
    lh, ll = kernel.pack_keys_pallas(
        np.stack(lcs, axis=1).astype(np.int32), interpret=interpret)
    rh, rl = kernel.pack_keys_pallas(
        np.stack(rcs, axis=1).astype(np.int32), interpret=interpret)
    # build-side sort on the host by design: the recombined int64 key is
    # the one mid-pipeline materialization, the order the one extra upload
    with enable_x64():
        rk_d = fns["join_words"](rh, rl)
    _note(d2h=1)
    order = np.argsort(np.asarray(rk_d), kind="stable")
    _note(h2d=1)
    order_d = jnp.asarray(order.astype(np.int32))
    rh_s = fns["take"](rh, order_d)
    rl_s = fns["take"](rl, order_d)
    if auto and nl * nr > _probe_work_cap():
        # compare budget exceeded: this stage runs as the device oracle
        dispatch.note_tier("join.pipeline.probe", "oracle", "work_cap")
        with enable_x64():
            _, search = _oracle_fns()
            lo_j, hi_j = search(rk_d[order_d],
                                fns["join_words"](lh, ll))
        lo_d = lo_j.astype(jnp.int32)
        counts_d = fns["sub"](hi_j, lo_j).astype(jnp.int32)
    else:
        lo_d, hi_d = kernel.probe_sorted_pallas(rh_s, rl_s, lh, ll,
                                                interpret=interpret)
        counts_d = fns["sub"](hi_d, lo_d)
    with enable_x64():
        _note(d2h=1)
        total = int(fns["total64"](counts_d))
    _check_total(total, max_total)
    if total == 0:
        return _EMPTY_PAIR
    if total >= 1 << 31 or nr >= 1 << 31:
        # past the int32 envelope no device stage can carry the expansion;
        # finish on the host (auto would normally cap out long before this)
        dispatch.note_tier("join.pipeline.expand", "host", "int32_envelope")
        lo_h = np.asarray(lo_d).astype(np.int64)
        ct_h = np.asarray(counts_d).astype(np.int64)
        li, pos = expand_pairs_numpy(lo_h, ct_h)
        return li, order[pos].astype(np.int64), total
    tp = _pow2_len(total)
    if auto and total * nl > _expand_work_cap():
        # ownership-test budget exceeded: searchsorted oracle, on device
        dispatch.note_tier("join.pipeline.expand", "oracle", "work_cap")
        mp = _pow2_len(nl)
        li_d, pos_d = fns["expand"](fns["pad_to"](lo_d, n=mp, fill=0),
                                    fns["pad_to"](counts_d, n=mp, fill=0),
                                    total=tp)
        li_d, pos_d = li_d[:total], pos_d[:total]
    else:
        starts_d = fns["starts"](counts_d)
        li_d, pos_d = kernel.expand_pairs_pallas(starts_d, counts_d, lo_d,
                                                 total=tp,
                                                 interpret=interpret)
        li_d, pos_d = li_d[:total], pos_d[:total]
    if auto and nr > _gather_resident_rows():
        dispatch.note_tier("join.pipeline.gather", "oracle",
                           "vmem_residency")
        ri_d = fns["take"](order_d, pos_d)     # table too big for one panel
    else:
        ri_d = kernel.gather_rows_pallas(order_d, pos_d, interpret=interpret)
    _note(d2h=2)
    return (np.asarray(li_d).astype(np.int64),
            np.asarray(ri_d).astype(np.int64), total)


def hash_join_pipeline(lcs: Sequence[np.ndarray], rcs: Sequence[np.ndarray],
                       *, mode: str = "auto",
                       use_kernel: bool | None = None,
                       interpret: bool | None = None,
                       max_total: "int | None" = None,
                       ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Fused probe→expand→gather: key columns in, final ``(li, ri, total)``
    pair indices out (``li`` probe-side row ids, ``ri`` build-side row ids,
    both int64). Intermediates stay device-resident between stages on the
    device tiers; ``max_total`` caps the expansion *before* it is
    materialized (:class:`ExpansionCapExceeded`).

    ``mode`` picks the tier: ``"numpy"`` (pure host), ``"oracle"``
    (device-resident jitted jnp), ``"pallas"`` (kernels; per-stage envelope
    fallbacks stay on device), or ``"auto"`` (pallas on TPU, numpy on CPU —
    the same policy the granular ops resolve per stage)."""
    if mode not in ("auto", "numpy", "oracle", "pallas"):
        raise ValueError(f"unknown pipeline mode: {mode!r}")
    assert len(lcs) <= 2 and len(rcs) <= 2, "reduce key columns first"
    nl, nr = len(lcs[0]), len(rcs[0])
    if nl == 0 or nr == 0:
        return _EMPTY_PAIR
    auto_mode = mode == "auto"
    if mode == "auto":
        mode = "pallas" if dispatch.on_tpu() else "numpy"
    if mode == "numpy":
        dispatch.note_tier("join.pipeline", "host",
                           "cpu_auto" if auto_mode else "forced")
        return _pipeline_numpy(lcs, rcs, max_total)
    if mode == "oracle":
        dispatch.note_tier("join.pipeline", "oracle", "forced")
        return _pipeline_oracle(lcs, rcs, max_total)
    return _pipeline_pallas(lcs, rcs, use_kernel, interpret, max_total)
