"""Pallas TPU kernels: hash-join key packing, sorted probe, segmented
ragged expansion, masked gather.

The executor's hash join has four vectorizable stages:

1. **pack** — reduce the (N, K<=2) shared-variable key columns of each side
   to one 62-bit key per row (base-2^31 positional packing; dictionary ids
   are < 2^31).
2. **probe** — for every probe-side key, the ``[lo, hi)`` range of equal
   keys in the sorted build side (``searchsorted`` left/right).
3. **expand** — turn the per-probe-row ``(lo, counts)`` match runs into
   flat ``(li, pos)`` pair-index arrays (the data-dependent ragged
   expansion, formerly host ``np.repeat``/``np.cumsum`` arithmetic). Match
   runs partition the output index space: output ``j`` belongs to exactly
   the segment ``i`` with ``starts[i] <= j < starts[i] + counts[i]``
   (``starts`` = exclusive cumsum of ``counts``), so each (BN, BM) grid
   step broadcast-tests a tile of output indices against a tile of
   segments and accumulates the single owner's ``(i, lo[i] + j -
   starts[i])`` via a masked sum — a segmented scan with no dynamic
   gathers on the VPU. Zero-count segments own nothing and drop out for
   free, which also makes the padding inert.
4. **gather** — index the build side's sort permutation with the expanded
   match positions.

TPUs have no int64, so packed keys travel through the kernels as two 32-bit
words: ``hi = key >> 32`` (int32, < 2^30 for K <= 2) and ``lo = key &
0xffffffff`` (uint32). Lexicographic order on ``(hi, lo-as-unsigned)``
equals int64 order on the packed key, which is what makes the probe exact.

The probe kernel is **sort-free on device**: instead of binary search (a
log-depth chain of dynamic gathers — hostile to the VPU), each (BN, BM)
grid step broadcast-compares a probe panel against a build panel and
accumulates ``lo = #build < probe`` / ``hi = #build <= probe`` counts.
On a sorted build side those counts *are* the searchsorted indices. The
build-side sort itself stays on the host (``np.argsort``), exactly like the
executor's jitted-jnp path.

Grids: pack/gather are 1-D over row tiles; probe is (N/BN, M/BM) with the
output accumulated over the build axis (TPU grids iterate sequentially, so
read-modify-write on the j axis is the standard reduction pattern). All
arrays are carried as (1, N) lane-major panels to respect the 128-lane
tiling constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HI_INF = jnp.int32(2**31 - 1)          # > any real hi word (< 2^30)
_LO_INF = jnp.uint32(0xFFFFFFFF)


def _pad_to(x: jnp.ndarray, n: int, fill) -> jnp.ndarray:
    """Pad the last axis to length ``n`` with ``fill``."""
    return jnp.full(x.shape[:-1] + (n,), fill, x.dtype).at[..., :x.shape[-1]] \
        .set(x)


# --------------------------------------------------------------------------- #
# pack
# --------------------------------------------------------------------------- #

def _pack_kernel(cols_ref, hi_ref, lo_ref, *, n_cols: int):
    c0 = cols_ref[0, :].astype(jnp.uint32)            # ids < 2^31
    if n_cols == 1:                                   # key = c0
        hi = jnp.zeros_like(c0, jnp.int32)
        lo = c0
    else:                                             # key = c0 * 2^31 + c1
        c1 = cols_ref[1, :].astype(jnp.uint32)
        hi = (c0 >> 1).astype(jnp.int32)
        lo = ((c0 & jnp.uint32(1)) << 31) | c1
    hi_ref[0, :] = hi
    lo_ref[0, :] = lo


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pack_keys_pallas(cols: jnp.ndarray, *, block_n: int = 256,
                     interpret: bool = False,
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(N, K<=2) int32 key columns -> ``(hi int32, lo uint32)`` word pair
    per row, the split representation of the base-2^31 packed int64 key."""
    n, k = cols.shape
    assert k in (1, 2), f"key columns must be reduced to <= 2, got {k}"
    np_ = max(block_n, (n + block_n - 1) // block_n * block_n)
    cols_t = _pad_to(cols.T.astype(jnp.int32), np_, 0)
    hi, lo = pl.pallas_call(
        functools.partial(_pack_kernel, n_cols=k),
        grid=(np_ // block_n,),
        in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, block_n), lambda i: (0, i)),
                   pl.BlockSpec((1, block_n), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int32),
                   jax.ShapeDtypeStruct((1, np_), jnp.uint32)],
        interpret=interpret,
    )(cols_t)
    return hi[0, :n], lo[0, :n]


# --------------------------------------------------------------------------- #
# probe
# --------------------------------------------------------------------------- #

def _probe_kernel(bh_ref, bl_ref, ph_ref, pl_ref, lo_ref, hi_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    bh = bh_ref[0, :]                                 # (BM,) int32
    bl = bl_ref[0, :]                                 # (BM,) uint32
    ph = ph_ref[0, :]                                 # (BN,) int32
    plo = pl_ref[0, :]                                # (BN,) uint32
    # (BN, BM) broadcast compare, lexicographic on the (hi, lo) word pair
    hi_lt = bh[None, :] < ph[:, None]
    hi_eq = bh[None, :] == ph[:, None]
    lt = hi_lt | (hi_eq & (bl[None, :] < plo[:, None]))
    le = lt | (hi_eq & (bl[None, :] == plo[:, None]))
    lo_ref[0, :] += lt.sum(axis=1).astype(jnp.int32)
    hi_ref[0, :] += le.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_m",
                                             "interpret"))
def probe_sorted_pallas(build_hi: jnp.ndarray, build_lo: jnp.ndarray,
                        probe_hi: jnp.ndarray, probe_lo: jnp.ndarray, *,
                        block_n: int = 256, block_m: int = 512,
                        interpret: bool = False,
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """searchsorted left/right of every probe key over the ascending build
    keys, both sides as (hi, lo) word pairs. Build padding is +inf word
    pairs, which never compare below a real probe key — the counts need no
    post-hoc clamping."""
    m, n = build_hi.shape[0], probe_hi.shape[0]
    mp = max(block_m, (m + block_m - 1) // block_m * block_m)
    np_ = max(block_n, (n + block_n - 1) // block_n * block_n)
    bh = _pad_to(build_hi[None, :], mp, _HI_INF)
    bl = _pad_to(build_lo[None, :], mp, _LO_INF)
    ph = _pad_to(probe_hi[None, :], np_, _HI_INF)
    plo = _pad_to(probe_lo[None, :], np_, _LO_INF)
    lo, hi = pl.pallas_call(
        _probe_kernel,
        grid=(np_ // block_n, mp // block_m),
        in_specs=[pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
                  pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
                  pl.BlockSpec((1, block_n), lambda i, j: (0, i)),
                  pl.BlockSpec((1, block_n), lambda i, j: (0, i))],
        out_specs=[pl.BlockSpec((1, block_n), lambda i, j: (0, i)),
                   pl.BlockSpec((1, block_n), lambda i, j: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int32),
                   jax.ShapeDtypeStruct((1, np_), jnp.int32)],
        interpret=interpret,
    )(bh, bl, ph, plo)
    return lo[0, :n], hi[0, :n]


# --------------------------------------------------------------------------- #
# expand
# --------------------------------------------------------------------------- #

def _expand_kernel(starts_ref, counts_ref, lo_ref, li_ref, pos_ref, *,
                   block_n: int, block_m: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        li_ref[...] = jnp.zeros_like(li_ref)
        pos_ref[...] = jnp.zeros_like(pos_ref)

    starts = starts_ref[0, :]                         # (BM,) int32
    counts = counts_ref[0, :]                         # (BM,) int32
    lo = lo_ref[0, :]                                 # (BM,) int32
    # (BN, BM) global output indices / segment ids for this grid step
    j = (pl.program_id(0) * block_n
         + jax.lax.broadcasted_iota(jnp.int32, (block_n, block_m), 0))
    seg = (pl.program_id(1) * block_m
           + jax.lax.broadcasted_iota(jnp.int32, (block_n, block_m), 1))
    # exactly one segment owns each real output index (runs partition the
    # output space); zero-count segments — including all padding — own none
    owns = (starts[None, :] <= j) & (j < (starts + counts)[None, :])
    li_ref[0, :] += jnp.where(owns, seg, 0).sum(axis=1)
    pos_ref[0, :] += jnp.where(owns, lo[None, :] + j - starts[None, :],
                               0).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("total", "block_n", "block_m",
                                             "interpret"))
def expand_pairs_pallas(starts: jnp.ndarray, counts: jnp.ndarray,
                        lo: jnp.ndarray, *, total: int, block_n: int = 256,
                        block_m: int = 512, interpret: bool = False,
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented ragged expansion: per-segment ``(starts, counts, lo)``
    match runs -> flat ``(li, pos)`` pair indices of length ``total``
    (``total`` = the static padded output size; callers slice to the true
    ``counts.sum()``). ``li[j]`` is the owning segment, ``pos[j] = lo[li[j]]
    + (j - starts[li[j]])`` its position in the build-side sort order.
    Output indices past the last run (padding included) own nothing and
    come back 0 — callers slice them off."""
    m = starts.shape[0]
    mp = max(block_m, (m + block_m - 1) // block_m * block_m)
    np_ = max(block_n, (total + block_n - 1) // block_n * block_n)
    st = _pad_to(starts.astype(jnp.int32)[None, :], mp, 0)
    ct = _pad_to(counts.astype(jnp.int32)[None, :], mp, 0)
    lp = _pad_to(lo.astype(jnp.int32)[None, :], mp, 0)
    li, pos = pl.pallas_call(
        functools.partial(_expand_kernel, block_n=block_n, block_m=block_m),
        grid=(np_ // block_n, mp // block_m),
        in_specs=[pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
                  pl.BlockSpec((1, block_m), lambda i, j: (0, j)),
                  pl.BlockSpec((1, block_m), lambda i, j: (0, j))],
        out_specs=[pl.BlockSpec((1, block_n), lambda i, j: (0, i)),
                   pl.BlockSpec((1, block_n), lambda i, j: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int32),
                   jax.ShapeDtypeStruct((1, np_), jnp.int32)],
        interpret=interpret,
    )(st, ct, lp)
    return li[0, :total], pos[0, :total]


# --------------------------------------------------------------------------- #
# gather
# --------------------------------------------------------------------------- #

def _gather_kernel(val_ref, idx_ref, out_ref, *, n_values: int, fill: int):
    vals = val_ref[0, :]                              # full table, resident
    idx = idx_ref[0, :]
    safe = jnp.clip(idx, 0, max(n_values - 1, 0))
    out = jnp.take(vals, safe, axis=0)
    out_ref[0, :] = jnp.where((idx >= 0) & (idx < n_values), out,
                              jnp.asarray(fill, vals.dtype))


@functools.partial(jax.jit, static_argnames=("fill", "block_n", "interpret"))
def gather_rows_pallas(values: jnp.ndarray, idx: jnp.ndarray, *,
                       fill: int = 0, block_n: int = 1024,
                       interpret: bool = False) -> jnp.ndarray:
    """Masked gather ``values[idx]`` (int32), out-of-range -> ``fill``.

    The value table stays resident across the row-tile grid (one VMEM
    panel), each program gathers one tile of indices against it.
    """
    m, n = values.shape[0], idx.shape[0]
    mp = max(128, (m + 127) // 128 * 128)
    np_ = max(block_n, (n + block_n - 1) // block_n * block_n)
    vals = _pad_to(values.astype(jnp.int32)[None, :], mp, 0)
    idxp = _pad_to(idx.astype(jnp.int32)[None, :], np_, -1)
    out = pl.pallas_call(
        functools.partial(_gather_kernel, n_values=m, fill=fill),
        grid=(np_ // block_n,),
        in_specs=[pl.BlockSpec((1, mp), lambda i: (0, 0)),
                  pl.BlockSpec((1, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.int32),
        interpret=interpret,
    )(vals, idxp)
    return out[0, :n]
