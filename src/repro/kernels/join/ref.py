"""Pure-jnp oracle for the hash-join pack/probe/gather kernel family.

This is the same math the executor's pre-Pallas jitted path runs (and the
numpy reference backend, modulo device): packed int64 keys, binary-search
probe against the sorted build side, plain gather. int64 keys require
``jax.experimental.enable_x64`` on the caller's side (the ops layer handles
it); two dictionary ids (< 2^31) pack exactly into one int64.
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_keys(cols: jnp.ndarray) -> jnp.ndarray:
    """(N, K) key columns (each value in ``[0, 2^31)``) -> (N,) int64 keys,
    base-2^31 positional packing. Exact for K <= 2."""
    cols = cols.astype(jnp.int64)
    key = cols[:, 0]
    for c in range(1, cols.shape[1]):
        key = key * jnp.int64(1 << 31) + cols[:, c]
    return key


def probe_sorted(build_sorted: jnp.ndarray, probe: jnp.ndarray,
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """searchsorted probe: for every probe key, the ``[lo, hi)`` index range
    of equal keys in the ascending ``build_sorted`` array."""
    lo = jnp.searchsorted(build_sorted, probe, side="left")
    hi = jnp.searchsorted(build_sorted, probe, side="right")
    return lo, hi


def gather_rows(values: jnp.ndarray, idx: jnp.ndarray, *,
                fill: int = 0) -> jnp.ndarray:
    """Masked gather: ``values[idx]`` with out-of-range indices -> ``fill``."""
    n = values.shape[0]
    safe = jnp.clip(idx, 0, max(n - 1, 0))
    out = values[safe] if n else jnp.zeros_like(idx, dtype=values.dtype)
    return jnp.where((idx >= 0) & (idx < n), out,
                     jnp.asarray(fill, values.dtype))
