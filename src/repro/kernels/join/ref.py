"""Pure-jnp oracle for the hash-join pack/probe/expand/gather kernel family.

This is the same math the executor's pre-Pallas jitted path runs (and the
numpy reference backend, modulo device): packed int64 keys, binary-search
probe against the sorted build side, plain gather. int64 keys require
``jax.experimental.enable_x64`` on the caller's side (the ops layer handles
it); two dictionary ids (< 2^31) pack exactly into one int64.
"""
from __future__ import annotations

import jax.numpy as jnp


def pack_keys(cols: jnp.ndarray) -> jnp.ndarray:
    """(N, K) key columns (each value in ``[0, 2^31)``) -> (N,) int64 keys,
    base-2^31 positional packing. Exact for K <= 2."""
    cols = cols.astype(jnp.int64)
    key = cols[:, 0]
    for c in range(1, cols.shape[1]):
        key = key * jnp.int64(1 << 31) + cols[:, c]
    return key


def probe_sorted(build_sorted: jnp.ndarray, probe: jnp.ndarray,
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """searchsorted probe: for every probe key, the ``[lo, hi)`` index range
    of equal keys in the ascending ``build_sorted`` array."""
    lo = jnp.searchsorted(build_sorted, probe, side="left")
    hi = jnp.searchsorted(build_sorted, probe, side="right")
    return lo, hi


def expand_pairs(lo: jnp.ndarray, counts: jnp.ndarray, total: int,
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented ragged expansion of ``(lo, counts)`` match runs into flat
    ``(li, pos)`` pair indices — the jnp form of the executor's former
    ``np.repeat``/``np.cumsum`` addressing arithmetic.

    ``starts`` (exclusive cumsum) partitions ``[0, counts.sum())`` into
    runs; output ``j``'s owner is the *last* segment whose start is ``<=
    j`` (``searchsorted`` right minus one — duplicate starts from
    zero-count segments resolve to the one segment that actually owns
    ``j``). ``total`` is static for jit; indices past ``counts.sum()``
    resolve to the last segment and must be sliced off by the caller."""
    starts = jnp.cumsum(counts) - counts
    j = jnp.arange(total, dtype=counts.dtype)
    seg = jnp.searchsorted(starts, j, side="right") - 1
    pos = lo[seg] + j - starts[seg]
    return seg, pos


def gather_rows(values: jnp.ndarray, idx: jnp.ndarray, *,
                fill: int = 0) -> jnp.ndarray:
    """Masked gather: ``values[idx]`` with out-of-range indices -> ``fill``."""
    n = values.shape[0]
    safe = jnp.clip(idx, 0, max(n - 1, 0))
    out = values[safe] if n else jnp.zeros_like(idx, dtype=values.dtype)
    return jnp.where((idx >= 0) & (idx < n), out,
                     jnp.asarray(fill, values.dtype))
