"""Pallas TPU kernel: chunked RWKV6 WKV with data-dependent decay.

The recurrence ``S_t = diag(w_t) S_{t-1} + k_t v_t^T`` is sequential per
channel, but within a chunk of C steps it closes to matmuls (the same
duality mamba2's SSD exploits):

    L      = inclusive cumsum of log w              (C, hd)
    A[t,j] = Σ_c r[t,c]·k[j,c]·exp(L[t-1,c] − L[j,c]),  j < t   (strict tril)
    y      = (A + diag-bonus(u)) @ V + (r·exp(L_ex)) @ S_in
    S_out  = exp(L_last) ∘ S_in + (k·exp(L_last − L))ᵀ @ V

Grid = (B*H, S/C); the chunk axis is sequential ("arbitrary") and carries the
(hd, hd) state in VMEM scratch. All math is f32 — ``exp(−L)`` grows like
``exp(0.7·C)`` for typical decays, so C ≤ 64 keeps it far from f32 overflow
(documented bound; the sweep tests assert it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)                   # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                   # (hd,)
    s_in = state_ref[...]                              # (hd, hd)

    logw = jnp.log(jnp.maximum(w, 1e-30))
    l_inc = jnp.cumsum(logw, axis=0)                   # L_t inclusive
    l_ex = l_inc - logw                                # L_{t-1} (exclusive)

    rr = r * jnp.exp(l_ex)                             # (C, hd); l_ex <= 0
    # Intra-chunk matrix via the bounded segment form: the factorized
    # (r e^{L_ex}) @ (k e^{-L_inc})^T overflows f32 for strong decays
    # (|log w|*C > 88); L_ex[t]-L_inc[j] <= 0 for j < t, so exponentiate
    # the (C, C, hd) difference directly — VPU-bound but overflow-free.
    d3 = l_ex[:, None, :] - l_inc[None, :, :]          # (C, C, hd)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where((ti > tj)[:, :, None], jnp.exp(d3), 0.0)
    a = (r[:, None, :] * k[None, :, :] * seg).sum(-1)  # (C, C), strict tril
    diag = ((r * u) * k).sum(axis=1)                   # (C,) bonus term
    y = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(rr, s_in, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    l_last = l_inc[-1]                                 # (hd,)
    k_tail = k * jnp.exp(l_last[None, :] - l_inc)      # (C, hd)
    s_new = jnp.exp(l_last)[:, None] * s_in + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        sout_ref[0] = s_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, w, u, s0, *, chunk: int = 64,
               interpret: bool = False):
    """r/k/v/w: (B, S, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd)."""
    b, s, h, hd = r.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    bh = b * h

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(bh, s, hd)

    rf, kf, vf, wf = (flat(x.astype(jnp.float32)) for x in (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(bh, hd)
    s0f = s0.reshape(bh, hd, hd).astype(jnp.float32)

    seq_spec = pl.BlockSpec((1, c, hd), lambda i, j: (i, j, 0))
    y, s_out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=c, n_chunks=s // c),
        grid=(bh, s // c),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, hd, hd), lambda i, j: (i, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, hd, hd), lambda i, j: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0f)
    y = y.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return y, s_out.reshape(b, h, hd, hd)
