"""Public WKV op: Pallas chunked kernel with jnp-scan fallback/oracle."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6_wkv import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def wkv(r, k, v, w, u, s0, *, use_kernel: bool | None = None,
        interpret: bool | None = None, chunk: int = 64):
    """Chunked WKV. Shapes as in :mod:`ref`. Differentiable via the scan
    fallback; the kernel path is used for serving/prefill where the
    sequential scan would serialize the TPU."""
    if use_kernel is None:
        use_kernel = _on_tpu() or r.shape[1] >= chunk
    if not use_kernel or r.shape[1] % min(chunk, r.shape[1]) != 0:
        return ref.wkv(r, k, v, w, u, s0)
    if interpret is None:
        interpret = not _on_tpu()
    return kernel.wkv_pallas(r, k, v, w, u, s0, chunk=chunk,
                             interpret=interpret)
