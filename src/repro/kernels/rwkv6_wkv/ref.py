"""Pure-jnp oracle for the RWKV6 WKV recurrence (data-dependent decay)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv(r, k, v, w, u, s0):
    """Sequential reference.

    r/k/v/w: (B, S, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).
      y_t = r_t . (diag(u) k_t v_t^T + S_{t-1})
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y (B, S, H, hd), S_final)."""
    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       u[None, :, :, None] * kv + state)
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final
