"""Empirical kernel-vs-fallback dispatch tuning.

The join family's auto dispatch is governed by scaling envelopes — the
quadratic probe-work cap, the expand ownership-test cap, the gather
VMEM-residency cap (``join/ops.py``). Their defaults are analytical
guesses; this module replaces guesses with measurements on the backend
that will actually serve: it sweeps each stage's Pallas kernel against the
fallback tier auto dispatch would otherwise pick (host numpy on CPU, the
jitted-jnp oracle on TPU), finds the work size where the kernel stops
winning, and records the crossover as a **dispatch profile** —

```
profile = autotune.tune_join()            # sweep on this backend
profile.save("results/dispatch_profile.json")
profile.install()                         # envelopes now govern dispatch
```

— which ``repro.kernels.dispatch`` resolves per call (env var > installed
profile > default), either installed programmatically or named via the
``REPRO_DISPATCH_PROFILE`` environment variable. The CLI form feeds CI and
the docs' crossover table::

    python -m repro.kernels.autotune --quick --out results/profile.json

On this CPU container the kernels execute in interpret mode (Python
per-op), so a recorded CPU profile legitimately measures "the kernel never
wins" and pins the caps to 0 — exactly the right dispatch decision there;
the TPU profile is the one with nontrivial crossovers.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.kernels import dispatch
from repro.kernels.join import ops

# envelope names, shared with join/ops.py (the single source of the
# defaults below is the ops module's getters — kept in sync by the tests)
PROBE_CAP = "REPRO_JOIN_PROBE_WORK_CAP"
EXPAND_CAP = "REPRO_JOIN_EXPAND_WORK_CAP"
GATHER_CAP = "REPRO_JOIN_GATHER_RESIDENT_ROWS"

_DEFAULTS = {PROBE_CAP: 1 << 32, EXPAND_CAP: 1 << 32, GATHER_CAP: 1 << 21}


@dataclasses.dataclass
class Measurement:
    """One sweep point: the stage's abstract work size (the quantity the
    envelope caps — compare pairs for the probe, ownership tests for the
    expand, table rows for the gather) and both tiers' wall time."""
    stage: str
    work: int
    kernel_us: float
    fallback_us: float

    @property
    def kernel_wins(self) -> bool:
        return self.kernel_us <= self.fallback_us


def crossover_cap(measurements: Sequence[Measurement], *, default: int,
                  ) -> int:
    """The empirical envelope value from a sweep: the work size past which
    the kernel loses to the fallback.

    * kernel never wins -> 0 (auto dispatch always falls back);
    * kernel still wins at the largest measured work -> ``default`` (no
      crossover observed inside the sweep, keep the analytical cap);
    * otherwise the geometric midpoint between the largest winning work
      and the smallest losing work above it — the sweep brackets the true
      crossover, and work scales multiplicatively.
    """
    ms = sorted(measurements, key=lambda m: m.work)
    wins = [m.work for m in ms if m.kernel_wins]
    if not wins:
        return 0
    last_win = max(wins)
    losses_above = [m.work for m in ms
                    if not m.kernel_wins and m.work > last_win]
    if not losses_above:
        return default
    return int(np.sqrt(float(last_win) * float(min(losses_above))))


@dataclasses.dataclass
class DispatchProfile:
    """A recorded set of dispatch envelopes plus the measurements behind
    them. ``kernels.dispatch.load_profile`` accepts it directly (it quacks
    via ``.envelopes``); :meth:`save`/:meth:`load` round-trip the JSON form
    the ``REPRO_DISPATCH_PROFILE`` env var points at."""
    envelopes: Dict[str, int]
    backend: str = "cpu"
    measurements: List[Measurement] = dataclasses.field(default_factory=list)

    def install(self) -> Dict[str, int]:
        return dispatch.load_profile(self)

    def save(self, path: str) -> None:
        payload = {
            "backend": self.backend,
            "envelopes": {k: int(v) for k, v in self.envelopes.items()},
            "measurements": [dataclasses.asdict(m)
                             for m in self.measurements],
        }
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")

    @classmethod
    def load(cls, path: str) -> "DispatchProfile":
        with open(path) as fh:
            raw = json.load(fh)
        return cls(envelopes={k: int(v)
                              for k, v in raw.get("envelopes", {}).items()},
                   backend=raw.get("backend", "cpu"),
                   measurements=[Measurement(**m)
                                 for m in raw.get("measurements", [])])


def _time_us(fn: Callable[[], object], repeats: int = 3) -> float:
    import jax

    jax.block_until_ready(fn())                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats * 1e6


def _join_fixture(rng: np.random.Generator, nl: int, nr: int):
    """Executor-shaped key columns, 50% hit rate (bench_kernels' shape)."""
    lcs = [rng.integers(0, 2**31 - 1, nl).astype(np.int64) for _ in range(2)]
    rcs = [rng.integers(0, 2**31 - 1, nr).astype(np.int64) for _ in range(2)]
    n = min(nl, nr) // 2
    for c in range(2):
        rcs[c][:n] = lcs[c][:n]
    return lcs, rcs


def tune_join(*, quick: bool = False,
              sizes: Sequence[int] | None = None,
              timer: Callable[[Callable[[], object]], float] | None = None,
              rng: np.random.Generator | None = None) -> DispatchProfile:
    """Sweep the join family's kernel stages against the fallback tier auto
    dispatch would pick on this backend, and return the recorded profile.

    ``timer`` is injectable (``fn -> microseconds``) so the crossover logic
    is unit-testable with synthetic clocks; ``sizes`` are per-side row
    counts (work scales quadratically off them for probe/expand).
    """
    import jax

    timer = timer or _time_us
    rng = rng or np.random.default_rng(0)
    if sizes is None:
        sizes = (64, 128) if quick else (256, 1024, 4096)
    on_tpu = dispatch.on_tpu()
    interpret = not on_tpu
    sweeps: Dict[str, List[Measurement]] = {"probe": [], "expand": [],
                                            "gather": []}
    for n in sizes:
        lcs, rcs = _join_fixture(rng, n, n)
        order, lo, counts = ops.hash_probe_numpy(lcs, rcs)
        total = int(counts.sum())
        li, pos = ops.expand_pairs_numpy(lo, counts)

        k = timer(lambda: ops.hash_probe(lcs, rcs, use_kernel=True,
                                         interpret=interpret))
        f = timer((lambda: ops.hash_probe_oracle(lcs, rcs)) if on_tpu
                  else (lambda: ops.hash_probe_numpy(lcs, rcs)))
        sweeps["probe"].append(Measurement("probe", n * n, k, f))

        k = timer(lambda: ops.expand_pairs(lo, counts, use_kernel=True,
                                           interpret=interpret))
        f = timer((lambda: ops.expand_pairs(lo, counts, use_kernel=False))
                  if on_tpu else (lambda: ops.expand_pairs_numpy(lo, counts)))
        sweeps["expand"].append(Measurement("expand", total * n, k, f))

        k = timer(lambda: ops.gather_rows(order, pos, use_kernel=True,
                                          interpret=interpret,
                                          bounded_by_len=True))
        f = timer(lambda: order[pos])
        sweeps["gather"].append(Measurement("gather", n, k, f))

    envelopes = {
        PROBE_CAP: crossover_cap(sweeps["probe"],
                                 default=_DEFAULTS[PROBE_CAP]),
        EXPAND_CAP: crossover_cap(sweeps["expand"],
                                  default=_DEFAULTS[EXPAND_CAP]),
        GATHER_CAP: crossover_cap(sweeps["gather"],
                                  default=_DEFAULTS[GATHER_CAP]),
    }
    return DispatchProfile(envelopes=envelopes,
                           backend=jax.default_backend(),
                           measurements=[m for ms in sweeps.values()
                                         for m in ms])


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write the recorded profile JSON here")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep (CI smoke)")
    ap.add_argument("--install", action="store_true",
                    help="install the profile into this process's dispatch "
                         "(demonstrates load; mostly useful under a REPL)")
    args = ap.parse_args()
    profile = tune_join(quick=args.quick)
    print("stage,work,kernel_us,fallback_us,kernel_wins")
    for m in profile.measurements:
        print(f"{m.stage},{m.work},{m.kernel_us:.1f},{m.fallback_us:.1f},"
              f"{int(m.kernel_wins)}")
    print("envelope,value")
    for k, v in profile.envelopes.items():
        print(f"{k},{v}")
    if args.install:
        profile.install()
    if args.out:
        profile.save(args.out)
        print(f"wrote {args.out} (backend={profile.backend})")


if __name__ == "__main__":
    main()
