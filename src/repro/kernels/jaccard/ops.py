"""Public op: Jaccard distance matrix with kernel/ref dispatch.

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
``interpret=True`` mode, and small problems fall back to the jnp oracle
(same math, no tiling overhead).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.jaccard import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def jaccard_distance(bitmaps: jnp.ndarray | np.ndarray,
                     *, use_kernel: bool | None = None,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Symmetric (Q, Q) Jaccard distance matrix from packed uint32 bitmaps."""
    a = jnp.asarray(bitmaps, dtype=jnp.uint32)
    if use_kernel is None:
        use_kernel = _on_tpu() or a.shape[0] >= 256
    if not use_kernel:
        return ref.jaccard_distance(a, a)
    if interpret is None:
        interpret = not _on_tpu()
    return kernel.jaccard_distance_pallas(a, a, interpret=interpret)
