"""Public op: Jaccard distance matrix with kernel/ref dispatch.

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
``interpret=True`` mode, and small problems fall back to the jnp oracle
(same math, no tiling overhead). The size threshold and TPU detection live
in the shared ``repro.kernels.dispatch`` policy (``hot_path=False``: this
op fires once per adaptation round, so interpret mode on CPU is an
acceptable price for exercising the real kernel everywhere).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.kernels.jaccard import kernel, ref


def jaccard_distance(bitmaps: jnp.ndarray | np.ndarray,
                     *, use_kernel: bool | None = None,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Symmetric (Q, Q) Jaccard distance matrix from packed uint32 bitmaps."""
    a = jnp.asarray(bitmaps, dtype=jnp.uint32)
    auto = use_kernel is None
    use_kernel, interpret = dispatch.resolve(use_kernel, interpret,
                                             a.shape[0], hot_path=False)
    if not use_kernel:
        dispatch.note_tier("jaccard.distance", "oracle",
                           "below_floor" if auto else "forced_off")
        return ref.jaccard_distance(a, a)
    dispatch.note_tier("jaccard.distance", "pallas",
                       "auto" if auto else "forced")
    return kernel.jaccard_distance_pallas(a, a, interpret=interpret)
