"""Pure-jnp oracle for the packed-bitmap Jaccard distance matrix."""
from __future__ import annotations

import jax.numpy as jnp


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount on uint32 words (same math the kernel uses)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def jaccard_distance(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """1 - |A∩B| / |A∪B| for packed uint32 bitmaps.

    a: (Q, W), b: (K, W) -> (Q, K) float32. Two empty sets are identical
    (J_sim = 1, distance 0), matching the paper's Fig.-1 convention.
    """
    inter = popcount(a[:, None, :] & b[None, :, :]).sum(-1)
    union = popcount(a[:, None, :] | b[None, :, :]).sum(-1)
    sim = jnp.where(union > 0, inter / jnp.maximum(union, 1), 1.0)
    return (1.0 - sim).astype(jnp.float32)
