"""Pallas TPU kernel: packed-bitmap Jaccard distance matrix.

The paper builds a query-distance matrix (Jaccard over feature sets) as input
to HAC every adaptation round. With feature bitmaps packed into uint32 words,
the distance matrix is a popcount-reduction over word tiles — VPU work with
an MXU-free inner loop, tiled so each (BQ, W) × (BK, W) pair of bitmap panels
resides in VMEM.

Grid: (Q/BQ, K/BK); each program computes a (BQ, BK) output tile by SWAR
popcount over the full word axis (workloads have O(10^3) features → W ≤ ~256
words ≈ 128 KiB per panel at BQ=128 — comfortably inside the ~16 MiB VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _jaccard_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...].astype(jnp.uint32)            # (BQ, W)
    b = b_ref[...].astype(jnp.uint32)            # (BK, W)
    inter = jnp.zeros(out_ref.shape, jnp.int32)
    union = jnp.zeros(out_ref.shape, jnp.int32)
    # broadcast over the word axis; popcount-reduce
    inter = _popcount_u32(a[:, None, :] & b[None, :, :]).sum(-1)
    union = _popcount_u32(a[:, None, :] | b[None, :, :]).sum(-1)
    sim = jnp.where(union > 0,
                    inter.astype(jnp.float32) /
                    jnp.maximum(union, 1).astype(jnp.float32),
                    jnp.float32(1.0))
    out_ref[...] = (1.0 - sim).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def jaccard_distance_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                            block_q: int = 128, block_k: int = 128,
                            interpret: bool = False) -> jnp.ndarray:
    """(Q, W) × (K, W) packed uint32 bitmaps -> (Q, K) float32 distances."""
    q, w = a.shape
    k, w2 = b.shape
    assert w == w2, (w, w2)
    bq = min(block_q, max(8, q))
    bk = min(block_k, max(8, k))
    # pad to block multiples; padded rows are empty bitmaps (distance 0 to
    # each other, 1 to non-empty) and are sliced away below.
    qp = (q + bq - 1) // bq * bq
    kp = (k + bk - 1) // bk * bk
    a_p = jnp.zeros((qp, w), a.dtype).at[:q].set(a)
    b_p = jnp.zeros((kp, w), b.dtype).at[:k].set(b)
    out = pl.pallas_call(
        _jaccard_kernel,
        grid=(qp // bq, kp // bk),
        in_specs=[
            pl.BlockSpec((bq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, kp), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:q, :k]
