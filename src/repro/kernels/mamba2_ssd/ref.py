"""Pure-jnp oracle for the per-head Mamba2 SSD recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd(x, b, c, dt, a, d, s0):
    """Sequential reference, one head.

    x: (B, S, hd), b/c: (B, S, N), dt: (B, S) (post-softplus), a: scalar < 0,
    d: scalar, s0: (B, N, hd).
      S_t = exp(dt_t * a) S_{t-1} + dt_t * b_t x_t^T
      y_t = c_t . S_t + d * x_t
    Returns (y (B, S, hd), S_final)."""
    def step(state, inp):
        x_t, b_t, c_t, dt_t = inp            # (B,hd), (B,N), (B,N), (B,)
        dec = jnp.exp(dt_t * a)[:, None, None]
        state = state * dec + dt_t[:, None, None] * \
            b_t[:, :, None] * x_t[:, None, :]
        y = jnp.einsum("bn,bnh->bh", c_t, state) + d * x_t
        return state, y

    xs = (x.transpose(1, 0, 2), b.transpose(1, 0, 2),
          c.transpose(1, 0, 2), dt.transpose(1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2), final
