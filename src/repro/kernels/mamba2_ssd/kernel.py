"""Pallas TPU kernel: chunked Mamba2 SSD (state-space dual) scan.

Grid = (B*H, S/C); the chunk axis is sequential and carries the (N, hd)
state in VMEM scratch. Within a chunk everything is matmuls (MXU):

    cum      = cumsum(dt * a)                      (C,)   a < 0 ⇒ cum ↓
    att[t,j] = (c_t · b_j) e^{cum_t − cum_j} dt_j   (tril, incl. diagonal)
    y        = att @ x + (c e^{cum}) @ S_in + D x
    S_out    = e^{cum_last} S_in + (b · dt e^{cum_last − cum})ᵀ @ x

All exponents are of non-positive values (uniform-sign decay), so unlike
RWKV6 there is no overflow hazard and chunks can be large (256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, d_ref, s0_ref,
                y_ref, sout_ref, state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    x = x_ref[0].astype(jnp.float32)                   # (C, hd)
    bm = b_ref[0].astype(jnp.float32)                  # (C, N)
    cm = c_ref[0].astype(jnp.float32)                  # (C, N)
    dt = dt_ref[0].astype(jnp.float32)                 # (C,)
    a = a_ref[0].astype(jnp.float32)                   # scalar (per head)
    d = d_ref[0].astype(jnp.float32)
    s_in = state_ref[...]                              # (N, hd)

    da = dt * a                                        # (C,) <= 0
    cum = jnp.cumsum(da)
    seg = cum[:, None] - cum[None, :]                  # (C, C), tril <= 0
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(ti >= tj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    att = scores * lmat * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + jax.lax.dot_general(cm * jnp.exp(cum)[:, None], s_in,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + d * x
    y_ref[0] = y.astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum) * dt         # (C,)
    contrib = jax.lax.dot_general(bm * decay_to_end[:, None], x,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    s_new = jnp.exp(cum[-1]) * s_in + contrib
    state_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        sout_ref[0] = s_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, b, c, dt, a, d, s0, *, chunk: int = 128,
               interpret: bool = False):
    """x: (B, S, H, hd); b/c: (B, S, N) (single group, shared across heads);
    dt: (B, S, H) post-softplus; a/d: (H,); s0: (B, H, N, hd)."""
    bb, s, h, hd = x.shape
    n = b.shape[-1]
    cs = min(chunk, s)
    assert s % cs == 0, (s, cs)
    bh = bb * h

    xf = x.transpose(0, 2, 1, 3).reshape(bh, s, hd).astype(jnp.float32)
    dtf = dt.transpose(0, 2, 1).reshape(bh, s).astype(jnp.float32)
    af = jnp.broadcast_to(a[None], (bb, h)).reshape(bh).astype(jnp.float32)
    df = jnp.broadcast_to(d[None], (bb, h)).reshape(bh).astype(jnp.float32)
    s0f = s0.reshape(bh, n, hd).astype(jnp.float32)

    y, s_out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=cs, n_chunks=s // cs),
        grid=(bh, s // cs),
        in_specs=[
            pl.BlockSpec((1, cs, hd), lambda i, j: (i, j, 0)),
            # b/c are per-batch (group-shared): index i // H
            pl.BlockSpec((1, cs, n), lambda i, j, h_=h: (i // h_, j, 0)),
            pl.BlockSpec((1, cs, n), lambda i, j, h_=h: (i // h_, j, 0)),
            pl.BlockSpec((1, cs), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, n, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cs, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, hd), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bh, n, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, hd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, b.astype(jnp.float32), c.astype(jnp.float32), dtf, af, df, s0f)
    return (y.reshape(bb, h, s, hd).transpose(0, 2, 1, 3),
            s_out.reshape(bb, h, n, hd))
