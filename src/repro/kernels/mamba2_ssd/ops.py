"""Public SSD op: Pallas chunked kernel with per-head jnp-scan oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_ssd import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(x, b, c, dt, a, d, s0, *, use_kernel: bool | None = None,
        interpret: bool | None = None, chunk: int = 128):
    """Multi-head chunked SSD; shapes as in :func:`kernel.ssd_pallas`."""
    if use_kernel is None:
        use_kernel = _on_tpu() or x.shape[1] >= chunk
    if not use_kernel:
        bb, s, h, hd = x.shape
        ys, fs = [], []
        for hi in range(h):
            y, f = ref.ssd(x[:, :, hi], b, c, dt[:, :, hi],
                           a[hi], d[hi], s0[:, hi])
            ys.append(y)
            fs.append(f)
        return jnp.stack(ys, 2), jnp.stack(fs, 1)
    if interpret is None:
        interpret = not _on_tpu()
    return kernel.ssd_pallas(x, b, c, dt, a, d, s0, chunk=chunk,
                             interpret=interpret)
