"""repro.write — the live write path: inserts/deletes served concurrently
with queries, migration, and replication.

AWAPart's premise is *continual* re-partitioning, but adapting to query
drift over an immutable graph is only half the story: xDGP and AdPart's
dynamic redistribution both treat graph **mutation** as the first-class
event partitioning must react to. This package makes the serving stack
writable:

* :class:`WriteBatch` — one normalized mutation (set semantics: deletes
  apply first, inserts win; redundant ops are no-ops).
* :func:`apply_batch` — the engine. Routes every effective row by the
  current primary assignment (``PartitionState.feature_to_shard`` of its
  owner feature), fans it out to every replica holder in the facade's
  ``ReplicaMap``, mutates the global ``TripleStore`` in place, re-indexes
  **only the touched shard views** (untouched shards keep their
  materialized ``TripleStore`` views — the same incremental-delta economy
  migration chunks get), and bumps the facade epoch + data version so the
  plan/result/profile caches invalidate correctly — including mid-
  ``MigrationSession``, where a later chunk moving a written feature
  naturally carries the post-write rows (chunk deltas are derived from the
  *live* state).
* :class:`WriteReport` / :class:`WriteLog` — what happened, per batch and
  per session: effective counts, per-feature write touches (the data-drift
  signal ``AWAPartController.note_writes`` folds into the TM window), the
  write-fanout traffic each replica copy cost, and any features born on
  the write path (new predicates are placed least-loaded; new
  ``rdf:type`` classes split out of the type predicate like any other
  tracked PO pair).
* :func:`rebuild_from_scratch` — the correctness oracle: an independently
  constructed ``PartitionedKG`` over the mutated triple set serving the
  same layout (feature universe translated by *key*). The write-path tests
  hold the live facade byte-identical to it at every epoch.

Writes are not migration: nothing moves between shards here. A write lands
where the layout says its rows live *today*; whether that layout should
change because of the write is the adaptation loop's call — write heat and
fanout bytes feed the accept guard (``repro.core.adaptive``), which prices
keeping a hot-written feature replicated against demoting it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.migration import TRIPLE_BYTES

__all__ = ["WriteBatch", "WriteReport", "WriteLog", "apply_batch",
           "fresh_entity_ids", "rebuild_from_scratch"]


def fresh_entity_ids(store, n: int = 1) -> np.ndarray:
    """Mint ``n`` entity ids no triple in ``store`` uses.

    The dictionary interns only *named* terms; bulk entity ids are
    allocated past it (see ``graph.lubm``), so ``Dictionary.encode`` on a
    fresh term can return an id some existing entity already carries —
    a subject collision that silently merges the new rows into a stranger's
    neighborhood. Writers minting subjects for new rows should take them
    from here instead: ids start one past the store's current maximum, so
    they stay fresh as long as each minted range is inserted before the
    next is minted."""
    base = int(store.triples.max(initial=-1)) + 1
    return np.arange(base, base + int(n), dtype=np.int64)


def _normalize(triples) -> np.ndarray:
    """(M, 3) int32, unique rows, from any triple-like input."""
    arr = np.asarray(triples if triples is not None else (), dtype=np.int32)
    arr = arr.reshape(-1, 3)
    return np.unique(arr, axis=0) if len(arr) else arr


def _row_keys(*arrays: np.ndarray) -> List[np.ndarray]:
    """One int64 key per (s, p, o) row, consistent *across* all given
    arrays (equal rows map to equal keys). Three int32 ids don't pack into
    one int64 directly, so the (s, p) pair is dense-ranked over the union
    first and the rank packed with o — the same base-2**31 trick the
    executors' ``_key_columns`` uses."""
    lens = [len(a) for a in arrays]
    if sum(lens) == 0:
        return [np.empty(0, np.int64) for _ in arrays]
    cat = np.concatenate([np.asarray(a, np.int64).reshape(-1, 3)
                          for a in arrays])
    sp = cat[:, 0] * np.int64(1 << 31) + cat[:, 1]
    _, inv = np.unique(sp, return_inverse=True)
    keys = inv.astype(np.int64) * np.int64(1 << 31) + cat[:, 2]
    out, at = [], 0
    for n in lens:
        out.append(keys[at:at + n])
        at += n
    return out


@dataclasses.dataclass
class WriteBatch:
    """One mutation against the live graph: triples to delete + triples to
    insert, dictionary-encoded (s, p, o) int32 rows.

    Semantics are set-based and deterministic regardless of row order:
    the post-batch triple set is ``(store - deletes) | inserts`` — deletes
    apply first, an insert of a triple also being deleted wins (the triple
    ends present). Inserting a triple already present and deleting one
    absent are redundant no-ops (counted in ``WriteReport.n_redundant``).
    """

    inserts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty((0, 3), np.int32))
    deletes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty((0, 3), np.int32))

    def __post_init__(self) -> None:
        self.inserts = _normalize(self.inserts)
        self.deletes = _normalize(self.deletes)

    @property
    def n_ops(self) -> int:
        return len(self.inserts) + len(self.deletes)

    def summary(self) -> str:
        return (f"WriteBatch(+{len(self.inserts)}/-{len(self.deletes)} "
                f"triples)")


@dataclasses.dataclass
class WriteReport:
    """What one applied :class:`WriteBatch` actually did."""

    n_inserted: int                    # effective rows added
    n_deleted: int                     # effective rows removed
    n_redundant: int                   # requested ops that were no-ops
    touched_shards: List[int]          # shards whose materialized rows changed
    feature_writes: Dict[int, int]     # owner feature -> rows written (+/-)
    # features born on this write (new predicate / new rdf:type class):
    # (feature idx, key, assigned primary shard)
    new_features: List[Tuple[int, Tuple, int]]
    fanout_copies: int                 # extra replica copies written
    fanout_bytes: int                  # replica write-fanout traffic (bytes)
    epoch: int                         # facade epoch after the write
    data_version: int                  # facade data version after the write
    seq: int = -1                      # position in the WriteLog (set there)

    @property
    def effective(self) -> bool:
        return bool(self.n_inserted or self.n_deleted)

    def summary(self) -> str:
        rep = (f", fanout {self.fanout_copies} copies/"
               f"{self.fanout_bytes} B" if self.fanout_copies else "")
        return (f"write +{self.n_inserted}/-{self.n_deleted} "
                f"({self.n_redundant} redundant) on shards "
                f"{self.touched_shards}{rep} -> epoch {self.epoch}")


class WriteLog:
    """Ordered log of applied batches — the session-level mutation history
    ``KGService`` keeps (telemetry + replay source for tests/benchmarks)."""

    def __init__(self) -> None:
        self.entries: List[Tuple[WriteBatch, WriteReport]] = []
        self.n_inserted = 0
        self.n_deleted = 0
        self.fanout_bytes = 0

    def append(self, batch: WriteBatch, report: WriteReport) -> int:
        report.seq = len(self.entries)
        self.entries.append((batch, report))
        self.n_inserted += report.n_inserted
        self.n_deleted += report.n_deleted
        self.fanout_bytes += report.fanout_bytes
        return report.seq

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"WriteLog({len(self.entries)} batches, "
                f"+{self.n_inserted}/-{self.n_deleted} triples, "
                f"{self.fanout_bytes} B fanout)")


# --------------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------------- #

def _resolve(kg, batch: WriteBatch):
    """Effective delete row ids + effective insert rows under set semantics.

    ``del_rows``: store rows whose triple is in ``deletes`` and not
    re-inserted by the same batch (insert wins). ``ins_rows``: insert
    triples not already present. Everything else is redundant."""
    store = kg.store
    skey, dkey, ikey = _row_keys(store.triples, batch.deletes, batch.inserts)
    order = np.argsort(skey, kind="stable")
    skey_sorted = skey[order]
    eff_del = dkey[~np.isin(dkey, ikey)] if len(dkey) else dkey
    del_rows: List[np.ndarray] = []
    if len(eff_del):
        lo = np.searchsorted(skey_sorted, eff_del, side="left")
        hi = np.searchsorted(skey_sorted, eff_del, side="right")
        # a store built via build_store is duplicate-free, but set-delete
        # removes every copy of the triple regardless
        del_rows = [order[l:h] for l, h in zip(lo.tolist(), hi.tolist())
                    if h > l]
    del_rows = (np.sort(np.concatenate(del_rows)) if del_rows
                else np.empty(0, np.int64))
    new_mask = (~np.isin(ikey, skey) if len(ikey)
                else np.zeros(0, dtype=bool))
    ins_rows = batch.inserts[new_mask]
    n_redundant = (len(batch.inserts) - len(ins_rows)) \
        + (len(batch.deletes) - len(del_rows))
    return del_rows, ins_rows, n_redundant


def _owner_features(kg, ins_rows: np.ndarray,
                    ) -> Tuple[np.ndarray, List[Tuple[int, Tuple, int]]]:
    """Owner feature per effective insert row, creating (and placing) any
    features the universe has never seen.

    Routing is vectorized (the PR-6 headroom item): one batched tracked-PO
    lookup plus one batched P lookup resolve every row whose owner feature
    already exists (``FeatureSpace.po_index_batch``/``p_index_batch`` —
    a ``searchsorted`` each instead of a python loop over the batch). Only
    the leftover rows — brand-new predicates and never-seen ``rdf:type``
    classes — take the scalar creation path, in first-occurrence order, so
    feature birth order and placement are byte-identical to the scalar
    routing (``_owner_features_scalar``, kept as the parity oracle).

    A new predicate's P feature goes to the least-loaded shard (by primary
    triple count — there is no parent to inherit from); a new
    ``rdf:type`` class gets a tracked PO feature on its parent P shard,
    mirroring the ownership split the FeatureSpace applies at
    construction, so a rebuild-from-scratch facade derives the identical
    owner for every row."""
    space, state = kg.space, kg.state
    owners = np.empty(len(ins_rows), dtype=np.int32)
    new_features: List[Tuple[int, Tuple, int]] = []
    if not len(ins_rows):
        return owners, new_features

    p = ins_rows[:, 1].astype(np.int64)
    o = ins_rows[:, 2].astype(np.int64)
    po = space.po_index_batch(p, o)
    owners[:] = po
    need = po < 0                          # PO pair untracked at batch start
    if not need.any():
        return owners, new_features

    tp = -1 if space.type_predicate is None else int(space.type_predicate)
    idx = np.flatnonzero(need)
    pi = space.p_index_batch(p[idx])
    fast = (pi >= 0) & (p[idx] != tp)      # known plain predicate: owner = P
    owners[idx[fast]] = pi[fast]

    loads = None
    placed: Dict[int, int] = {}        # new feature idx -> assigned shard

    def place_least_loaded(fid: int) -> int:
        nonlocal loads
        if loads is None:
            loads = np.asarray(kg.shard_sizes(), dtype=np.int64).copy()
        dst = int(np.argmin(loads))
        loads[dst] += 1
        return dst

    nf_before = space.n_features
    for i in idx[~fast].tolist():          # feature-creating rows only
        p_i, o_i = int(p[i]), int(o[i])
        f = space.po_index(p_i, o_i)       # may exist since batch start now
        if f is None:
            known = space.index_of(("P", p_i))
            if known is None:
                known = space.track_p(p_i)
                dst = place_least_loaded(known)
                placed[known] = dst
                new_features.append((known, space.key(known), dst))
            if p_i == space.type_predicate:
                # a never-seen class: split it out of rdf:type exactly like
                # the constructor / track_workload would have
                f = space.track_po(p_i, o_i)
                dst = (placed[known] if known in placed
                       else int(state.feature_to_shard[known]))
                placed[f] = dst
                new_features.append((f, space.key(f), dst))
            else:
                f = known
        owners[i] = f
    if space.n_features > nf_before:
        add = np.array([shard for _f, _k, shard in new_features],
                       dtype=np.int32)
        assert len(add) == space.n_features - nf_before
        state.feature_to_shard = np.concatenate(
            [state.feature_to_shard, add])
        state.feature_sizes = np.concatenate(
            [state.feature_sizes, np.zeros(len(add), np.int64)])
        kg.replicas.extend(state.feature_to_shard)
    return owners, new_features


def _owner_features_scalar(kg, ins_rows: np.ndarray,
                           ) -> Tuple[np.ndarray,
                                      List[Tuple[int, Tuple, int]]]:
    """The original per-row routing loop — the parity oracle the vectorized
    :func:`_owner_features` is tested against (identical owners, identical
    feature birth order/placement, identical state growth)."""
    space, state = kg.space, kg.state
    owners = np.empty(len(ins_rows), dtype=np.int32)
    new_features: List[Tuple[int, Tuple, int]] = []
    loads = None
    placed: Dict[int, int] = {}        # new feature idx -> assigned shard

    def place_least_loaded(fid: int) -> int:
        nonlocal loads
        if loads is None:
            loads = np.asarray(kg.shard_sizes(), dtype=np.int64).copy()
        dst = int(np.argmin(loads))
        loads[dst] += 1
        return dst

    nf_before = space.n_features
    for i, (s, p, o) in enumerate(ins_rows.tolist()):
        f = space.po_index(p, o)
        if f is None:
            known = space.index_of(("P", p))
            if known is None:
                known = space.track_p(p)
                dst = place_least_loaded(known)
                placed[known] = dst
                new_features.append((known, space.key(known), dst))
            if p == space.type_predicate:
                f = space.track_po(p, o)
                dst = (placed[known] if known in placed
                       else int(state.feature_to_shard[known]))
                placed[f] = dst
                new_features.append((f, space.key(f), dst))
            else:
                f = known
        owners[i] = f
    if space.n_features > nf_before:
        add = np.array([shard for _f, _k, shard in new_features],
                       dtype=np.int32)
        assert len(add) == space.n_features - nf_before
        state.feature_to_shard = np.concatenate(
            [state.feature_to_shard, add])
        state.feature_sizes = np.concatenate(
            [state.feature_sizes, np.zeros(len(add), np.int64)])
        kg.replicas.extend(state.feature_to_shard)
    return owners, new_features


def apply_batch(kg, batch: WriteBatch) -> WriteReport:
    """Apply one :class:`WriteBatch` to a live ``PartitionedKG``.

    Effective rows are routed by the **current** primary assignment of
    their owner feature and fanned out to every holder in the facade's
    ``ReplicaMap`` (a replicated feature's copies stay byte-identical —
    that fanout is exactly the per-write cost the adaptation guard prices).
    Only the shards whose materialized rows changed are re-indexed; an
    effective write bumps the facade epoch (plans/results invalidate) and
    its data version (layout-invariant profiles invalidate too — join
    results are no longer the same graph's). A fully-redundant batch is a
    no-op: same epoch, caches intact.
    """
    state = kg.state
    del_rows, ins_rows, n_redundant = _resolve(kg, batch)
    if not len(del_rows) and not len(ins_rows):
        return WriteReport(
            n_inserted=0, n_deleted=0, n_redundant=n_redundant,
            touched_shards=[], feature_writes={}, new_features=[],
            fanout_copies=0, fanout_bytes=0, epoch=kg.epoch,
            data_version=kg.data_version)

    owners_ins, new_features = _owner_features(kg, ins_rows)
    owners_del = kg.owners[del_rows]
    touched_feats = np.unique(np.concatenate([owners_del, owners_ins])
                              .astype(np.int64))

    # fanout: every extra holder of a written feature receives the row too
    n_copies = kg.replicas.n_copies()
    extra = np.maximum(n_copies[touched_feats] - 1, 0)
    writes_per_feat = np.bincount(
        np.concatenate([owners_del, owners_ins]).astype(np.int64),
        minlength=len(state.feature_to_shard))[touched_feats]
    fanout_copies = int((extra * writes_per_feat).sum())
    fanout_bytes = fanout_copies * TRIPLE_BYTES

    # shards whose materialized rows change: every holder of a touched
    # feature (the primary's bit is always set in the mask)
    hold = np.bitwise_or.reduce(kg.replicas.masks[touched_feats])
    touched_shards = [s for s in range(state.n_shards)
                      if (int(hold) >> s) & 1]

    # mutate the global store in place; remap the facade's row indexes
    remap = kg.store.apply_mutation(ins_rows, del_rows)
    keep = remap >= 0
    kg.owners = np.concatenate([kg.owners[keep], owners_ins])
    kg._triple_shard = np.concatenate(
        [kg._triple_shard[keep],
         state.feature_to_shard[owners_ins]]).astype(np.int32)
    np.subtract.at(state.feature_sizes, owners_del, 1)
    np.add.at(state.feature_sizes, owners_ins, 1)

    touched = set(touched_shards)
    for s in range(state.n_shards):
        if s in touched:
            kg._rows[s] = np.flatnonzero(kg._triple_shard == s)
            kg._views[s] = None
        elif len(del_rows):
            # untouched shards hold no deleted row; the remap is monotonic
            # over survivors, so sorted row lists stay sorted
            kg._rows[s] = remap[kg._rows[s]]
            kg._replica_rows[s] = remap[kg._replica_rows[s]]
        kg._shard_rows[s] = None
    kg._rebuild_feature_index()
    for s in touched_shards:
        kg._refresh_replica_rows(s, state.feature_to_shard)

    kg.epoch += 1
    kg.data_version += 1
    kg._invalidate_caches()
    kg._profiles.clear()       # profiles are data-dependent: global row ids

    m = getattr(kg, "metrics", None)
    if m is not None:          # repro.obs: write-path traffic counters
        m.counter("write.batches").inc()
        m.counter("write.rows_inserted").inc(len(ins_rows))
        m.counter("write.rows_deleted").inc(len(del_rows))
        m.counter("write.rows_redundant").inc(n_redundant)
        m.counter("write.fanout_copies").inc(fanout_copies)
        m.counter("write.fanout_bytes").inc(fanout_bytes)

    return WriteReport(
        n_inserted=len(ins_rows), n_deleted=len(del_rows),
        n_redundant=n_redundant, touched_shards=touched_shards,
        feature_writes={int(f): int(c) for f, c in
                        zip(touched_feats.tolist(), writes_per_feat.tolist())},
        new_features=new_features, fanout_copies=fanout_copies,
        fanout_bytes=fanout_bytes, epoch=kg.epoch,
        data_version=kg.data_version)


# --------------------------------------------------------------------------- #
# the correctness oracle
# --------------------------------------------------------------------------- #

def rebuild_from_scratch(kg):
    """An independently-built ``PartitionedKG`` over the live facade's
    current triples, serving the same layout.

    Fresh ``TripleStore``, fresh ``FeatureSpace`` mirroring the live
    feature universe by *key* (including features whose triples were all
    deleted — queries may still reference them), and the primary/replica
    assignment translated key-by-key. The write-path property tests hold
    the live facade byte-identical (bindings + comparable ``ExecStats``)
    to this rebuild at every epoch.
    """
    from repro.api.facade import PartitionedKG
    from repro.core.features import FeatureSpace
    from repro.core.partition import PartitionState
    from repro.graph.triples import TripleStore
    from repro.replicate import ReplicaMap

    store2 = TripleStore(kg.store.triples.copy(), kg.store.dictionary)
    space2 = FeatureSpace(store2, type_predicate=kg.space.type_predicate)
    for key in kg.space.feature_keys():
        if key[0] == "PO":
            space2.track_po(key[1], key[2])
        else:
            space2.track_p(key[1])
    f2s = np.empty(space2.n_features, dtype=np.int32)
    masks = np.empty(space2.n_features, dtype=np.uint64)
    for i in range(space2.n_features):
        j = kg.space.index_of(space2.key(i))
        assert j is not None, \
            f"rebuilt space tracks {space2.key(i)} but the live one doesn't"
        f2s[i] = kg.state.feature_to_shard[j]
        masks[i] = kg.replicas.masks[j]
    state2 = PartitionState(f2s, space2.feature_sizes(), kg.n_shards)
    return PartitionedKG(store2, space2, state2,
                         max_join_rows=kg.max_join_rows,
                         replicas=ReplicaMap(masks, kg.n_shards))
