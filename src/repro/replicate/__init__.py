"""repro.replicate — workload-aware read replication of hot features.

AWAPart adapts the *placement* of triples to the workload, but a single-copy
layout cannot eliminate the residual distributed joins: a hot feature touched
by queries homed on many different shards forces cut-edge shipping wherever
it lands. AdPart and TAPER both resolve this by incrementally *replicating*
frequently-accessed data alongside workload-adaptive placement — a query
reads the copy nearest to its PPN, and only features with no local copy are
shipped.

This package is the layout side of that idea:

* :class:`ReplicaMap` — feature -> set-of-shards, carried by
  ``PartitionedKG`` beside the primary ``PartitionState``. The primary
  assignment stays authoritative (exactly one designated primary copy per
  feature; writes/deltas fan out to every copy); replicas are pure read
  copies the planner may serve locally.
* :func:`propose_replicas` — the per-adaptation-round policy: promote the
  hottest workload features (``migration.feature_heat``) onto the PPNs that
  read them remotely, greedy under a byte budget; features not re-proposed
  are demoted. The ``AWAPartController`` calls this each round and the
  accept guard prices the resulting copy traffic like any other migration
  bytes.

Replica *materialization* is not a new mechanism: promotions/demotions ride
the existing ``MigrationPlan``/``MigrationChunk``/``MigrationSession``
machinery (``repro.core.migration``, ``repro.migrate``) as ``replica_adds``
/ ``replica_drops`` ops, so copy traffic drains under the same
``migration_budget`` as moves and every partially-replicated layout is a
first-class served epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.migration import TRIPLE_BYTES, feature_heat
from repro.core.partition import PartitionState

__all__ = ["ReplicaMap", "propose_replicas"]


def _popcount(masks: np.ndarray) -> np.ndarray:
    """Set bits per uint64 mask, (F,) int64 (portable: no np.bitwise_count)."""
    if len(masks) == 0:
        return np.zeros(0, dtype=np.int64)
    bits = np.unpackbits(np.ascontiguousarray(masks).view(np.uint8))
    return bits.reshape(len(masks), 64).sum(axis=1).astype(np.int64)


@dataclasses.dataclass
class ReplicaMap:
    """Which shards hold a copy of each feature, as per-feature bitmasks.

    ``masks[f]`` has bit ``s`` set iff shard ``s`` holds a copy of feature
    ``f``'s triples. The designated primary copy is NOT stored here — it is
    the ``PartitionState.feature_to_shard`` assignment carried beside this
    map — but its bit is always set (invariant maintained by every mutation
    path), so ``masks`` alone answers "who can serve f locally".
    """

    masks: np.ndarray                  # (F,) uint64 holder bitmask
    n_shards: int

    def __post_init__(self) -> None:
        assert self.n_shards <= 64, "bitmask layout supports <= 64 shards"
        self.masks = np.ascontiguousarray(self.masks, dtype=np.uint64)

    @classmethod
    def primary_only(cls, state: PartitionState) -> "ReplicaMap":
        """The no-replication layout: each feature held by its primary."""
        masks = (np.uint64(1) << state.feature_to_shard.astype(np.uint64))
        return cls(masks, state.n_shards)

    def copy(self) -> "ReplicaMap":
        return ReplicaMap(self.masks.copy(), self.n_shards)

    # ------------------------------------------------------------------ #
    @property
    def n_features(self) -> int:
        return len(self.masks)

    @property
    def has_replicas(self) -> bool:
        """True when any feature has more than one copy."""
        m = self.masks
        return bool((m & (m - np.uint64(1))).any())

    def has(self, f: int, s: int) -> bool:
        return bool((self.masks[f] >> np.uint64(s)) & np.uint64(1))

    def holders(self, f: int) -> List[int]:
        m = int(self.masks[f])
        return [s for s in range(self.n_shards) if (m >> s) & 1]

    def on_shard(self, s: int) -> np.ndarray:
        """(F,) bool: does shard ``s`` hold a copy of each feature?"""
        return ((self.masks >> np.uint64(s)) & np.uint64(1)).astype(bool)

    def n_copies(self) -> np.ndarray:
        """(F,) copies per feature (always >= 1 once primaries are set)."""
        return _popcount(self.masks)

    def replicated(self) -> np.ndarray:
        """Feature ids holding more than one copy."""
        m = self.masks
        return np.flatnonzero(m & (m - np.uint64(1)))

    def replica_bytes(self, feature_sizes: np.ndarray) -> int:
        """Total bytes of non-primary copies (the ``replica_budget`` unit)."""
        extra = np.maximum(self.n_copies() - 1, 0)
        return int((extra * np.asarray(feature_sizes, np.int64)).sum()
                   * TRIPLE_BYTES)

    # ------------------------------------------------------------------ #
    def add(self, f: int, s: int) -> None:
        self.masks[f] |= np.uint64(1) << np.uint64(s)

    def remove(self, f: int, s: int) -> None:
        self.masks[f] &= ~(np.uint64(1) << np.uint64(s))

    def move_primary(self, f: int, src: int, dst: int) -> None:
        """A primary move ships the data away from ``src``: the copy leaves
        ``src`` and lands on ``dst`` (merging with any replica already
        there); other replicas are untouched."""
        self.masks[f] = (self.masks[f] & ~(np.uint64(1) << np.uint64(src))) \
            | (np.uint64(1) << np.uint64(dst))

    def extend(self, feature_to_shard: np.ndarray) -> None:
        """Grow to a larger feature universe: new features (split PO
        children) start primary-only on their inherited shard."""
        n_new = len(feature_to_shard) - len(self.masks)
        assert n_new >= 0
        if n_new == 0:
            return
        new = (np.uint64(1)
               << feature_to_shard[len(self.masks):].astype(np.uint64))
        self.masks = np.concatenate([self.masks, new])

    def __eq__(self, other) -> bool:
        return (isinstance(other, ReplicaMap)
                and self.n_shards == other.n_shards
                and np.array_equal(self.masks, other.masks))


# --------------------------------------------------------------------------- #
# promotion/demotion policy (one call per adaptation round)
# --------------------------------------------------------------------------- #

def propose_replicas(space, state: PartitionState, queries: Sequence,
                     budget_bytes: int, *,
                     heat: np.ndarray | None = None,
                     write_heat: np.ndarray | None = None) -> ReplicaMap:
    """Workload-aware replica set for ``state``, greedy under a byte budget.

    Candidates are ``(feature, shard)`` pairs where some query's PPN reads
    the feature remotely (the feature's primary is not the PPN). Promotion
    order is hottest feature first (``migration.feature_heat``), then the
    pair's frequency-weighted remote demand, with deterministic id
    tie-breaks. A copy costs its feature's triples in bytes; pairs that no
    longer fit the remaining budget are skipped so smaller hot features can
    still fill it. Features not selected hold only their primary copy —
    demotion of cold replicas is implicit in rebuilding the map fresh each
    round.

    ``write_heat`` (rows written per feature this TM window, already scaled
    by the caller's write-rate weight — see ``AdaptConfig.write_cost_weight``)
    turns the order write-aware: a copy of a written feature must receive
    every write too, so promotion ranks by *net* heat (read minus write) and
    a candidate whose recurring fanout outweighs its read demand is never
    proposed — the accept guard then prices dropping the existing copy as a
    per-window saving. None keeps the read-only behaviour bit-identical."""
    rmap = ReplicaMap.primary_only(state)
    budget = int(budget_bytes or 0)
    queries = list(queries)
    if budget <= 0 or not queries:
        return rmap
    from repro.query import plan as qplan     # deferred: keeps imports acyclic

    if heat is None:
        heat = feature_heat(space, queries)
    net_heat = np.asarray(heat, np.float64)
    if write_heat is not None:
        wh = np.zeros(len(net_heat))
        wh[:min(len(net_heat), len(write_heat))] = \
            write_heat[:min(len(net_heat), len(write_heat))]
        net_heat = net_heat - wh
    sizes = np.asarray(state.feature_sizes, np.int64)
    demand: Dict[Tuple[int, int], float] = {}
    for q in queries:
        ppn = qplan.primary_shard(q, space, state)
        for f in space.query_features(q).tolist():
            if int(state.feature_to_shard[f]) != ppn:
                key = (int(f), int(ppn))
                demand[key] = demand.get(key, 0.0) + q.frequency
    order = sorted(demand, key=lambda fs: (-float(net_heat[fs[0]]),
                                           -demand[fs], fs))
    spent = 0
    for f, s in order:
        if write_heat is not None and net_heat[f] <= 0:
            continue           # fanout eats the read savings: don't promote
        cost = int(sizes[f]) * TRIPLE_BYTES
        if cost <= 0 or rmap.has(f, s) or spent + cost > budget:
            continue
        rmap.add(f, s)
        spent += cost
    return rmap
