"""Render the §Roofline markdown table from dry-run JSONs.

  python results/make_table.py results/dryrun3 [--md]
"""
import glob
import json
import sys

d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun3"
md = "--md" in sys.argv
rows = []
for f in sorted(glob.glob(f"{d}/*.json")):
    rec = json.load(open(f))
    if rec.get("skipped") or "error" in rec:
        continue
    r = rec["roofline"]
    rows.append((rec["arch"], rec["shape"], rec["mesh"],
                 r["t_compute"], r["t_memory"], r["t_collective"],
                 r["dominant"], r["useful_flops_ratio"],
                 r["roofline_fraction"]))


def fmt(t):
    if t >= 1:
        return f"{t:.2f} s"
    if t >= 1e-3:
        return f"{t * 1e3:.1f} ms"
    return f"{t * 1e6:.0f} us"


if md:
    print("| arch | shape | mesh | t_comp | t_mem | t_coll | dominant | useful | fraction |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a, s, m, c, me, x, dom, u, fr in sorted(rows):
        print(f"| {a} | {s} | {m} | {fmt(c)} | {fmt(me)} | {fmt(x)} | "
              f"{dom} | {u:.2f} | {fr:.3f} |")
else:
    for a, s, m, c, me, x, dom, u, fr in sorted(rows):
        print(f"{a:18s} {s:12s} {m:6s} c={fmt(c):>9s} m={fmt(me):>9s} "
              f"x={fmt(x):>9s} {dom[:4]:5s} u={u:5.2f} f={fr:.3f}")
