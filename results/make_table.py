"""Render result tables.

* Roofline (dry-run JSON dir):  python results/make_table.py results/dryrun3 [--md]
* Streaming tails (CSV):        python results/make_table.py results/exp_streaming.csv [--md]
* Kernel/exp rows (CSV):        python results/make_table.py results/exp_kernels.csv [--md]

A ``.csv`` argument is discriminated by header: a ``rate_qps`` column
renders the streaming-admission percentile table (per ``(mode, rate_qps)``,
the p50/p95/p99 over every per-window row ``benchmarks/bench_streaming.py``
wrote); a ``scenario`` column renders the drift-reactivity table (per
``(scenario, mode)`` recovery metrics recomputed from the per-window rows
``benchmarks/bench_drift.py`` wrote); a ``us_per_call`` column renders the
generic name/time/derived rows that ``bench_kernels.py --csv`` and
``bench_exp1.py`` emit — including the fused-vs-staged join-pipeline
speedup rows; a ``metric`` column renders the observability snapshot
(``repro.obs.MetricsRegistry.to_csv`` / ``launch.serve --metrics-csv``)
grouped by kind — counters and gauges as single values, histograms with
their mean/p50/p95/p99/max columns.
"""
import csv
import glob
import json
import sys

d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun3"
md = "--md" in sys.argv


def fmt(t):
    if t >= 1:
        return f"{t:.2f} s"
    if t >= 1e-3:
        return f"{t * 1e3:.1f} ms"
    return f"{t * 1e6:.0f} us"


def streaming_table(path):
    """Percentile rows per (mode, rate_qps) from the per-window CSV."""
    groups = {}
    with open(path, newline="") as fh:
        for rec in csv.DictReader(fh):
            key = (float(rec["rate_qps"]), rec["mode"])
            groups.setdefault(key, []).append(rec)
    rows = []
    for (rate, mode), recs in sorted(groups.items()):
        n = sum(int(r["n"]) for r in recs)
        # worst window carries the tail; the mean row summarizes the run
        p50 = sum(float(r["p50_ms"]) * int(r["n"]) for r in recs) / n
        p95 = max(float(r["p95_ms"]) for r in recs)
        p99 = max(float(r["p99_ms"]) for r in recs)
        rows.append((rate, mode, len(recs), n, p50, p95, p99))
    if md:
        print("| rate (qps) | mode | windows | queries | p50 | p95 (worst window) | p99 (worst window) |")
        print("|---|---|---|---|---|---|---|")
        for rate, mode, w, n, p50, p95, p99 in rows:
            print(f"| {rate:g} | {mode} | {w} | {n} | {fmt(p50 / 1e3)} | "
                  f"{fmt(p95 / 1e3)} | {fmt(p99 / 1e3)} |")
    else:
        for rate, mode, w, n, p50, p95, p99 in rows:
            print(f"rate={rate:8g} {mode:10s} windows={w:3d} n={n:5d} "
                  f"p50={fmt(p50 / 1e3):>9s} p95={fmt(p95 / 1e3):>9s} "
                  f"p99={fmt(p99 / 1e3):>9s}")


def drift_table(path, margin=0.2, baseline_windows=3):
    """Reactivity rows per (scenario, mode) from bench_drift's per-window
    CSV: onsets recovered, worst degradation depth, max time-to-recover,
    and migration+replica bytes spent recovering. Mirrors the definitions
    in ``repro.scenario.reactivity`` — baselines anchor to the tail of the
    most recent earlier phase with the same ``mix_id``, else the windows
    just before the onset."""
    arms = {}
    with open(path, newline="") as fh:
        for rec in csv.DictReader(fh):
            arms.setdefault((rec["scenario"], rec["mode"]), []).append(rec)
    rows = []
    for (scenario, mode), recs in arms.items():
        recs.sort(key=lambda r: int(r["window"]))
        onsets = [int(r["window"]) for r in recs if r["onset"] == "1"]
        spans = list(zip([0] + onsets, onsets + [len(recs)]))
        n_rec, depths, ttrs, spent = 0, [], [], 0
        for start, end in spans:
            if start not in onsets:
                continue
            key = recs[start]["mix_id"]
            same = [(s, e) for s, e in spans if e <= start
                    and recs[s]["mix_id"] == key]
            s, e = same[-1] if same else (max(0, start - baseline_windows),
                                          start)
            pre = recs[max(s, e - baseline_windows):e]
            base = sum(float(r["window_ms"]) for r in pre) / len(pre)
            span = recs[start:end]
            at = next((i for i, r in enumerate(span)
                       if float(r["window_ms"]) <= (1 + margin) * base),
                      None)
            upto = span if at is None else span[:at + 1]
            depths.append(max(float(r["window_ms"]) for r in upto) / base)
            if at is not None:
                n_rec += 1
                ttrs.append(at)
            spent += sum(int(r["stall_bytes"]) for r in upto)
        rows.append((scenario, mode, len(recs), len(onsets), n_rec,
                     max(depths), max(ttrs, default=0), spent))
    if md:
        print("| scenario | mode | windows | onsets | recovered | "
              "worst depth | max ttr | bytes/recovery |")
        print("|---|---|---|---|---|---|---|---|")
        for s, m, w, o, r, dep, ttr, b in rows:
            print(f"| {s} | {m} | {w} | {o} | {r} | {dep:.2f}x | {ttr} | "
                  f"{b} |")
    else:
        for s, m, w, o, r, dep, ttr, b in rows:
            print(f"{s:16s} {m:18s} windows={w:3d} onsets={o} "
                  f"recovered={r} depth={dep:5.2f}x ttr={ttr} bytes={b}")


def rows_table(path):
    """Generic ``name,us_per_call,derived`` rows (bench_kernels/bench_exp1):
    one line per row, times human-formatted, the derived annotation —
    speedups, transfer counts, shapes — carried through verbatim."""
    with open(path, newline="") as fh:
        recs = list(csv.DictReader(fh))
    if md:
        print("| name | time/value | derived |")
        print("|---|---|---|")
        for r in recs:
            print(f"| {r['name']} | {fmt(float(r['us_per_call']) / 1e6)} | "
                  f"{r['derived']} |")
    else:
        for r in recs:
            print(f"{r['name']:42s} {fmt(float(r['us_per_call']) / 1e6):>10s}"
                  f"  {r['derived']}")


def metrics_table(path):
    """Observability-snapshot rows (``MetricsRegistry.to_csv``): counters
    and gauges print their single value, histograms their count plus the
    mean/p50/p95/p99/max summary — grouped by kind, names sorted."""
    with open(path, newline="") as fh:
        recs = list(csv.DictReader(fh))
    order = {"counter": 0, "gauge": 1, "histogram": 2}
    recs.sort(key=lambda r: (order.get(r["kind"], 9), r["metric"]))
    if md:
        print("| metric | kind | value/n | mean | p50 | p95 | p99 | max |")
        print("|---|---|---|---|---|---|---|---|")
        for r in recs:
            tail = (" | ".join(f"{float(r[c]):g}" for c in
                               ("mean", "p50", "p95", "p99", "max"))
                    if r["kind"] == "histogram"
                    else " | ".join([""] * 5))
            print(f"| {r['metric']} | {r['kind']} | {float(r['value']):g} | "
                  f"{tail} |")
    else:
        for r in recs:
            if r["kind"] == "histogram":
                print(f"{r['metric']:44s} hist  n={float(r['value']):g} "
                      f"mean={float(r['mean']):.6g} "
                      f"p50={float(r['p50']):.6g} "
                      f"p95={float(r['p95']):.6g} "
                      f"p99={float(r['p99']):.6g} "
                      f"max={float(r['max']):.6g}")
            else:
                print(f"{r['metric']:44s} {r['kind']:5s} "
                      f"{float(r['value']):g}")


def roofline_table(dirname):
    rows = []
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        rec = json.load(open(f))
        if rec.get("skipped") or "error" in rec:
            continue
        r = rec["roofline"]
        rows.append((rec["arch"], rec["shape"], rec["mesh"],
                     r["t_compute"], r["t_memory"], r["t_collective"],
                     r["dominant"], r["useful_flops_ratio"],
                     r["roofline_fraction"]))
    if md:
        print("| arch | shape | mesh | t_comp | t_mem | t_coll | dominant | useful | fraction |")
        print("|---|---|---|---|---|---|---|---|---|")
        for a, s, m, c, me, x, dom, u, fr in sorted(rows):
            print(f"| {a} | {s} | {m} | {fmt(c)} | {fmt(me)} | {fmt(x)} | "
                  f"{dom} | {u:.2f} | {fr:.3f} |")
    else:
        for a, s, m, c, me, x, dom, u, fr in sorted(rows):
            print(f"{a:18s} {s:12s} {m:6s} c={fmt(c):>9s} m={fmt(me):>9s} "
                  f"x={fmt(x):>9s} {dom[:4]:5s} u={u:5.2f} f={fr:.3f}")


if d.endswith(".csv"):
    with open(d, newline="") as fh:
        head = csv.DictReader(fh).fieldnames or []
    if "us_per_call" in head:
        rows_table(d)
    elif "metric" in head:
        metrics_table(d)
    elif "scenario" in head:
        drift_table(d)
    else:
        streaming_table(d)
else:
    roofline_table(d)
