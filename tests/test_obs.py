"""repro.obs: the modeled-clock span tracer and the metrics registry —
unit behavior (nesting, clock, null twins, kind discipline) plus THE
observability acceptance properties: traces are byte-identical across
same-seed runs, span *structure* is identical across executor backends,
and a traced drain records every adaptation round, migration chunk and
per-query plan→ship decomposition."""
import json

import pytest

from repro.api import KGService
from repro.obs import (NULL_METRICS, NULL_TRACER, MetricsRegistry,
                       NullTracer, Tracer)
from repro.stream import LatencyRecorder, QueryLatency

EXECUTORS = ("numpy", "jax", "jax-pallas")


# --------------------------------------------------------------------------- #
# tracer unit behavior
# --------------------------------------------------------------------------- #

def test_tracer_nesting_and_clock():
    tr = Tracer()
    with tr.span("window", n=2) as w:
        with tr.span("query", dur=0.5, query="Q1"):
            pass
        with tr.span("query", dur=0.25, query="Q2"):
            pass
        w.annotate(late=True)
    # siblings lay out sequentially; the dur=0 parent covers its children
    q1, q2 = tr.find("query")
    assert (q1["ts"], q1["dur"]) == (0.0, 0.5)
    assert (q2["ts"], q2["dur"]) == (0.5, 0.25)
    (win,) = tr.find("window")
    assert win["ts"] == 0.0 and win["dur"] == pytest.approx(0.75)
    assert win["args"] == {"n": 2, "late": True}
    assert tr.now == pytest.approx(0.75)
    # depth reflects the open stack; structure is open-order
    assert tr.structure() == [(0, "window"), (1, "query"), (1, "query")]

    tr.advance_to(2.0)
    assert tr.now == 2.0
    tr.advance_to(1.0)                  # monotone: never rewinds
    assert tr.now == 2.0
    with tr.span("query", dur=0.1):
        pass
    assert tr.find("query")[-1]["ts"] == 2.0


def test_tracer_chrome_export_schema(tmp_path):
    tr = Tracer()
    with tr.span("adapt.round", cat="adapt", trigger="explicit") as sp:
        with tr.span("migration.chunk", cat="migrate", dur=0.125, bytes=96):
            pass
        sp.annotate(accepted=True)
    raw = tr.chrome_trace()
    assert raw["displayTimeUnit"] == "ms"
    phases = [e["ph"] for e in raw["traceEvents"]]
    assert phases.count("M") == 2 and phases.count("X") == len(tr.events)
    for ev in raw["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0      # microseconds
    chunk = next(e for e in raw["traceEvents"]
                 if e["name"] == "migration.chunk")
    assert chunk["dur"] == pytest.approx(0.125e6)

    p = tmp_path / "t.json"
    assert tr.export(str(p)) == len(tr.events) == 2
    assert json.loads(p.read_text()) == json.loads(tr.to_json())
    pl = tmp_path / "t.jsonl"
    assert tr.export(str(pl)) == 2
    lines = [json.loads(s) for s in pl.read_text().splitlines()]
    # JSONL is in span *open* (seq) order, not close order
    assert [e["name"] for e in lines] == ["adapt.round", "migration.chunk"]


def test_tracer_attrs_json_safe():
    import numpy as np
    tr = Tracer()
    with tr.span("x", a=np.int32(3), b=np.float64(0.5), c=(1, np.int64(2)),
                 d={"k": np.bool_(True)}, e=None):
        pass
    (ev,) = tr.events
    assert ev["args"] == {"a": 3, "b": 0.5, "c": [1, 2],
                          "d": {"k": True}, "e": None}
    json.dumps(ev["args"])              # round-trips without a custom encoder


def test_null_tracer_is_inert():
    tr = NULL_TRACER
    assert isinstance(tr, NullTracer) and not tr.enabled
    with tr.span("query", dur=1.0, big=list(range(10))) as sp:
        sp.annotate(x=1)
    tr.instant("mark")
    tr.advance_to(99.0)
    assert len(tr) == 0 and tr.structure() == [] and tr.span_counts() == {}
    assert tr.find("query") == [] and tr.now == 0.0


# --------------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------------- #

def test_metrics_registry_snapshot_and_kinds():
    m = MetricsRegistry()
    m.counter("a.hits").inc()
    m.counter("a.hits").inc(4)
    m.gauge("b.level").set(2.0)
    assert m.gauge("b.peak").track_max(3.0) == 3.0
    assert m.gauge("b.peak").track_max(1.0) == 3.0
    for v in (1.0, 2.0, 3.0, 4.0):
        m.histogram("c.lat").observe(v)
    snap = m.snapshot()
    assert snap["counters"] == {"a.hits": 5}
    assert snap["gauges"] == {"b.level": 2.0, "b.peak": 3.0}
    h = snap["histograms"]["c.lat"]
    assert h["n"] == 4 and h["mean"] == 2.5 and h["max"] == 4.0
    assert h["p50"] <= h["p95"] <= h["p99"] <= h["max"]
    # a name is bound to one instrument kind for its lifetime
    with pytest.raises(TypeError, match="counter"):
        m.gauge("a.hits")
    with pytest.raises(TypeError, match="histogram"):
        m.counter("c.lat")


def test_metrics_registry_csv(tmp_path):
    import csv
    m = MetricsRegistry()
    m.counter("z.n").inc(7)
    m.histogram("a.lat").observe(0.5)
    p = tmp_path / "m.csv"
    assert m.to_csv(str(p)) == 2
    rows = list(csv.DictReader(open(p, newline="")))
    assert [r["metric"] for r in rows] == ["a.lat", "z.n"]   # sorted
    assert rows[1]["kind"] == "counter" and rows[1]["value"] == "7"
    assert rows[1]["p95"] == ""                              # restval
    assert rows[0]["kind"] == "histogram" and float(rows[0]["p50"]) == 0.5


def test_null_metrics_is_inert(tmp_path):
    NULL_METRICS.counter("x").inc(5)
    NULL_METRICS.gauge("y").set(1.0)
    NULL_METRICS.histogram("z").observe(1.0)
    assert len(NULL_METRICS) == 0
    assert NULL_METRICS.snapshot() == {"counters": {}, "gauges": {},
                                       "histograms": {}}
    p = tmp_path / "null.csv"
    assert NULL_METRICS.to_csv(str(p)) == 0
    assert p.read_text().startswith("metric,kind,value")


# --------------------------------------------------------------------------- #
# recorder queue-time summaries (satellite: queue-vs-execute split)
# --------------------------------------------------------------------------- #

def _rec(i, window=0, queue=0.05, exec_s=0.1):
    t0 = 0.1 * i
    return QueryLatency(seq=i, name=f"Q{i}", window=window, shard=i % 2,
                        arrival_s=t0, start_s=t0 + queue,
                        finish_s=t0 + queue + exec_s, epoch=0, cached=False)


def test_recorder_queue_summaries(tmp_path):
    rec = LatencyRecorder()
    for i in range(8):
        rec.record(_rec(i, window=i // 4, queue=0.01 * (i + 1)))
    s = rec.summary()
    assert s["queue"]["n"] == 8
    assert s["queue"]["max"] == pytest.approx(0.08)
    assert s["queue"]["p50"] < s["p50"]          # queue is a strict subset
    for w, ws in rec.per_window().items():
        assert ws["queue"]["n"] == 4
    rows = rec.window_rows(mode="t", rate_qps=1.0)
    cols = list(rows[0])
    # the legacy header prefix consumers index by, queue columns after
    assert cols[:9] == ["mode", "rate_qps", "window", "n", "p50_ms",
                        "p95_ms", "p99_ms", "mean_ms", "max_ms"]
    assert cols[9:] == ["queue_p50_ms", "queue_p95_ms", "queue_p99_ms"]
    p = tmp_path / "w.csv"
    assert rec.to_csv(str(p), mode="t", rate_qps=1.0) == 2
    assert p.read_text().splitlines()[0] == ",".join(cols)


def test_recorder_empty_summary_well_formed():
    s = LatencyRecorder.empty_summary()
    assert s["n"] == 0 and s["p99"] == 0.0
    assert s["queue"] == dict(n=0, mean=0.0, p50=0.0, p95=0.0, p99=0.0,
                              max=0.0)


# --------------------------------------------------------------------------- #
# service wiring
# --------------------------------------------------------------------------- #

def test_stats_and_tracer_raise_before_ready(small_lubm):
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    with pytest.raises(RuntimeError, match="bootstrap"):
        svc.stats()
    with pytest.raises(RuntimeError, match="trace"):
        svc.tracer()                     # tracing off -> actionable error
    svc.bootstrap(small_lubm.base_workload())
    st = svc.stats()                     # no stream yet: empty but shaped
    assert st["latency"] == LatencyRecorder.empty_summary()
    assert st["latency_per_shard"] == {}
    assert "queries.served" not in st["metrics"]["counters"]


def _traced_drain(ds, executor="numpy"):
    svc = KGService.from_dataset(ds, n_shards=4, executor=executor,
                                 migration_budget=120_000, trace=True)
    svc.bootstrap(ds.base_workload())
    window = ds.extended_workload()
    svc.query_batch(window)
    report = svc.adapt(ds.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted and svc.session is not None
    windows = 1
    while svc.session is not None:       # drain while serving, traced
        svc.query_batch(window)
        windows += 1
    return svc, windows, len(window)


def test_traced_drain_is_complete_and_metered(small_lubm):
    svc, windows, per_window = _traced_drain(small_lubm)
    counts = svc.tracer().span_counts()
    served = windows * per_window
    # every query decomposes plan -> scan -> join -> federate -> ship
    for leg in ("plan", "scan", "join", "federate", "ship"):
        assert counts[leg] == counts["query"] == served
    assert counts["window"] == windows
    assert counts["adapt.round"] == 1
    assert counts["migration.chunk"] >= 3
    (rnd,) = svc.tracer().find("adapt.round")
    assert rnd["args"]["accepted"] is True
    assert rnd["args"]["trigger"] == "explicit"
    assert rnd["args"]["reason"] in ("amortized", "improved")
    assert rnd["args"]["t_new"] < rnd["args"]["t_base"]
    # a query span's children tile its modeled duration exactly
    tr = svc.tracer()
    q = next(e for e in tr.events if e["name"] == "query")
    kids = [e for e in tr.events
            if e["name"] in ("plan", "scan", "join", "federate", "ship")
            and q["ts"] <= e["ts"] and e["ts"] + e["dur"] <= q["ts"]
            + q["dur"] + 1e-12]
    assert sum(k["dur"] for k in kids[:5]) == pytest.approx(q["dur"])
    m = svc.stats()["metrics"]
    assert m["counters"]["queries.served"] == served
    assert m["counters"]["migrate.chunks"] == counts["migration.chunk"]
    assert m["counters"]["adapt.accepted"] == 1
    assert m["histograms"]["query.modeled_s"]["n"] == served
    assert m["gauges"]["migrate.progress"] == 1.0
    assert m["counters"]["federation.bytes_shipped"] > 0
    # kernel dispatch tier picks landed in the ambient registry
    assert any(k.startswith("kernels.dispatch.jaccard.distance.")
               for k in m["counters"])


def test_trace_byte_identical_same_seed(small_lubm):
    a, _, _ = _traced_drain(small_lubm)
    b, _, _ = _traced_drain(small_lubm)
    assert a.tracer().to_json() == b.tracer().to_json()
    assert a.tracer().to_jsonl() == b.tracer().to_jsonl()


def test_trace_structure_identical_across_executors(small_lubm):
    traces = {}
    for name in EXECUTORS:
        svc, _, _ = _traced_drain(small_lubm, executor=name)
        traces[name] = svc.tracer()
    ref = traces["numpy"]
    for name in EXECUTORS[1:]:
        assert traces[name].structure() == ref.structure(), name
        # modeled durations derive from ExecStats.COMPARABLE, pinned
        # identical across backends -> the whole trace is byte-identical
        assert traces[name].to_json() == ref.to_json(), name


def test_untraced_service_records_nothing(small_lubm):
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(small_lubm.extended_workload())
    assert isinstance(svc._tracer, NullTracer)
    assert len(svc._tracer) == 0
    # ...but the metrics registry is always live
    assert svc.stats()["metrics"]["counters"]["queries.served"] > 0


def test_traced_flash_crowd_scenario():
    """A traced drift replay captures the reaction end-to-end: the round
    the controller fires, its drain, and every served query — and stays
    byte-identical across two same-seed replays."""
    from repro import scenario as drift
    from repro.graph import watdiv

    ds = watdiv.load(1, seed=0)
    scn = drift.flash_crowd(ds, warm=2, spike=2, cool=1,
                            queries_per_window=6, seed=3)

    def run():
        svc = KGService.from_dataset(ds, n_shards=4,
                                     migration_budget=1 << 20,
                                     replica_budget=1 << 20, trace=True)
        svc.bootstrap(scn.bootstrap_workload(ds))
        rep = drift.run_scenario(svc, scn, ds, adapt=True,
                                 mode="awapart/adaptive", warmup_phases=1)
        return svc, rep

    svc, rep = run()
    assert any(w.adapted for w in rep.windows)
    counts = svc.tracer().span_counts()
    # every reacted window is covered by a recorded round (warm-up and
    # rejected rounds may add more)
    assert counts["adapt.round"] >= sum(1 for w in rep.windows if w.adapted)
    assert counts["query"] > 0 and counts["window"] > 0
    rounds = svc.tracer().find("adapt.round")
    assert all(r["args"]["trigger"] in ("degradation", "write_drift",
                                        "no_baseline", "explicit")
               for r in rounds)
    svc2, _ = run()
    assert svc2.tracer().to_json() == svc.tracer().to_json()
