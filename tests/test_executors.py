"""Executor backends: numpy/jax equivalence on random BGPs and layouts
(property-based), the cartesian row cap, unified bytes-shipped accounting,
and the deprecated ``engine`` shims."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings, max_examples
from repro.api import KGService
from repro.core.features import FeatureSpace
from repro.core.migration import TRIPLE_BYTES
from repro.core.partition import hash_partition
from repro.graph.triples import Dictionary, build_store
from repro.query import engine
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.pattern import Query, is_var, var



def _assert_equivalent(res_a, res_b, label=""):
    (ba, sa), (bb, sb) = res_a, res_b
    assert canon_bindings(ba) == canon_bindings(bb), label
    for f in qexec.ExecStats.COMPARABLE:
        assert getattr(sa, f) == getattr(sb, f), (label, f)


def _random_dataset(rng, n_triples=400, n_pred=6, n_ent=40):
    d = Dictionary()
    for i in range(max(n_ent, n_pred)):
        d.encode(f"t{i}")
    t = np.stack([rng.integers(0, n_ent, n_triples),
                  rng.integers(0, n_pred, n_triples),
                  rng.integers(0, n_ent, n_triples)], axis=1).astype(np.int32)
    store = build_store(t, d)
    return store, FeatureSpace(store)


def _random_query(rng, store, name="R"):
    """Random BGP: chains/stars with shared vars, constant objects, repeated
    intra-pattern variables, occasional disconnected (cartesian) patterns and
    unbound predicates."""
    n_pat = int(rng.integers(1, 5))
    pats, pool, next_var = [], [], 0
    for _ in range(n_pat):
        row = store.triples[rng.integers(store.n_triples)]
        p = int(row[1]) if rng.random() > 0.1 else var(98)
        if pool and rng.random() < 0.7:
            s = pool[rng.integers(len(pool))]
        else:
            s, next_var = var(next_var), next_var + 1
        u = rng.random()
        if u < 0.45:
            o = int(row[2])
        elif u < 0.6 and pool:
            o = pool[rng.integers(len(pool))]
        elif u < 0.7:
            o = s                                 # (?x, p, ?x)
        else:
            o, next_var = var(next_var), next_var + 1
        pool += [x for x in (s, o) if is_var(x) and x not in pool]
        pats.append((s, p, o))
    return Query(name=name, patterns=tuple(pats))


@settings(max_examples=max_examples(15, 5), deadline=None)
@given(st.integers(0, 2**20))
def test_numpy_jax_equivalent_on_random_bgps(seed):
    """Property: for random stores, BGPs and layouts, NumpyExecutor and
    JaxExecutor produce identical bindings and ExecStats."""
    rng = np.random.default_rng(seed)
    store, space = _random_dataset(rng)
    state = hash_partition(space.feature_sizes(),
                           int(rng.integers(1, 7)), seed=seed % 17)
    sharded = engine.ShardedStore(store, space, state)
    for i in range(3):
        q = _random_query(rng, store, name=f"R{i}")
        plan = qplan.plan(q, sharded)
        ref = qexec.NumpyExecutor().run(plan, sharded)
        # probe_kernel=True pins the kernels' bit-equality (the jitted jnp
        # pack/search on "jax", the Pallas interpret-mode word-pair kernels
        # on "jax-pallas"); the default (auto) dispatches must agree too
        for jx in (qexec.JaxExecutor(probe_kernel=True),
                   qexec.JaxExecutor(),
                   qexec.JaxExecutor(pallas=True, probe_kernel=True),
                   qexec.JaxExecutor(pallas=True)):
            _assert_equivalent(ref, jx.run(plan, sharded),
                               (seed, jx.name, jx.probe_kernel, q.patterns))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**20))
def test_jax_batch_equals_per_query_runs(seed):
    """run_batch over a window == independent run() per plan."""
    rng = np.random.default_rng(seed)
    store, space = _random_dataset(rng)
    state = hash_partition(space.feature_sizes(), 4, seed=1)
    sharded = engine.ShardedStore(store, space, state)
    plans = [qplan.plan(_random_query(rng, store, name=f"R{i}"), sharded)
             for i in range(5)]
    ex = qexec.JaxExecutor()
    batch = ex.run_batch(plans, sharded)
    for plan, got in zip(plans, batch):
        _assert_equivalent(got, ex.run(plan, sharded), plan.query.name)


def _cartesian_fixture():
    rng = np.random.default_rng(7)
    store, space = _random_dataset(rng, n_triples=600)
    p0 = int(store.triples[0, 1])
    # two fully disconnected unbound-object patterns: |m0| x |m1| rows
    q = Query(name="X", patterns=((var(0), p0, var(1)),
                                  (var(2), p0, var(3))))
    state = hash_partition(space.feature_sizes(), 3, seed=0)
    return q, engine.ShardedStore(store, space, state)


@pytest.mark.parametrize("make", [qexec.NumpyExecutor, qexec.JaxExecutor])
def test_cartesian_cap_enforced(make):
    """The cross-product branch enforces a real row cap (clear error) and
    surfaces materialized cartesian rows in ExecStats."""
    q, sharded = _cartesian_fixture()
    plan = qplan.plan(q, sharded)
    assert plan.ops[1].cartesian
    n = plan.ops[0].est_rows * plan.ops[1].est_rows

    _, stats = make().run(plan, sharded)          # under the default cap
    assert stats.cartesian_rows == n > 0
    assert stats.rows == n

    with pytest.raises(qexec.JoinCapExceeded, match="cap"):
        make(max_join_rows=n - 1).run(plan, sharded)


@pytest.mark.parametrize("make", [
    qexec.NumpyExecutor,
    lambda: qexec.JaxExecutor(probe_kernel=True),
    qexec.JaxExecutor,
    lambda: qexec.JaxExecutor(pallas=True, probe_kernel=True),
    lambda: qexec.JaxExecutor(pallas=True),
])
def test_three_shared_vars_join_is_exact(make):
    """Regression: a base-2^31 pack of 3 shared vars wraps int64 and
    hash-equates rows whose leading key differs by 4 — the dense-rank
    reduction must keep the join exact."""
    d = Dictionary()
    for i in range(7):
        d.encode(f"t{i}")
    # (a,b,c) from p1=(0,1,2); p2=(?c,?b,?a) row (6,1,0) binds (a=0,b=1,c=6):
    # naive packed keys collide (diff = 4 * 2^62 == 0 mod 2^64), yet c != c'
    store = build_store(np.array([[0, 1, 2], [6, 1, 0]], np.int32), d)
    space = FeatureSpace(store)
    a, b, c = var(0), var(1), var(2)
    q = Query(name="tri", patterns=((a, b, c), (c, b, a)))
    sharded = engine.ShardedStore(store, space,
                                  hash_partition(space.feature_sizes(), 1, 0))
    bindings, stats = make().run(qplan.plan(q, sharded), sharded)
    assert stats.rows == 0
    assert canon_bindings(bindings) == []


def _ragged_fixture():
    """Two patterns whose shared variable always binds the same entity:
    the hash-join expansion materializes |m0| x |m1| pairs without being a
    cartesian plan op (every probe row matches every build row)."""
    d = Dictionary()
    for i in range(40):
        d.encode(f"t{i}")
    p, q_, hub = 1, 2, 5
    rows = [[3 + i, p, hub] for i in range(20)]
    rows += [[25 + j, q_, hub] for j in range(12)]
    store = build_store(np.array(rows, np.int32), d)
    space = FeatureSpace(store)
    x, y, z = var(0), var(1), var(2)
    q = Query(name="H", patterns=((x, p, y), (z, q_, y)))
    state = hash_partition(space.feature_sizes(), 3, seed=0)
    return q, engine.ShardedStore(store, space, state), 20 * 12


@pytest.mark.parametrize("make", [
    qexec.NumpyExecutor,
    qexec.JaxExecutor,
    lambda **kw: qexec.JaxExecutor(probe_kernel=True, **kw),
    lambda **kw: qexec.JaxExecutor(pallas=True, probe_kernel=True, **kw),
])
def test_ragged_expansion_cap_enforced(make):
    """The ragged hash-join expansion honors max_join_rows exactly like the
    cartesian path — clear error just under the total, expanded_rows
    surfaced in ExecStats at or above it — on every backend tier."""
    q, sharded, n = _ragged_fixture()
    plan = qplan.plan(q, sharded)
    assert not plan.ops[1].cartesian

    _, stats = make().run(plan, sharded)          # under the default cap
    assert stats.expanded_rows == n
    assert stats.rows == n
    assert stats.cartesian_rows == 0

    with pytest.raises(qexec.JoinCapExceeded, match=f"{n} rows"):
        make(max_join_rows=n - 1).run(plan, sharded)
    _, at_cap = make(max_join_rows=n).run(plan, sharded)
    assert at_cap.expanded_rows == n


def test_ragged_expansion_rows_profiled():
    """profile_from_plan records the expansion total and stats_from_profile
    re-accounts it — the COMPARABLE contract covers expanded_rows."""
    q, sharded, n = _ragged_fixture()
    plan = qplan.plan(q, sharded)
    with pytest.raises(qexec.JoinCapExceeded):
        qexec.profile_from_plan(plan, sharded.store, max_join_rows=n - 1)
    prof = qexec.profile_from_plan(plan, sharded.store)
    assert prof.expanded_rows == n
    est = qplan.stats_from_profile(q, prof, sharded.space, sharded.state,
                                   sharded.triple_shard)
    assert est.expanded_rows == n
    assert "expanded_rows" in qexec.ExecStats.COMPARABLE


def test_profile_honors_configured_join_cap(small_lubm):
    """The executor's max_join_rows threads through KGService into the
    facade's profiling, so adaptation never rejects a workload the serving
    executor was configured to allow."""
    q, sharded = _cartesian_fixture()
    plan = qplan.plan(q, sharded)
    n = plan.ops[0].est_rows * plan.ops[1].est_rows
    with pytest.raises(qexec.JoinCapExceeded):
        qexec.profile_from_plan(plan, sharded.store, max_join_rows=n - 1)
    prof = qexec.profile_from_plan(plan, sharded.store, max_join_rows=n)
    assert prof.cartesian_rows == n

    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 executor=qexec.NumpyExecutor(
                                     max_join_rows=123_456_789))
    kg = svc.bootstrap(small_lubm.base_workload())
    assert kg.max_join_rows == 123_456_789


def test_bytes_shipped_uses_triple_bytes_constant(small_lubm, space):
    """Executed and profiled stats charge shipping with the same constant:
    bytes_shipped == rows_shipped * TRIPLE_BYTES on every path."""
    space.track_workload(small_lubm.base_workload())
    state = hash_partition(space.feature_sizes(), 8, seed=0)
    sharded = engine.ShardedStore(small_lubm.store, space, state)
    for qname in ("Q2", "Q9", "EQ4"):
        q = small_lubm.queries[qname]
        plan = qplan.plan(q, sharded)
        for ex in (qexec.NumpyExecutor(), qexec.JaxExecutor()):
            _, stats = ex.run(plan, sharded)
            assert stats.bytes_shipped == stats.rows_shipped * TRIPLE_BYTES
        prof = qexec.profile_from_plan(plan, small_lubm.store)
        est = qplan.stats_from_profile(q, prof, space, state,
                                       sharded.triple_shard)
        assert est.bytes_shipped == est.rows_shipped * TRIPLE_BYTES
        assert est.bytes_shipped == stats.bytes_shipped


def test_deprecated_engine_shims_still_work(small_lubm, space):
    """The retired free functions warn but delegate to the new surface."""
    space.track_workload(small_lubm.base_workload())
    state = hash_partition(space.feature_sizes(), 4, seed=0)
    sharded = engine.ShardedStore(small_lubm.store, space, state)
    q = small_lubm.queries["Q6"]

    with pytest.warns(DeprecationWarning):
        bindings, stats = engine.execute(q, sharded)
    ref_b, ref_s = qexec.NumpyExecutor().run(qplan.plan(q, sharded), sharded)
    assert canon_bindings(bindings) == canon_bindings(ref_b)
    assert stats.rows == ref_s.rows

    with pytest.warns(DeprecationWarning):
        times, _ = engine.run_workload([q], sharded)
    assert times[q.name] == pytest.approx(stats.modeled_time())

    with pytest.warns(DeprecationWarning):
        avg = engine.workload_average_time([q], sharded)
    assert avg == pytest.approx(times[q.name])

    with pytest.warns(DeprecationWarning):
        prof = engine.profile_query(q, small_lubm.store)
    with pytest.warns(DeprecationWarning):
        est = engine.stats_from_profile(q, prof, space, state,
                                        sharded.triple_shard)
    assert est.rows == stats.rows
    assert est.bytes_shipped == stats.bytes_shipped


def test_executor_registry_resolves_jax_pallas():
    """executor="jax-pallas" threads through get_executor / KGService and
    names itself distinctly in telemetry."""
    ex = qexec.get_executor("jax-pallas")
    assert isinstance(ex, qexec.JaxExecutor) and ex.pallas
    assert ex.name == "jax-pallas"
    assert qexec.get_executor("jax").name == "jax"
    with pytest.raises(ValueError, match="jax-pallas"):
        qexec.get_executor("pallas")


@pytest.mark.parametrize("probe_kernel", [True, None])
def test_pallas_join_empty_probe_and_zero_match_edges(probe_kernel):
    """The kernel path's padding must be inert at the raggedest edges: a
    pattern with zero matches (empty probe side mid-pipeline) and a join
    whose keys never meet (zero-match probe) both agree with numpy."""
    d = Dictionary()
    for i in range(9):
        d.encode(f"t{i}")
    p, q = 1, 2
    # p-objects are {2, 4}; q-subjects are {5, 7}: disjoint on purpose
    store = build_store(np.array([[0, p, 2], [3, p, 4],
                                  [5, q, 6], [7, q, 8]], np.int32), d)
    space = FeatureSpace(store)
    state = hash_partition(space.feature_sizes(), 3, seed=2)
    sharded = engine.ShardedStore(store, space, state)
    x, y, z = var(0), var(1), var(2)
    queries = [
        # second pattern matches zero rows (0 is never a p-object) -> the
        # probe side of the join is empty
        Query(name="E0", patterns=((x, p, y), (y, p, 0))),
        # both patterns match rows, but the shared variable's key sets are
        # disjoint ({2,4} vs {5,7}) -> a zero-match probe
        Query(name="E1", patterns=((x, p, y), (y, q, z))),
        # empty from the first op
        Query(name="E2", patterns=((x, p, 0), (y, q, z))),
    ]
    jx = qexec.JaxExecutor(pallas=True, probe_kernel=probe_kernel)
    for q in queries:
        plan = qplan.plan(q, sharded)
        ref = qexec.NumpyExecutor().run(plan, sharded)
        got = jx.run(plan, sharded)
        _assert_equivalent(ref, got, q.name)
        assert got[1].rows == 0


def test_pallas_batch_equals_per_query_runs():
    """jax-pallas run_batch over a window == independent run() per plan
    (window dedup + kernel probe don't change results)."""
    rng = np.random.default_rng(23)
    store, space = _random_dataset(rng)
    state = hash_partition(space.feature_sizes(), 4, seed=1)
    sharded = engine.ShardedStore(store, space, state)
    plans = [qplan.plan(_random_query(rng, store, name=f"P{i}"), sharded)
             for i in range(4)]
    ex = qexec.JaxExecutor(pallas=True, probe_kernel=True)
    for plan, got in zip(plans, ex.run_batch(plans, sharded)):
        _assert_equivalent(got, ex.run(plan, sharded), plan.query.name)
