"""Feature extraction, scoring, migration: unit + hypothesis invariants."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings

from repro.api import (AWAPartitioner, HashPartitioner, KGService,
                       WawPartitioner)
from repro.core import migration
from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState, greedy_balance, hash_partition
from repro.core.scoring import distributed_joins, score_matrix, workload_stats
from repro.graph import watdiv


def test_feature_extraction_fig1(small_lubm, space):
    """Fig. 1: Q2 has 6 features (3 PO + 3 P), Q8 has 5 (2 PO + 3 P)."""
    space.track_workload(small_lubm.base_workload())
    q2 = space.query_features(small_lubm.queries["Q2"], fine=False)
    q8 = space.query_features(small_lubm.queries["Q8"], fine=False)
    assert len(q2) == 6
    assert len(q8) == 5
    inter = len(np.intersect1d(q2, q8))
    union = len(np.union1d(q2, q8))
    # paper: J_sim(Q2, Q8) = 3/8 -> distance 0.625
    assert inter == 3 and union == 8


def test_triple_owners_cover_everything(small_lubm, space):
    owners = space.triple_owners()
    assert owners.shape[0] == small_lubm.store.n_triples
    assert (owners >= 0).all() and (owners < space.n_features).all()
    sizes = space.feature_sizes(owners)
    assert sizes.sum() == small_lubm.store.n_triples


def test_tracking_po_splits_parent(small_lubm, space):
    d = small_lubm.dictionary
    p_takes = d.lookup("ub:takesCourse")
    before = space.feature_sizes()[space.p_index(p_takes)]
    idx = space.track_po(p_takes, small_lubm.named.grad_course0)
    sizes = space.feature_sizes()
    assert sizes[idx] > 0
    assert sizes[space.p_index(p_takes)] == before - sizes[idx]


def test_greedy_balance(rng):
    sizes = rng.integers(1, 1000, size=60).astype(np.int64)
    state = PartitionState(np.zeros(60, np.int32), sizes, 8)  # all on shard 0
    greedy_balance(state, np.arange(60), tolerance=1.2)
    assert state.imbalance() < 1.5


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_migration_conserves_triples(seed, n_shards):
    rng = np.random.default_rng(seed)
    n_feat = int(rng.integers(5, 40))
    sizes = rng.integers(0, 500, size=n_feat).astype(np.int64)
    old = hash_partition(sizes, n_shards, seed=seed)
    new = old.copy()
    moved = rng.random(n_feat) < 0.4
    new.feature_to_shard[moved] = rng.integers(0, n_shards, moved.sum())
    plan = migration.plan(old, new)
    # conservation: total triples unchanged, per-feature single copy
    assert old.shard_sizes().sum() == new.shard_sizes().sum() == sizes.sum()
    # plan covers exactly the changed features
    changed = set(np.where(old.feature_to_shard != new.feature_to_shard)[0])
    assert {m[0] for m in plan.moves} == changed
    assert plan.bytes == plan.n_triples * migration.TRIPLE_BYTES


def test_extend_state_inherits_parent_shard():
    sizes = np.array([10, 20, 30], np.int64)
    state = PartitionState(np.array([0, 1, 2], np.int32), sizes, 3)
    new_sizes = np.array([4, 20, 30, 6], np.int64)  # feature 3 split from 0
    ext = migration.extend_state(state, new_sizes, parent_of_new=[0])
    assert ext.feature_to_shard[3] == state.feature_to_shard[0]
    assert ext.shard_sizes().sum() == new_sizes.sum()


def test_strategies_serve_identical_bindings_on_watdiv():
    """Cross-strategy regression pin: hash, wawpart and awapart layouts of
    the same WatDiv graph serve byte-identical bindings for the whole
    template workload — partitioning moves cost around (messages, shipped
    rows), never answers."""
    ds = watdiv.load(1, seed=0)
    window = ds.base_workload()
    ref, ref_rows = None, None
    costs = {}
    for part in (HashPartitioner(seed=1), WawPartitioner(),
                 AWAPartitioner()):
        svc = KGService(ds.store, 4, part, executor="numpy",
                        type_predicate=ds.dictionary.lookup("rdf:type"))
        svc.bootstrap(window)
        results = svc.query_batch(window)
        got = [canon_bindings(b) for b, _ in results]
        if ref is None:
            ref, ref_rows = part.name, got
            assert all(got), "reference strategy served an empty template"
        else:
            assert got == ref_rows, f"{part.name} bindings differ from {ref}"
        costs[part.name] = sum(s.messages for _, s in results)
    # costs are allowed to differ (that is the whole point of the
    # strategies); they just have to be accounted consistently
    assert sorted(costs) == ["awapart", "hash", "wawpart"]
    assert all(c >= 0 for c in costs.values())


def test_scoring_prefers_colocation(small_lubm, space):
    queries = small_lubm.base_workload()
    space.track_workload(queries)
    stats = workload_stats(queries, space)
    sizes = space.feature_sizes()
    state = hash_partition(sizes, 4, seed=1)
    scores = score_matrix(stats, state)
    assert scores.shape == (len(stats.key_features), 4)
    # moving every key feature to its argmax shard must not increase the
    # frequency-weighted distributed join count
    dj0 = distributed_joins(stats, state)
    new = state.copy()
    for ki, k in enumerate(stats.key_features.tolist()):
        new.feature_to_shard[k] = int(np.argmax(scores[ki]))
    dj1 = distributed_joins(stats, new)
    assert dj1 <= dj0
