"""Per-arch smoke tests (reduced configs) + structural model properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm, rwkv, ssm, transformer
from repro.optim import AdamWConfig


@pytest.fixture(scope="module")
def opt_cfg():
    return AdamWConfig(total_steps=10, warmup_steps=2)


def _batch_for(cfg, rng, b=2, s=16):
    if cfg.embedding_inputs:
        return {
            "embeddings": jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "mask": jnp.asarray(rng.random((b, s)) < 0.3),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_train_step(arch, rng, opt_cfg):
    """One forward/train step on CPU: output shapes + no NaNs (deliverable f)."""
    cfg = configs.get(arch).reduced()
    params, axes, opt_state = lm.init_all(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, rng)
    p2, o2, metrics = lm.train_step(params, opt_state, batch, cfg, None,
                                    opt_cfg)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = jax.tree.reduce(
        lambda acc, pair: acc, [True])
    flat0 = jax.tree.leaves(params)
    flat1 = jax.tree.leaves(p2)
    assert any(
        (np.asarray(a) != np.asarray(b)).any()
        for a, b in zip(flat0, flat1)
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating))


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get(a).has_decode])
def test_arch_smoke_prefill_decode(arch, rng):
    cfg = configs.get(arch).reduced()
    params, _, _ = lm.init_all(jax.random.PRNGKey(0), cfg, opt=False)
    batch = _batch_for(cfg, rng)
    logits_last, caches = lm.prefill_step(params, batch, cfg, None)
    assert logits_last.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits_last, -1).astype(jnp.int32)
    logits, caches = lm.decode_step(
        params, caches, {"token": tok, "pos": jnp.asarray(16, jnp.int32)},
        cfg, None)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["smollm-360m", "zamba2-7b", "rwkv6-3b",
                                  "qwen2.5-32b"])
def test_prefill_decode_consistent_with_forward(arch, rng):
    """Teacher-forced decode after prefill == full forward logits."""
    cfg = configs.get(arch).reduced()
    params, _, _ = lm.init_all(jax.random.PRNGKey(0), cfg, opt=False)
    # S=16: divisible by the reduced ssm_chunk (8) for the hybrid arch
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    full_logits, _, _ = transformer.forward(params, toks, cfg, None)
    _, caches = lm.prefill_step(params, {"tokens": toks[:, :8]}, cfg, None)
    # caches for attention archs are sized to the prefill length; decode
    # writes at pos >= that length require a bigger cache — re-init at 12
    if "k" in (caches or {}):
        big = transformer.init_decode_caches(cfg, 2, 16)
        # keep non-KV state (hybrid conv/ssm) from the prefill
        for key in caches:
            if key not in ("k", "v"):
                big[key] = caches[key]
        big["k"] = big["k"].at[:, :, :8].set(caches["k"])
        big["v"] = big["v"].at[:, :, :8].set(caches["v"])
        caches = big
    logits = None
    for pos in range(8, 16):
        logits, caches = transformer.decode_step(
            params, caches, toks[:, pos], jnp.asarray(pos, jnp.int32), cfg,
            None)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, -1]), atol=2e-3)


def test_unrolled_equals_scanned(rng):
    for arch in ("smollm-360m", "zamba2-7b", "rwkv6-3b", "olmoe-1b-7b"):
        cfg = configs.get(arch).reduced()
        cfg_u = dataclasses.replace(cfg, scan_layers=False)
        params, _, _ = lm.init_all(jax.random.PRNGKey(0), cfg, opt=False)
        batch = _batch_for(cfg, rng)
        l1, _ = lm.loss_fn(params, batch, cfg, None)
        l2, _ = lm.loss_fn(params, batch, cfg_u, None)
        assert abs(float(l1) - float(l2)) < 1e-5, arch


def test_flash_path_matches_reference_attention(rng):
    cfg = configs.get("qwen3-0.6b").reduced()
    cfg_flash = dataclasses.replace(cfg, use_flash=True)
    params, _, _ = lm.init_all(jax.random.PRNGKey(0), cfg, opt=False)
    batch = _batch_for(cfg, rng, s=32)
    l0, _ = lm.loss_fn(params, batch, cfg, None)
    l1, _ = lm.loss_fn(params, batch, cfg_flash, None)
    assert abs(float(l0) - float(l1)) < 1e-3


def test_mamba2_chunked_matches_decode(rng):
    cfg = configs.get("zamba2-7b").reduced()
    p, _ = ssm.mamba2_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.5, jnp.float32)
    y_chunk, st = ssm.mamba2_apply(p, x, cfg, return_state=True)
    state = ssm.mamba2_init_state(cfg, 2)
    ys = []
    for t in range(16):
        y_t, state = ssm.mamba2_decode(p, x[:, t], state, cfg)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_chunk), atol=2e-4)
    # prefill-collected state matches the sequentially-built one
    np.testing.assert_allclose(np.asarray(st["ssm"]), np.asarray(state["ssm"]),
                               atol=2e-4)


def test_rwkv_train_matches_decode(rng):
    cfg = configs.get("rwkv6-3b").reduced()
    p, _ = rwkv.rwkv_block_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 12, cfg.d_model)) * 0.5, jnp.float32)
    y_train = rwkv.rwkv_block_apply(p, x, cfg)
    st = rwkv.rwkv_init_state(cfg, 2)
    ys = []
    for t in range(12):
        y_t, st = rwkv.rwkv_block_decode(p, x[:, t], st, cfg)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_train), atol=1e-4)


def test_param_counts_match_analytic():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        # structural check on the reduced config (full would allocate GBs)
        red = cfg.reduced()
        params, _, _ = lm.init_all(jax.random.PRNGKey(0), red, opt=False)
        actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params)
                     if hasattr(p, "shape"))
        analytic = red.n_params()
        assert abs(actual - analytic) / max(actual, 1) < 0.35, \
            (arch, actual, analytic)
