"""repro.stream: continuous admission, pipelined windows, the background
drainer, tail-latency telemetry — and THE streaming acceptance property:
streamed admission (arbitrary arrival order, writes and migration chunks
drained mid-stream) produces bindings byte-identical to synchronous
``query_batch`` over the same admission order, on numpy/jax/jax-pallas,
at every epoch."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings
from test_executors import _random_dataset, _random_query
from test_write_path import _random_batch

from repro import stream as kgstream
from repro.api import (HashPartitioner, KGService, MigrationSession,
                       StreamService, WriteBatch)
from repro.core import migration
from repro.core.partition import hash_partition
from repro.graph.triples import TripleStore
from repro.query import exec as qexec
from repro.replicate import ReplicaMap
from repro.stream import (LatencyRecorder, QueryLatency, interleave,
                          open_loop_arrivals, poisson_arrivals,
                          percentile_summary, replay)

EXECUTORS = ("numpy", "jax", "jax-pallas")


def _fresh_service(ds, n_shards=4, **kwargs):
    """A KGService over a COPY of the (memoized) dataset's store — the
    write path mutates stores in place, and equivalence twins must not
    share one."""
    store = TripleStore(ds.store.triples.copy(), ds.store.dictionary)
    return KGService(store, n_shards,
                     type_predicate=ds.dictionary.lookup("rdf:type"),
                     **kwargs)


# --------------------------------------------------------------------------- #
# telemetry
# --------------------------------------------------------------------------- #

def test_percentile_summary_shape():
    s = percentile_summary([])
    assert s == dict(n=0, mean=0.0, p50=0.0, p95=0.0, p99=0.0, max=0.0)
    s = percentile_summary(np.linspace(0.0, 1.0, 101))
    assert s["n"] == 101 and s["max"] == 1.0
    assert s["p50"] == pytest.approx(0.5)
    assert s["p95"] == pytest.approx(0.95)
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_latency_recorder_grouping(tmp_path):
    rec = LatencyRecorder()
    for i in range(10):
        rec.record(QueryLatency(
            seq=i, name=f"Q{i}", window=i // 5, shard=i % 2,
            arrival_s=0.1 * i, start_s=0.1 * i + 0.05,
            finish_s=0.1 * i + 0.05 + 0.01 * (i + 1), epoch=0,
            cached=False))
    assert len(rec) == 10
    assert rec.summary()["n"] == 10
    per_w = rec.per_window()
    assert sorted(per_w) == [0, 1] and per_w[0]["n"] == 5
    per_s = rec.per_shard()
    assert sorted(per_s) == [0, 1]
    # latency = queue + service; the record exposes both
    r = rec.records[3]
    assert r.latency_s == pytest.approx(r.queue_s + (r.finish_s - r.start_s))
    # CSV export: one row per window, constants prepended
    path = tmp_path / "lat.csv"
    n = rec.to_csv(path, mode="pipelined", rate_qps=10)
    text = path.read_text().splitlines()
    assert n == 2 and len(text) == 3
    assert text[0].startswith("mode,rate_qps,window,n,p50_ms,p95_ms,p99_ms")


def test_arrival_processes():
    arr = open_loop_arrivals(5, rate_qps=10.0, start=1.0)
    assert np.allclose(np.diff(arr), 0.1) and arr[0] == 1.0
    rng = np.random.default_rng(0)
    poi = poisson_arrivals(100, rate_qps=10.0, rng=rng)
    assert (np.diff(poi) >= 0).all()
    assert np.mean(np.diff(poi)) == pytest.approx(0.1, rel=0.5)


# --------------------------------------------------------------------------- #
# admission mechanics
# --------------------------------------------------------------------------- #

def test_stream_serves_and_matches_query_batch(small_lubm):
    window = small_lubm.extended_workload()
    svc_sync = _fresh_service(small_lubm)
    svc_sync.bootstrap(small_lubm.base_workload())
    ref = {q.name: canon_bindings(b)
           for q, (b, _) in zip(window, svc_sync.query_batch(window))}

    svc = _fresh_service(small_lubm)
    svc.bootstrap(small_lubm.base_workload())
    stream = svc.stream(max_window=7)
    seqs = [stream.submit(q) for q in window]
    assert seqs == list(range(len(window)))
    assert stream.pending == len(window)
    served = stream.run_until_idle()
    assert stream.pending == 0
    results = stream.poll()
    assert [r.seq for r in results] == seqs          # completion in order
    assert stream.poll() == []                       # drained
    for q, r in zip(window, results):
        assert canon_bindings(r.bindings) == ref[q.name], q.name
    # telemetry surfaced through the recorder and KGService.stats()
    assert served is stream.recorder and len(served) == len(window)
    assert stream.n_windows == int(np.ceil(len(window) / 7))
    stats = svc.stats()
    assert stats["latency"]["n"] == len(window)
    assert set(stats["latency_per_shard"]) <= set(range(svc.n_shards))
    assert all(s["p50"] <= s["p95"] <= s["p99"]
               for s in stats["latency_per_shard"].values())


def test_window_never_spans_a_write(small_lubm):
    """A write admitted between two queries splits the window: the second
    query is served post-write even though both arrived together."""
    svc = _fresh_service(small_lubm)
    svc.bootstrap(small_lubm.base_workload())
    q = small_lubm.queries["Q1"]
    d = small_lubm.dictionary
    tp, take = d.lookup("rdf:type"), d.lookup("ub:takesCourse")
    cls = d.lookup("ub:GraduateStudent")
    s = int(svc.fresh_ids(1)[0])
    batch = WriteBatch(inserts=[[s, tp, cls],
                                [s, take, small_lubm.named.grad_course0]])
    stream = svc.stream()
    stream.submit(q, at=0.0)
    stream.submit_write(batch, at=0.0)
    stream.submit(q, at=0.0)
    stream.run_until_idle()
    first, second = stream.poll()
    assert len(second.bindings[next(iter(second.bindings))]) \
        == len(first.bindings[next(iter(first.bindings))]) + 1
    assert second.latency.epoch > first.latency.epoch
    assert stream.n_windows == 2


def test_arrival_clock_and_monotone_clamp(small_lubm):
    svc = _fresh_service(small_lubm)
    svc.bootstrap(small_lubm.base_workload())
    stream = svc.stream()
    q = small_lubm.queries["Q1"]
    stream.submit(q, at=5.0)
    stream.submit(q, at=1.0)             # out-of-order timestamp: clamped
    assert [ev.arrival_s for ev in stream._queue] == [5.0, 5.0]
    stream.run_until_idle()
    a, b = stream.poll()
    assert a.latency.arrival_s == 5.0 and b.latency.arrival_s == 5.0
    # the clock idled up to the first arrival; one window, repeat cached
    assert stream.now >= 5.0 and b.latency.cached is False  # same window,
    # both executed in the same batch -> the repeat is a plan-level dedup?
    # no: same window executes misses once, the second is a result-cache hit
    # only across windows; within one batch both index the same miss list
    assert stream.n_windows == 1


def test_pipelined_hides_stalls_sync_does_not(small_lubm):
    """Same admission order, migration in flight: pipeline=True finishes
    no later and serves a no-worse p95 than pipeline=False, with
    byte-identical bindings."""
    window = small_lubm.extended_workload()

    def run(pipeline):
        svc = _fresh_service(small_lubm, migration_budget=120_000)
        svc.bootstrap(small_lubm.base_workload())
        svc.query_batch(window)
        rep = svc.adapt(small_lubm.workload(
            [f"EQ{i}" for i in range(1, 11)]))
        assert rep.accepted and svc.session is not None
        stream = svc.stream(pipeline=pipeline, max_window=8)
        events = interleave(
            window * 2, open_loop_arrivals(len(window) * 2, 40.0))
        replay(stream, events)
        return svc, stream, stream.poll()

    svc_p, sp, res_p = run(True)
    svc_s, ss, res_s = run(False)
    for a, b in zip(res_p, res_s):
        assert a.query.name == b.query.name
        assert canon_bindings(a.bindings) == canon_bindings(b.bindings)
    assert sp.now <= ss.now
    assert sp.recorder.summary()["p95"] <= ss.recorder.summary()["p95"]
    # the pipelined run hid stall time behind execution
    hidden = sum(w["hidden_s"] for w in sp.window_log)
    assert hidden > 0
    assert all(w["hidden_s"] == 0.0 for w in ss.window_log)


def test_idle_gaps_drain_migration(small_lubm):
    """Widely-spaced arrivals: the pipelined drainer retires extra chunks
    inside the idle gaps, finishing the migration strictly earlier than
    the one-chunk-per-window baseline discipline would."""
    window = small_lubm.extended_workload()
    svc = _fresh_service(small_lubm, migration_budget=60_000)
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(window)
    rep = svc.adapt(small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    assert rep.accepted and svc.session is not None
    n_chunks = svc.session.n_chunks
    assert n_chunks >= 4
    stream = svc.stream(pipeline=True, max_window=4)
    # 3 sparse windows of 4 queries, 2 seconds apart — gap >> chunk stall
    for i, q in enumerate(window[:12]):
        stream.submit(q, at=2.0 * (i // 4))
    stream.run_until_idle()
    assert svc.session is None, "idle gaps should have finished the drain"
    assert stream.n_windows == 3
    drained = sum(1 for w in stream.window_log if w["chunk_bytes"] > 0)
    assert drained <= stream.n_windows < n_chunks


def test_prestaging_survives_quiet_windows(small_lubm):
    """With no mutations in flight, window N+1's plans are pre-staged
    during window N and used as cache hits (no rebuilds)."""
    window = small_lubm.extended_workload()
    svc = _fresh_service(small_lubm)
    svc.bootstrap(small_lubm.base_workload())
    stream = svc.stream(pipeline=True, max_window=6)
    for q in window:
        stream.submit(q, at=0.0)
    stream.run_until_idle()
    assert stream.prestage_hits > 0
    # plan cost was charged exactly once per distinct query
    assert svc.kg.plan_builds == len(window)


# --------------------------------------------------------------------------- #
# THE acceptance property (satellite: hypothesis interleaving test)
# --------------------------------------------------------------------------- #

def _twin(seed, executor, n_shards=4):
    """Deterministic service twin: same seed -> identical store, layout,
    in-flight migration session (with replica promotions) and executor."""
    rng = np.random.default_rng(seed)
    store, _ = _random_dataset(rng, n_triples=300)
    svc = KGService(store, n_shards, HashPartitioner(), executor=executor)
    svc.bootstrap(())
    sizes = svc.space.feature_sizes()
    target = hash_partition(sizes, n_shards,
                            seed=int(rng.integers(1 << 16)))
    target_replicas = ReplicaMap.primary_only(target)
    for f in range(len(target.feature_to_shard)):
        if rng.random() < 0.3:
            target_replicas.add(f, int(rng.integers(n_shards)))
    budget = max(int(sizes.sum()) * migration.TRIPLE_BYTES // 5, 1)
    svc.session = MigrationSession(svc.kg, target, bytes_budget=budget,
                                   target_replicas=target_replicas)
    return svc


def _script(seed, n_events=8):
    """Generate the admission script once, against a scratch twin that
    applies writes as it generates them (deletes sample the evolving
    store), capturing raw arrays so every replay sees identical events."""
    rng = np.random.default_rng(seed)
    scratch = _twin(seed, "numpy")
    queries = [_random_query(rng, scratch.store, name=f"R{i}")
               for i in range(3)]
    events, t = [], 0.0
    for _ in range(n_events):
        t += float(rng.choice([0.0, 0.01, 0.5]))
        if rng.random() < 0.4:
            batch = _random_batch(rng, scratch.kg)
            scratch.write(batch)
            events.append((t, WriteBatch(batch.inserts.copy(),
                                         batch.deletes.copy())))
        else:
            events.append((t, queries[int(rng.integers(len(queries)))]))
    if not any(isinstance(p, WriteBatch) for _, p in events):
        batch = _random_batch(rng, scratch.kg)
        scratch.write(batch)
        events.append((t, WriteBatch(batch.inserts.copy(),
                                     batch.deletes.copy())))
    return events


def _sync_replay(svc, events):
    """Synchronous admission-order baseline: writes apply in place, runs
    of consecutive queries execute as query_batch windows."""
    out, pending = [], []

    def flush():
        if pending:
            for b, _ in svc.query_batch(list(pending)):
                out.append(canon_bindings(b))
            pending.clear()

    for _, payload in events:
        if isinstance(payload, WriteBatch):
            flush()
            svc.write(WriteBatch(payload.inserts.copy(),
                                 payload.deletes.copy()))
            out.append(None)
        else:
            pending.append(payload)
    flush()
    return out


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_streamed_admission_matches_synchronous_batch(seed):
    """THE streaming acceptance property: arbitrary arrival order, writes
    and migration/replica chunks drained mid-stream — streamed bindings
    are byte-identical to synchronous ``query_batch`` over the same
    admission order, per executor, at every epoch along the way."""
    events = _script(seed)
    pipeline = bool(seed % 2)
    max_window = int(np.random.default_rng(seed).integers(1, 5))
    per_exec = {}
    for name in EXECUTORS:
        sync = _sync_replay(_twin(seed, name), events)

        svc = _twin(seed, name)
        stream = StreamService(svc, pipeline=pipeline,
                               max_window=max_window)
        replay(stream, [(at, (p if isinstance(p, WriteBatch)
                              else p)) for at, p in events])
        got = {r.seq: canon_bindings(r.bindings) for r in stream.poll()}
        for i, (at, payload) in enumerate(events):
            if isinstance(payload, WriteBatch):
                assert i not in got
            else:
                assert got[i] == sync[i], \
                    (seed, name, i, payload.name)
        per_exec[name] = [got[i] for i in sorted(got)]
        # streamed queries were recorded with monotone finish times
        fins = [r.finish_s for r in stream.recorder.records]
        assert fins == sorted(fins)
    assert per_exec["numpy"] == per_exec["jax"] == per_exec["jax-pallas"]


def test_stream_with_service_adaptation_loop(small_lubm):
    """End-to-end: bootstrapped adaptive service, accepted round with a
    budgeted session, writes + queries streamed while the drain retires —
    final layout lands exactly on the accepted target."""
    window = small_lubm.extended_workload()
    svc = _fresh_service(small_lubm, migration_budget=120_000,
                         replica_budget=256_000)
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(window)
    report = svc.adapt(small_lubm.workload(
        [f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted and svc.session is not None
    sess = svc.session
    rng = np.random.default_rng(0)
    t = svc.store.triples.copy()
    stream = svc.stream(pipeline=True, max_window=len(window))
    at = 0.0
    for _ in range(sess.n_chunks + 1):
        rows = t[rng.integers(0, len(t), 32)].copy()
        rows[:, 0] = svc.fresh_ids(len(rows)).astype(np.int32)
        stream.submit_write(WriteBatch(inserts=rows), at=at)
        for q in window:
            stream.submit(q, at=at)
        at += 0.5
    stream.run_until_idle()
    assert svc.session is None
    nf = len(sess.target.feature_to_shard)
    assert np.array_equal(svc.kg.state.feature_to_shard[:nf],
                          sess.target.feature_to_shard)
    assert svc.write_log.n_inserted > 0
    assert svc.stats()["latency"]["n"] == len(window) * (sess.n_chunks + 1)
