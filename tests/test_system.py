"""End-to-end behaviour of the paper's system (Fig. 6 master-node loop),
orchestrated through the public ``repro.api`` surface."""
import numpy as np

from repro.api import KGService
from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.features import FeatureSpace


def test_full_awapart_loop(lubm3):
    """Initial partition -> serve -> workload change -> adapt -> improve."""
    svc = KGService.from_dataset(lubm3, n_shards=8)
    kg = svc.bootstrap(lubm3.base_workload())

    # balanced initial partition (oversized single features bound this)
    assert kg.imbalance() < 2.5
    assert sum(kg.shard_sizes()) == lubm3.store.n_triples

    # serve the extended workload, record runtimes (TM metadata)
    extended = lubm3.extended_workload()
    times0, stats0 = svc.run_workload(extended)
    for q in extended:
        svc.observe(q, times0[q.name])
    assert svc.avg_execution_time() > 0

    report = svc.adapt(lubm3.workload([f"EQ{i}" for i in range(1, 11)]))
    # the guard guarantees no regression on the measured objective
    if report.accepted:
        assert report.t_new < report.t_base
        assert report.plan.n_moves > 0
        assert report.dj_after <= report.dj_before
        assert report.n_clusters > 0
    else:
        assert report.plan.n_moves == 0

    # the facade serves the adapted layout in place (incremental delta)
    dj0 = sum(s.distributed_joins for s in stats0.values())
    _, stats1 = svc.run_workload(extended)
    dj1 = sum(s.distributed_joins for s in stats1.values())
    if report.accepted:
        assert dj1 <= dj0


def test_should_adapt_threshold(small_lubm):
    space = FeatureSpace(small_lubm.store,
                         type_predicate=small_lubm.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=4,
                             config=AdaptConfig(adapt_threshold=1.5))
    q = small_lubm.queries["Q6"]
    ctrl.reset_baseline(0.1)
    ctrl.observe(q, 0.1)
    assert not ctrl.should_adapt()
    ctrl.exec_times[q.name] = [0.4]     # 4x degradation
    assert ctrl.should_adapt()


def test_service_threshold_loop(small_lubm):
    """Service-level TM loop: baseline reset forces the next round."""
    svc = KGService.from_dataset(
        small_lubm, n_shards=4,
        config=AdaptConfig(adapt_threshold=1.5))
    svc.bootstrap(small_lubm.base_workload())
    svc.reset_baseline(0.1)
    q = small_lubm.queries["Q6"]
    svc.observe(q, 0.1)
    assert not svc.should_adapt()
    assert svc.maybe_adapt() is None          # within threshold: no round
    svc.observe(q, 10.0)                      # massive degradation
    assert svc.should_adapt()
    svc.reset_baseline()                      # clearing also forces a round
    assert svc.should_adapt()
