"""End-to-end behaviour of the paper's system (Fig. 6 master-node loop)."""
import numpy as np

from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.features import FeatureSpace
from repro.query import engine


def test_full_awapart_loop(lubm3):
    """Initial partition -> serve -> workload change -> adapt -> improve."""
    space = FeatureSpace(lubm3.store,
                         type_predicate=lubm3.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=8)
    base = lubm3.base_workload()
    space.track_workload(base)
    state0 = ctrl.initial_partition(base)

    # balanced initial partition (oversized single features bound this)
    assert state0.imbalance() < 2.5
    sharded0 = engine.ShardedStore(lubm3.store, space, state0)
    assert sum(sharded0.shard_sizes()) == lubm3.store.n_triples

    # serve the extended workload, record runtimes (TM metadata)
    extended = lubm3.extended_workload()
    times0, stats0 = engine.run_workload(extended, sharded0)
    for q in extended:
        ctrl.observe(q, times0[q.name])
    assert ctrl.avg_execution_time() > 0

    def measure(cand):
        sh = engine.ShardedStore(lubm3.store, space, cand)
        return engine.workload_average_time(list(ctrl.workload.values()), sh)

    state1, report = ctrl.adapt(
        lubm3.workload([f"EQ{i}" for i in range(1, 11)]), measure=measure)
    # the guard guarantees no regression on the measured objective
    if report.accepted:
        assert report.t_new < report.t_base
        assert report.plan.n_moves > 0
        assert report.dj_after <= report.dj_before
    else:
        assert report.plan.n_moves == 0

    sharded1 = engine.ShardedStore(lubm3.store, space, state1)
    dj0 = sum(s.distributed_joins for s in stats0.values())
    _, stats1 = engine.run_workload(extended, sharded1)
    dj1 = sum(s.distributed_joins for s in stats1.values())
    if report.accepted:
        assert dj1 <= dj0


def test_should_adapt_threshold(small_lubm):
    space = FeatureSpace(small_lubm.store,
                         type_predicate=small_lubm.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=4,
                             config=AdaptConfig(adapt_threshold=1.5))
    q = small_lubm.queries["Q6"]
    ctrl._baseline_avg = 0.1
    ctrl.observe(q, 0.1)
    assert not ctrl.should_adapt()
    ctrl.exec_times[q.name] = [0.4]     # 4x degradation
    assert ctrl.should_adapt()
