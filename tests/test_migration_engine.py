"""Online migration engine: chunked MigrationSessions, dual-layout serving
correctness at every intermediate epoch, the migration-cost-aware accept
guard, and the TM/plan-cache satellites."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings
from test_executors import _random_dataset, _random_query

from repro.api import (HashPartitioner, KGService, MigrationSession,
                       PartitionedKG)
from repro.core import migration
from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.partition import hash_partition
from repro.query import exec as qexec
from repro.query import plan as qplan


# --------------------------------------------------------------------------- #
# chunking
# --------------------------------------------------------------------------- #

def _random_plan(rng, n_feat=30, n_shards=5):
    sizes = rng.integers(0, 400, size=n_feat).astype(np.int64)
    old = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    new = old.copy()
    moved = rng.random(n_feat) < 0.5
    new.feature_to_shard[moved] = rng.integers(0, n_shards, moved.sum())
    return old, new, migration.plan(old, new), sizes


@given(st.integers(0, 2 ** 20), st.integers(1, 5000))
@settings(max_examples=25, deadline=None)
def test_chunk_plan_partitions_moves_within_budget(seed, budget):
    """Chunks cover the plan's moves exactly once, conserve bytes, and each
    chunk fits the budget unless it is a single oversized move."""
    rng = np.random.default_rng(seed)
    _, _, plan, sizes = _random_plan(rng)
    chunks = migration.chunk_plan(plan, sizes, bytes_budget=budget)
    assert sorted(m for c in chunks for m in c.moves) == sorted(plan.moves)
    assert sum(c.bytes for c in chunks) == plan.bytes
    assert sum(c.n_triples for c in chunks) == plan.n_triples
    for c in chunks:
        assert c.bytes <= budget or c.n_moves == 1
    if not plan.moves:
        assert chunks == []


def test_chunk_plan_orders_hottest_first():
    sizes = np.array([10, 10, 10, 10], np.int64)
    old = hash_partition(sizes, 2, seed=0)
    new = old.copy()
    new.feature_to_shard[:] = (old.feature_to_shard + 1) % 2   # move all
    plan = migration.plan(old, new)
    heat = np.array([0.0, 5.0, 1.0, 9.0])
    chunks = migration.chunk_plan(plan, sizes, bytes_budget=1,
                                  priority=heat)
    order = [c.moves[0][0] for c in chunks]
    assert order == [3, 1, 2, 0]                               # heat-descending


def test_migration_seconds_prices_pairs_and_bytes():
    net = qexec.NetworkModel(latency_s=0.1, bandwidth_Bps=1000.0)
    plan = migration.MigrationPlan(
        moves=[(0, 0, 1), (1, 0, 1), (2, 1, 2)], n_triples=100,
        bytes=1200)
    # two distinct (src, dst) pairs + 1200 B on the wire
    assert migration.migration_seconds(plan, net) == \
        pytest.approx(2 * 0.1 + 1200 / 1000.0)


# --------------------------------------------------------------------------- #
# session mechanics on the live facade
# --------------------------------------------------------------------------- #

def _kg_pair(rng, n_shards=4):
    """A live facade plus an independent fully-committed reference facade."""
    store, space = _random_dataset(rng)
    sizes = space.feature_sizes()
    state = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    target = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    kg = PartitionedKG(store, space, state.copy())
    ref = PartitionedKG(store, space, target.copy())
    return kg, ref, target


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_mid_migration_queries_match_committed_layout(seed):
    """The acceptance property: at EVERY intermediate session epoch, query
    bindings and ExecStats.rows equal the fully-committed layout's (results
    are layout-invariant; only federation stats may differ) — under both
    executors."""
    rng = np.random.default_rng(seed)
    kg, ref, target = _kg_pair(rng)
    queries = [_random_query(rng, kg.store, name=f"R{i}") for i in range(3)]
    refs = [qexec.NumpyExecutor().run(ref.plan(q), ref) for q in queries]

    budget = max(int(target.feature_sizes.sum()) * migration.TRIPLE_BYTES
                 // 6, 1)
    session = MigrationSession(kg, target, bytes_budget=budget)
    executors = [qexec.NumpyExecutor(), qexec.JaxExecutor()]
    epochs_seen = []
    while True:                       # checks the pre-drain epoch too
        epochs_seen.append(kg.epoch)
        for q, (rb, rs) in zip(queries, refs):
            for ex in executors:
                b, s = ex.run(kg.plan(q), kg)
                assert canon_bindings(b) == canon_bindings(rb), \
                    (q.name, ex.name, kg.epoch)
                assert s.rows == rs.rows
        if session.step() is None:
            break
    assert np.array_equal(kg.state.feature_to_shard,
                          target.feature_to_shard)
    # every applied chunk produced a distinct served epoch
    assert len(set(epochs_seen)) == len(epochs_seen)
    assert session.epochs[:-1] == epochs_seen[:len(session.epochs) - 1]


def test_session_epochs_views_and_plan_cache(small_lubm):
    """Each chunk bumps the facade epoch, invalidates cached plans, and
    re-indexes only the shards its moves touch."""
    svc = KGService.from_dataset(small_lubm, n_shards=8)
    kg = svc.bootstrap(small_lubm.base_workload())
    q = small_lubm.queries["Q9"]
    kg.plan(q)

    target = kg.state.copy()
    f_all = np.argsort(-kg.state.feature_sizes)[:6]
    target.feature_to_shard[f_all] = \
        (target.feature_to_shard[f_all] + 1) % kg.n_shards
    session = MigrationSession(kg, target, bytes_budget=1)   # 1 move per chunk
    assert session.n_chunks == len(f_all)

    views0 = list(kg.shards)
    epoch0, builds0 = kg.epoch, kg.plan_builds
    chunk = session.step()
    assert kg.epoch == epoch0 + 1
    kg.plan(q)
    assert kg.plan_builds == builds0 + 1        # plan cache was invalidated
    touched = {chunk.moves[0][1], chunk.moves[0][2]}
    for s in range(kg.n_shards):
        if s not in touched:
            assert kg.shards[s] is views0[s]    # untouched views reused
    session.drain()
    assert session.done and session.progress() == 1.0
    assert session.step() is None
    assert sum(kg.shard_sizes()) == small_lubm.store.n_triples


def test_noop_delta_keeps_plan_cache_and_epoch(small_lubm):
    """Satellite: committing a state identical to the current one must not
    wipe cached QueryPlans nor advance the epoch."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    p0 = kg.plan(small_lubm.queries["Q9"])
    epoch0, builds0 = kg.epoch, kg.plan_builds
    plan = kg.commit(kg.state.copy())
    assert plan.n_moves == 0
    assert kg.epoch == epoch0
    assert kg.plan(small_lubm.queries["Q9"]) is p0
    assert kg.plan_builds == builds0


# --------------------------------------------------------------------------- #
# service loop: budget knob, step/drain, interleaved windows
# --------------------------------------------------------------------------- #

def test_service_chunked_adapt_interleaves_with_query_batch(small_lubm):
    """With a migration_budget, adapt() leaves a pending session; each
    query_batch window applies exactly one chunk; results at every epoch are
    identical to an atomically-committed twin service."""
    window = small_lubm.extended_workload()
    new10 = small_lubm.workload([f"EQ{i}" for i in range(1, 11)])

    atomic = KGService.from_dataset(small_lubm, n_shards=4)
    atomic.bootstrap(small_lubm.base_workload())
    atomic.query_batch(window)
    rep_a = atomic.adapt(new10)
    assert atomic.session is None                   # drained inside adapt

    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 migration_budget=120_000)
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(window)
    rep_c = svc.adapt(new10)
    assert rep_c.accepted == rep_a.accepted
    assert rep_c.plan.bytes == rep_a.plan.bytes
    assert svc.session is not None and svc.session.n_chunks >= 3

    ref = {q.name: canon_bindings(atomic.query(q)[0]) for q in window[:4]}
    windows = 0
    while svc.session is not None:
        results = svc.query_batch(window[:4])       # serve + one chunk ahead
        for q, (b, _) in zip(window[:4], results):
            assert canon_bindings(b) == ref[q.name], q.name
        windows += 1
    assert windows >= 3
    assert np.array_equal(svc.kg.state.feature_to_shard,
                          atomic.kg.state.feature_to_shard)


def test_service_step_and_drain(small_lubm):
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 migration_budget=60_000)
    svc.bootstrap(small_lubm.base_workload())
    assert svc.step() is None and svc.drain() == 0  # idle: no session
    svc.query_batch(small_lubm.extended_workload())
    report = svc.adapt(small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    if not report.accepted:
        pytest.skip("round rejected on this layout")
    n = svc.session.n_chunks
    assert svc.step() is not None                   # one chunk applied
    assert svc.drain() == n - 1                     # the rest
    assert svc.session is None


def test_adapt_finishes_inflight_session_first(small_lubm):
    """A new round while a drain is in flight finishes the old session, so
    the controller's view and the served layout never diverge."""
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 migration_budget=60_000)
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(small_lubm.extended_workload())
    svc.adapt(small_lubm.workload(["EQ1", "EQ2", "EQ3"]))
    pending = svc.session
    if pending is not None:
        target1 = pending.target
        svc.adapt(small_lubm.workload([f"EQ{i}" for i in range(4, 11)]))
        assert pending.done                         # old drain completed
        assert pending.applied == pending.n_chunks
        del target1


# --------------------------------------------------------------------------- #
# migration-cost-aware guard + TM satellites
# --------------------------------------------------------------------------- #

def test_guard_rejects_when_migration_cost_dominates(small_lubm):
    """Same round, same gain — but a network where shipping the plan costs
    more than the savings amortized over the window must be rejected."""
    def round_with(net, amortize):
        svc = KGService.from_dataset(
            small_lubm, n_shards=4, net=net,
            config=AdaptConfig(amortize_window=amortize))
        svc.bootstrap(small_lubm.base_workload())
        svc.query_batch(small_lubm.extended_workload())
        return svc.adapt(small_lubm.workload([f"EQ{i}"
                                              for i in range(1, 11)]))

    ok = round_with(None, None)
    assert ok.accepted and ok.migration_s > 0 and ok.amortize_window > 0

    slow = qexec.NetworkModel(bandwidth_Bps=1.0)    # ~bytes seconds to ship
    rejected = round_with(slow, 1)
    assert not rejected.accepted
    assert rejected.plan.n_moves == 0               # reverted
    assert rejected.migration_s > rejected.t_base - rejected.t_new


def test_guard_rejects_with_zero_amortize_window(small_lubm):
    """amortize_window=0 declares no future executions to amortize over:
    any positive migration cost must be rejected, however large the gain."""
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 config=AdaptConfig(amortize_window=0))
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(small_lubm.extended_workload())
    report = svc.adapt(small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    assert not report.accepted
    assert report.amortize_window == 0 and report.plan.n_moves == 0


def test_drain_completion_restarts_tm_window(small_lubm):
    """The TM observes hybrid-layout times while draining; finishing the
    drain must restart the window so the pinned post-migration baseline is
    not compared against mid-drain observations (no spurious round)."""
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 migration_budget=120_000)
    svc.bootstrap(small_lubm.base_workload())
    window = small_lubm.extended_workload()
    svc.query_batch(window)
    report = svc.adapt(small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    if not report.accepted:
        pytest.skip("round rejected on this layout")
    while svc.session is not None:
        svc.query_batch(window)                 # hybrid-layout observations
    # the final chunk applies (and restarts the TM) ahead of the last
    # window, so only final-layout observations remain: exactly one per
    # query, averaging to the pinned t_new baseline — no spurious round
    ctrl = svc.controller
    assert all(len(v) == 1 for v in ctrl.exec_times.values())
    assert ctrl.avg_execution_time() == pytest.approx(report.t_new)
    assert not svc.should_adapt()


def test_should_adapt_requires_an_observation(small_lubm):
    """Satellite: a fresh session (no baseline AND empty TM) must not
    trigger an adaptation round."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    svc.bootstrap(small_lubm.base_workload())
    assert not svc.should_adapt()                   # empty TM: nothing to fix
    assert svc.maybe_adapt() is None
    svc.query(small_lubm.queries["Q6"])
    assert svc.should_adapt()                       # observed, no baseline

    ctrl = AWAPartController(svc.space, 4)
    assert not ctrl.should_adapt()
    ctrl.observe(small_lubm.queries["Q6"], 0.5)
    assert ctrl.should_adapt()


def test_reset_baseline_clears_nonadaptive_times(small_lubm):
    """Satellite: reset_baseline restarts the TM window consistently for
    non-adaptive strategies too."""
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 partitioner=HashPartitioner())
    svc.bootstrap()
    svc.query(small_lubm.queries["Q6"])
    assert svc.avg_execution_time() > 0
    svc.reset_baseline()
    assert svc.avg_execution_time() == 0.0
