"""repro.scenario: drift schedules and the reactivity driver.

Pins: (1) schedules are deterministic — same seed, same dataset, same
admission stream, byte-identical write batches included; (2) replaying a
schedule through the continuous-admission stream produces bindings
byte-identical to the synchronous ``query_batch`` replay of the same
schedule, per executor (numpy/jax/jax-pallas), with writes landing and a
budgeted migration draining mid-replay; (3) the recovery metrics
(baseline anchoring, time-to-recover, bytes-per-recovery) compute what
they claim on synthetic window series."""
import numpy as np
import pytest

from conftest import canon_bindings

from repro import scenario as drift
from repro.api import KGService, MigrationSession, WriteBatch
from repro.core import migration
from repro.core.partition import hash_partition
from repro.graph import watdiv
from repro.graph.triples import TripleStore
from repro.replicate import ReplicaMap

EXECUTORS = ("numpy", "jax", "jax-pallas")
FACTORIES = (drift.diurnal, drift.flash_crowd, drift.hot_set_churn,
             drift.mixed_read_write)


@pytest.fixture(scope="module")
def ds():
    return watdiv.load(1, seed=0)


def _fresh_service(ds, executor="numpy", n_shards=4, **kwargs):
    """Service over a COPY of the memoized store — scenario writes mutate
    stores in place and twins must not share one."""
    store = TripleStore(ds.store.triples.copy(), ds.store.dictionary)
    return KGService(store, n_shards,
                     type_predicate=ds.dictionary.lookup("rdf:type"),
                     executor=executor, **kwargs)


def _force_session(svc, seed=0):
    """Put a deterministic budgeted migration (with replica promotions) in
    flight, so the replay serves hybrid layouts across several epochs."""
    sizes = svc.space.feature_sizes()
    target = hash_partition(sizes, svc.n_shards, seed=seed)
    reps = ReplicaMap.primary_only(target)
    rng = np.random.default_rng(seed)
    for f in range(len(target.feature_to_shard)):
        if rng.random() < 0.2:
            reps.add(f, int(rng.integers(svc.n_shards)))
    budget = max(int(sizes.sum()) * migration.TRIPLE_BYTES // 6, 1)
    svc.session = MigrationSession(svc.kg, target, bytes_budget=budget,
                                   target_replicas=reps)
    assert svc.session.n_chunks >= 3


# --------------------------------------------------------------------------- #
# schedule determinism
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("factory", FACTORIES,
                         ids=lambda f: f.__name__)
def test_schedule_is_deterministic(ds, factory):
    a = factory(ds, seed=11).schedule(ds)
    b = factory(ds, seed=11).schedule(ds)
    assert len(a) == len(b) > 0
    for wa, wb in zip(a, b):
        assert (wa.index, wa.phase, wa.onset, wa.mix_key) \
            == (wb.index, wb.phase, wb.onset, wb.mix_key)
        assert [q.name for q in wa.queries] == [q.name for q in wb.queries]
        if wa.write_rows is None:
            assert wb.write_rows is None
        else:
            assert wa.write_rows.tobytes() == wb.write_rows.tobytes()
    c = factory(ds, seed=12).schedule(ds)
    assert [[q.name for q in w.queries] for w in a] \
        != [[q.name for q in w.queries] for w in c], "seed ignored"


@pytest.mark.parametrize("factory", FACTORIES,
                         ids=lambda f: f.__name__)
def test_schedule_structure(ds, factory):
    scn = factory(ds, seed=0)
    windows = scn.schedule(ds)
    assert windows[0].onset is False
    assert sum(w.onset for w in windows) == len(scn.phases) - 1
    assert [w.index for w in windows] == list(range(len(windows)))
    assert sum(1 for _ in windows) == sum(p.windows for p in scn.phases)
    for w in windows:
        assert len(w.queries) == scn.queries_per_window
        assert all(q.name in ds.queries for q in w.queries)
    # phase 0's distinct mix = the bootstrap workload
    boot = {q.name for q in scn.bootstrap_workload(ds)}
    assert boot == {n for n, x in scn.phases[0].mix if x > 0}


def test_write_rows_use_fresh_disjoint_subjects(ds):
    scn = drift.mixed_read_write(ds, read_windows=1, write_windows=3,
                                 cool_windows=1, writes_per_window=8,
                                 queries_per_window=4, seed=4)
    windows = scn.schedule(ds)
    burst = [w for w in windows if w.write_rows is not None]
    assert len(burst) == 3
    top = int(ds.store.triples.max())
    seen = set()
    for w in burst:
        subjects = set(w.write_rows[:, 0].tolist())
        assert all(s > top for s in subjects), "subject collides with graph"
        assert not (subjects & seen), "subjects reused across windows"
        seen |= subjects


# --------------------------------------------------------------------------- #
# reactivity metrics on synthetic series
# --------------------------------------------------------------------------- #

def _rec(i, phase, onset, ms, stall=0, key=None):
    return drift.WindowRecord(
        index=i, phase=phase, onset=onset, n_queries=1, write_rows=0,
        avg_ms=ms, stall_bytes=stall, window_ms=ms, bytes_shipped=0,
        epoch=0, adapted=False, mix_key=key if key is not None else phase)


def test_reactivity_recovery_and_bytes():
    ws = [_rec(0, "a", False, 10.0), _rec(1, "a", False, 10.0),
          _rec(2, "a", False, 10.0),
          _rec(3, "b", True, 50.0, stall=100),
          _rec(4, "b", False, 30.0, stall=50),
          _rec(5, "b", False, 11.0, stall=25),
          _rec(6, "b", False, 99.0, stall=7)]
    (r,) = drift.reactivity(ws, margin=0.2)
    assert r.onset == 3 and r.baseline_ms == pytest.approx(10.0)
    assert r.recovered and r.time_to_recover == 2          # first <= 12.0
    assert r.depth == pytest.approx(5.0)                   # peak before rec.
    assert r.bytes_spent == 175                            # onset..recovery


def test_reactivity_never_recovers():
    ws = [_rec(0, "a", False, 10.0),
          _rec(1, "b", True, 40.0, stall=5), _rec(2, "b", False, 35.0,
                                                  stall=5)]
    (r,) = drift.reactivity(ws, margin=0.2)
    assert not r.recovered and r.time_to_recover is None
    assert r.depth == pytest.approx(4.0) and r.bytes_spent == 10


def test_reactivity_anchors_to_same_mix_phase():
    """A recurring phase is judged against its own past (the tail of the
    last same-mix phase), not against the different-floor phase that
    happens to precede it."""
    ws = [_rec(0, "day0", False, 10.0, key="day"),
          _rec(1, "day0", False, 10.0, key="day"),
          _rec(2, "night0", True, 100.0, key="night"),
          _rec(3, "night0", False, 100.0, key="night"),
          _rec(4, "day1", True, 11.0, key="day"),
          _rec(5, "day1", False, 11.0, key="day"),
          _rec(6, "night1", True, 101.0, key="night")]
    night0, day1, night1 = drift.reactivity(ws, margin=0.2)
    # first occurrence: falls back to the immediately-preceding windows
    assert night0.baseline_ms == pytest.approx(10.0) and not night0.recovered
    # recurring phases: anchored like-for-like
    assert day1.baseline_ms == pytest.approx(10.0)
    assert day1.recovered and day1.time_to_recover == 0
    assert night1.baseline_ms == pytest.approx(100.0)
    assert night1.recovered and night1.time_to_recover == 0


# --------------------------------------------------------------------------- #
# driver mechanics
# --------------------------------------------------------------------------- #

def test_run_scenario_telemetry_and_writes(ds):
    scn = drift.mixed_read_write(ds, read_windows=1, write_windows=2,
                                 cool_windows=1, writes_per_window=8,
                                 queries_per_window=4, seed=2)
    svc = _fresh_service(ds)
    svc.bootstrap(scn.bootstrap_workload(ds))
    before = svc.store.n_triples
    rep = drift.run_scenario(svc, scn, ds, adapt=False, mode="frozen")
    assert rep.scenario == "mixed_read_write" and rep.mode == "frozen"
    assert [w.write_rows for w in rep.windows] == [0, 24, 24, 0]
    assert svc.write_log.n_inserted == 48          # 8 users x 3 rows x 2
    assert svc.store.n_triples == before + 48
    assert [w.onset for w in rep.windows] == [False, True, False, True]
    assert all(w.window_ms >= w.avg_ms > 0 for w in rep.windows)
    assert len(rep.recoveries) == 2
    s = rep.summary()
    assert s["windows"] == 4 and s["onsets"] == 2
    assert s["bytes_spent"] == 0                   # frozen: no migrations


def test_run_scenario_charges_migration_stalls(ds):
    scn = drift.hot_set_churn(ds, steps=2, windows_per_step=2,
                              queries_per_window=4, seed=1)
    svc = _fresh_service(ds, migration_budget=20_000)
    svc.bootstrap(scn.bootstrap_workload(ds))
    _force_session(svc, seed=3)
    rep = drift.run_scenario(svc, scn, ds, adapt=False, mode="frozen")
    drained = sum(w.stall_bytes for w in rep.windows)
    assert drained > 0, "in-flight chunks never charged to a window"
    for w in rep.windows:
        assert w.window_ms >= w.avg_ms
        if w.stall_bytes:
            assert w.window_ms > w.avg_ms


# --------------------------------------------------------------------------- #
# THE parity property: streamed schedule == synchronous schedule
# --------------------------------------------------------------------------- #

def _sync_replay(svc, windows):
    out, epochs = [], set()
    for w in windows:
        if w.write_rows is not None:
            svc.write(WriteBatch(inserts=w.write_rows.copy()))
        for b, _ in svc.query_batch(w.queries):
            out.append(canon_bindings(b))
        epochs.add(svc.kg.epoch)
    return out, epochs


def test_streamed_schedule_matches_synchronous(ds):
    """Same drift schedule, same starting state (budgeted migration with
    replica promotions in flight): the continuous-admission replay serves
    bindings byte-identical to the synchronous window loop, on every
    executor — across the epochs the writes and chunk drains create."""
    scn = drift.mixed_read_write(ds, read_windows=1, write_windows=2,
                                 cool_windows=1, writes_per_window=8,
                                 queries_per_window=5, seed=5)
    windows = scn.schedule(ds)
    per_exec = {}
    for name in EXECUTORS:
        def build():
            svc = _fresh_service(ds, executor=name,
                                 migration_budget=30_000)
            svc.bootstrap(scn.bootstrap_workload(ds))
            _force_session(svc, seed=7)
            return svc

        sync, epochs = _sync_replay(build(), windows)
        assert len(epochs) > 1, "replay never crossed an epoch"

        svc = _fresh_service(ds, executor=name, migration_budget=30_000)
        svc.bootstrap(scn.bootstrap_workload(ds))
        _force_session(svc, seed=7)
        stream, results = drift.stream_schedule(
            svc, windows, max_window=scn.queries_per_window)
        got = [canon_bindings(r.bindings) for r in results]
        assert got == sync, name
        assert svc.write_log.n_inserted == 48
        per_exec[name] = got
    assert per_exec["numpy"] == per_exec["jax"] == per_exec["jax-pallas"]
