"""repro.api: incremental shard views vs full rebuild, strategy plugging,
and service/facade invariants."""
import numpy as np
import pytest

from repro.api import (AWAPartitioner, HashPartitioner, KGService,
                       Partitioner, WawPartitioner)
from repro.core.partition import hash_partition
from repro.query import engine


def _assert_views_match_full_rebuild(kg):
    """Every materialized shard view must equal a from-scratch rebuild of the
    same PartitionState (triples in identical global order)."""
    full = engine.ShardedStore(kg.store, kg.space, kg.state)
    for s, (inc, ref) in enumerate(zip(kg.shards, full.shards)):
        assert np.array_equal(inc.triples, ref.triples), f"shard {s} diverged"
    assert sum(kg.shard_sizes()) == kg.store.n_triples


def test_incremental_views_equal_full_rebuild_across_rounds(small_lubm):
    """Equivalence property: applying MigrationPlan deltas to materialized
    views == rebuilding every shard from the PartitionState, across several
    adaptation rounds (including universe growth from new PO features)."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    _assert_views_match_full_rebuild(kg)

    rounds = [["EQ1", "EQ2", "EQ3"],
              ["EQ4", "EQ5", "EQ6"],
              [f"EQ{i}" for i in range(7, 11)]]
    for names in rounds:
        svc.reset_baseline()      # force a round regardless of threshold
        report = svc.adapt(small_lubm.workload(names))
        assert report is not None
        _assert_views_match_full_rebuild(kg)


def test_profile_accounting_matches_execution(small_lubm):
    """Candidate pricing (stats_from_profile over cached QueryProfiles) must
    reproduce engine.execute's federation statistics exactly, under both the
    live layout and an arbitrary other one."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    queries = small_lubm.extended_workload()
    layouts = [kg.state, hash_partition(kg.state.feature_sizes, 4, seed=3)]
    fields = ("scan_rows_critical", "join_rows", "distributed_joins",
              "rows_shipped", "bytes_shipped", "messages", "rows")
    for layout in layouts:
        sh = engine.ShardedStore(small_lubm.store, svc.space, layout)
        ts = layout.triple_shards(kg.owners).astype(np.int32)
        for q in queries:
            _, real = engine.execute(q, sh)
            est = engine.stats_from_profile(q, kg.profile(q), svc.space,
                                            layout, ts)
            for f in fields:
                assert getattr(real, f) == getattr(est, f), (q.name, f)
            assert abs(real.modeled_time() - est.modeled_time()) < 1e-12


def test_measure_candidate_is_side_effect_free(small_lubm):
    """Evaluating a candidate layout must leave state, row-sets and views
    untouched (pure profile re-accounting)."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    queries = small_lubm.base_workload()

    before_views = list(kg.shards)                  # materialize + capture
    before_f2s = kg.state.feature_to_shard.copy()
    before_sizes = kg.shard_sizes()

    cand = hash_partition(kg.state.feature_sizes, kg.n_shards, seed=7)
    t = kg.measure_candidate(cand, queries)
    assert t > 0

    assert np.array_equal(kg.state.feature_to_shard, before_f2s)
    assert kg.shard_sizes() == before_sizes
    for v0, v1 in zip(before_views, kg.shards):
        assert v0 is v1                             # views restored by pointer
    _assert_views_match_full_rebuild(kg)


def test_commit_moves_only_planned_triples(small_lubm):
    """commit() returns the applied MigrationPlan; untouched shard views are
    reused, not rebuilt."""
    svc = KGService.from_dataset(small_lubm, n_shards=8)
    kg = svc.bootstrap(small_lubm.base_workload())
    views0 = list(kg.shards)
    rebuilds0 = kg.view_rebuilds

    new_state = kg.state.copy()
    f = int(np.argmax(new_state.feature_sizes))     # move one big feature
    src = int(new_state.feature_to_shard[f])
    dst = (src + 1) % kg.n_shards
    new_state.feature_to_shard[f] = dst

    plan = kg.commit(new_state)
    assert {m[0] for m in plan.moves} == {f}
    assert plan.n_triples == int(kg.state.feature_sizes[f])
    _assert_views_match_full_rebuild(kg)
    # only src/dst re-indexed, the other six views are the same objects
    for s in range(kg.n_shards):
        if s not in (src, dst):
            assert kg.shards[s] is views0[s]
    assert kg.view_rebuilds == rebuilds0 + 2


@pytest.mark.parametrize("make", [HashPartitioner, WawPartitioner,
                                  AWAPartitioner])
def test_partitioner_strategies_interchangeable(small_lubm, make):
    """All strategies satisfy the protocol and serve the same workload."""
    part = make()
    assert isinstance(part, Partitioner)
    svc = KGService.from_dataset(small_lubm, n_shards=4, partitioner=part)
    kg = svc.bootstrap(small_lubm.base_workload())
    assert sum(kg.shard_sizes()) == small_lubm.store.n_triples
    _, stats = svc.query(small_lubm.queries["Q6"])
    assert stats.rows > 0
    assert svc.avg_execution_time() > 0


def test_non_adaptive_strategy_rejects_adapt(small_lubm):
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 partitioner=HashPartitioner())
    svc.bootstrap()
    assert not svc.should_adapt()
    assert svc.maybe_adapt() is None
    with pytest.raises(TypeError):
        svc.adapt(small_lubm.base_workload())


def test_adaptive_strategy_beats_hash_on_distributed_joins(small_lubm):
    """The point of the paper: workload-aware placement cuts federation."""
    base = small_lubm.base_workload()

    def dj_total(partitioner, workload):
        svc = KGService.from_dataset(small_lubm, n_shards=4,
                                     partitioner=partitioner)
        svc.bootstrap(workload)
        _, stats = svc.run_workload(base)
        return sum(s.distributed_joins for s in stats.values())

    assert dj_total(WawPartitioner(), base) <= dj_total(HashPartitioner(), ())
