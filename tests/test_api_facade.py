"""repro.api: incremental shard views vs full rebuild, strategy/executor
plugging, plan-cache behaviour, and service/facade invariants."""
import numpy as np
import pytest

from conftest import canon_bindings
from repro.api import (AWAPartitioner, HashPartitioner, JaxExecutor,
                       KGService, NumpyExecutor, Partitioner, WawPartitioner)
from repro.core.partition import hash_partition
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.engine import ShardedStore



def _assert_views_match_full_rebuild(kg):
    """Every materialized shard view must equal a from-scratch rebuild of the
    same PartitionState (triples in identical global order)."""
    full = ShardedStore(kg.store, kg.space, kg.state)
    for s, (inc, ref) in enumerate(zip(kg.shards, full.shards)):
        assert np.array_equal(inc.triples, ref.triples), f"shard {s} diverged"
    assert sum(kg.shard_sizes()) == kg.store.n_triples


def test_incremental_views_equal_full_rebuild_across_rounds(small_lubm):
    """Equivalence property: applying MigrationPlan deltas to materialized
    views == rebuilding every shard from the PartitionState, across several
    adaptation rounds (including universe growth from new PO features)."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    _assert_views_match_full_rebuild(kg)

    rounds = [["EQ1", "EQ2", "EQ3"],
              ["EQ4", "EQ5", "EQ6"],
              [f"EQ{i}" for i in range(7, 11)]]
    for names in rounds:
        svc.reset_baseline()      # force a round regardless of threshold
        report = svc.adapt(small_lubm.workload(names))
        assert report is not None
        _assert_views_match_full_rebuild(kg)


def test_profile_accounting_matches_execution(small_lubm):
    """Candidate pricing (stats_from_profile over cached QueryProfiles) must
    reproduce the executor's federation statistics exactly, under both the
    live layout and an arbitrary other one."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    queries = small_lubm.extended_workload()
    layouts = [kg.state, hash_partition(kg.state.feature_sizes, 4, seed=3)]
    for layout in layouts:
        sh = ShardedStore(small_lubm.store, svc.space, layout)
        for q in queries:
            _, real = NumpyExecutor().run(qplan.plan(q, sh), sh)
            est = qplan.stats_from_profile(q, kg.profile(q), svc.space,
                                           layout, sh.triple_shard)
            for f in qexec.ExecStats.COMPARABLE:
                assert getattr(real, f) == getattr(est, f), (q.name, f)
            assert abs(real.modeled_time() - est.modeled_time()) < 1e-12


def test_jax_batch_matches_numpy_per_query_and_plan_cache(small_lubm):
    """Acceptance equivalence suite: for a fixed workload, JaxExecutor batch
    results (bindings + stats) match NumpyExecutor per-query results exactly,
    and one plan per (query, store) is built across an adaptation round."""
    svc = KGService.from_dataset(small_lubm, n_shards=4, executor="numpy")
    kg = svc.bootstrap(small_lubm.base_workload())
    workload = small_lubm.extended_workload()

    per_query = [svc.query(q) for q in workload]          # numpy, one at a time
    assert kg.plan_builds == len(workload)

    # jax, one batch — run the executor directly: the service itself would
    # serve these (query, epoch) repeats from the facade's result cache
    jx = JaxExecutor(probe_kernel=True)                   # pin the kernels
    batch = jx.run_batch([kg.plan(q) for q in workload], kg)
    for q, (bn, sn), (bj, sj) in zip(workload, per_query, batch):
        assert canon_bindings(bn) == canon_bindings(bj), q.name
        for f in qexec.ExecStats.COMPARABLE:
            assert getattr(sn, f) == getattr(sj, f), (q.name, f)

    # the whole second pass was served from the plan cache
    assert kg.plan_builds == len(workload)
    assert kg.plan_hits == len(workload)

    # and a service-level repeat at the same epoch is served from the
    # result cache without reaching any executor
    svc.executor = jx
    assert kg.result_hits == 0
    repeat = svc.query_batch(workload)
    assert kg.result_hits == len(workload)
    assert kg.plan_builds == len(workload)
    for (bn, _), (br, _) in zip(per_query, repeat):
        assert canon_bindings(bn) == canon_bindings(br)

    # an adaptation round prices every candidate from cached plans/profiles:
    # still exactly one plan built per (query, store) — until the commit
    # invalidates the cache (the layout, hence PPN, changed)
    builds_before = kg.plan_builds
    svc.adapt(small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    assert kg.plan_builds == builds_before
    svc.query_batch(workload)
    assert kg.plan_builds == builds_before + len(workload)


def test_plan_cache_invalidated_by_commit_and_sync(small_lubm):
    """commit() and sync_universe() must drop cached plans: the PPN vote
    depends on the layout and the feature universe."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    q = small_lubm.queries["Q9"]

    p0 = kg.plan(q)
    assert kg.plan(q) is p0                       # cached
    assert kg.plan_hits == 1

    # move every feature the query votes with to another shard: the cached
    # plan would keep a stale PPN
    new_state = kg.state.copy()
    feats = svc.space.query_features(q)
    dst = (p0.ppn + 1) % kg.n_shards
    new_state.feature_to_shard[feats] = dst
    kg.commit(new_state)

    p1 = kg.plan(q)
    assert p1 is not p0
    assert p1.ppn == dst
    assert qplan.plan(q, kg).ppn == dst           # agrees with a fresh build

    # universe growth (new tracked PO features) also invalidates
    kg.sync_universe()                            # no growth: cache survives
    assert kg.plan(q) is p1
    svc.space.track_workload(
        small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    assert svc.space.n_features > len(kg.state.feature_to_shard)
    kg.sync_universe()
    assert kg.plan(q) is not p1


def test_measure_candidate_is_side_effect_free(small_lubm):
    """Evaluating a candidate layout must leave state, row-sets and views
    untouched (pure profile re-accounting)."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    queries = small_lubm.base_workload()

    before_views = list(kg.shards)                  # materialize + capture
    before_f2s = kg.state.feature_to_shard.copy()
    before_sizes = kg.shard_sizes()

    cand = hash_partition(kg.state.feature_sizes, kg.n_shards, seed=7)
    t = kg.measure_candidate(cand, queries)
    assert t > 0

    assert np.array_equal(kg.state.feature_to_shard, before_f2s)
    assert kg.shard_sizes() == before_sizes
    for v0, v1 in zip(before_views, kg.shards):
        assert v0 is v1                             # views restored by pointer
    _assert_views_match_full_rebuild(kg)


def test_commit_moves_only_planned_triples(small_lubm):
    """commit() returns the applied MigrationPlan; untouched shard views are
    reused, not rebuilt."""
    svc = KGService.from_dataset(small_lubm, n_shards=8)
    kg = svc.bootstrap(small_lubm.base_workload())
    views0 = list(kg.shards)
    rebuilds0 = kg.view_rebuilds

    new_state = kg.state.copy()
    f = int(np.argmax(new_state.feature_sizes))     # move one big feature
    src = int(new_state.feature_to_shard[f])
    dst = (src + 1) % kg.n_shards
    new_state.feature_to_shard[f] = dst

    plan = kg.commit(new_state)
    assert {m[0] for m in plan.moves} == {f}
    assert plan.n_triples == int(kg.state.feature_sizes[f])
    _assert_views_match_full_rebuild(kg)
    # only src/dst re-indexed, the other six views are the same objects
    for s in range(kg.n_shards):
        if s not in (src, dst):
            assert kg.shards[s] is views0[s]
    assert kg.view_rebuilds == rebuilds0 + 2


@pytest.mark.parametrize("make", [HashPartitioner, WawPartitioner,
                                  AWAPartitioner])
def test_partitioner_strategies_interchangeable(small_lubm, make):
    """All strategies satisfy the protocol and serve the same workload."""
    part = make()
    assert isinstance(part, Partitioner)
    svc = KGService.from_dataset(small_lubm, n_shards=4, partitioner=part)
    kg = svc.bootstrap(small_lubm.base_workload())
    assert sum(kg.shard_sizes()) == small_lubm.store.n_triples
    _, stats = svc.query(small_lubm.queries["Q6"])
    assert stats.rows > 0
    assert svc.avg_execution_time() > 0


@pytest.mark.parametrize("executor", ["numpy", "jax"])
def test_executor_strategies_interchangeable(small_lubm, executor):
    """Both backends satisfy the Executor protocol and serve the loop."""
    svc = KGService.from_dataset(small_lubm, n_shards=4, executor=executor)
    assert isinstance(svc.executor, qexec.Executor)
    assert svc.executor.name == executor
    svc.bootstrap(small_lubm.base_workload())
    _, stats = svc.query(small_lubm.queries["Q6"])
    assert stats.rows > 0
    results = svc.query_batch([small_lubm.queries["Q1"],
                               small_lubm.queries["Q6"]])
    assert len(results) == 2
    assert svc.avg_execution_time() > 0


def test_unknown_executor_rejected(small_lubm):
    with pytest.raises(ValueError, match="unknown executor"):
        KGService.from_dataset(small_lubm, n_shards=4, executor="spark")


def test_non_adaptive_strategy_rejects_adapt(small_lubm):
    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 partitioner=HashPartitioner())
    svc.bootstrap()
    assert not svc.should_adapt()
    assert svc.maybe_adapt() is None
    with pytest.raises(TypeError):
        svc.adapt(small_lubm.base_workload())


def test_adaptive_strategy_beats_hash_on_distributed_joins(small_lubm):
    """The point of the paper: workload-aware placement cuts federation."""
    base = small_lubm.base_workload()

    def dj_total(partitioner, workload):
        svc = KGService.from_dataset(small_lubm, n_shards=4,
                                     partitioner=partitioner)
        svc.bootstrap(workload)
        _, stats = svc.run_workload(base)
        return sum(s.distributed_joins for s in stats.values())

    assert dj_total(WawPartitioner(), base) <= dj_total(HashPartitioner(), ())
