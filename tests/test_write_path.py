"""repro.write: live insert/delete mechanics, routing + replica fanout,
stale-cache epoch discipline, write-aware adaptation pricing, and the
interleaved-mutations acceptance property (writes/queries/migration chunks
byte-identical to a rebuild-from-scratch PartitionedKG at every epoch, on
all executors and replicated layouts)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings, max_examples
from test_executors import _random_dataset, _random_query
from test_replication import _random_replicas

from repro import write as kgwrite
from repro.api import (KGService, MigrationSession, PartitionedKG,
                       WriteBatch)
from repro.core import migration
from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.features import FeatureSpace
from repro.core.partition import PartitionState, hash_partition
from repro.graph.triples import Dictionary, build_store
from repro.query import exec as qexec
from repro.query.pattern import Query, var
from repro.replicate import ReplicaMap, propose_replicas


def _tiny_kg(n_shards=3, replicas=None):
    """4 predicates x hand-placed features, deterministic layout."""
    d = Dictionary()
    for i in range(30):
        d.encode(f"t{i}")
    rng = np.random.default_rng(7)
    t = np.stack([rng.integers(0, 20, 120), rng.integers(0, 4, 120),
                  rng.integers(0, 20, 120)], axis=1).astype(np.int32)
    store = build_store(t, d)
    space = FeatureSpace(store)
    state = hash_partition(space.feature_sizes(), n_shards, 0)
    return PartitionedKG(store, space, state, replicas=replicas)


def _assert_matches_rebuild(kg, queries, ctx=""):
    """Live facade == rebuild-from-scratch oracle: identical bindings and
    comparable ExecStats on every backend."""
    twin = kgwrite.rebuild_from_scratch(kg)
    nx = qexec.NumpyExecutor()
    refs = [nx.run(twin.plan(q), twin) for q in queries]
    execs = [nx, qexec.JaxExecutor(),
             qexec.JaxExecutor(pallas=True, probe_kernel=True)]
    plans = [kg.plan(q) for q in queries]
    for ex in execs:
        for q, (b, s), (rb, rs) in zip(queries, ex.run_batch(plans, kg),
                                       refs):
            assert canon_bindings(b) == canon_bindings(rb), \
                (ctx, q.name, ex.name, kg.epoch)
            for f in qexec.ExecStats.COMPARABLE:
                assert getattr(s, f) == getattr(rs, f), \
                    (ctx, q.name, ex.name, f, kg.epoch)


# --------------------------------------------------------------------------- #
# WriteBatch / TripleStore mutation mechanics
# --------------------------------------------------------------------------- #

def test_write_batch_normalizes_and_dedups():
    batch = WriteBatch(inserts=[(1, 2, 3), (1, 2, 3), (4, 5, 6)],
                       deletes=np.array([[7, 8, 9]]))
    assert batch.inserts.shape == (2, 3)
    assert batch.inserts.dtype == np.int32
    assert batch.deletes.shape == (1, 3)
    assert batch.n_ops == 3
    empty = WriteBatch()
    assert empty.inserts.shape == (0, 3) and empty.n_ops == 0


def test_triple_store_apply_mutation_remap():
    d = Dictionary()
    t = np.array([[0, 1, 2], [3, 4, 5], [6, 7, 8]], np.int32)
    store = build_store(t, d)
    # delete the middle row, append one
    remap = store.apply_mutation(np.array([[9, 9, 9]], np.int32),
                                 np.array([1], np.int64))
    assert np.array_equal(remap, [0, -1, 1])
    assert store.n_triples == 3
    assert store.count(9, 9, 9) == 1 and store.count(3, 4, 5) == 0
    # indexes rebuilt: pattern lookups still see a consistent store
    assert store.count(None, 7, None) == 1
    # pure-insert mutation: identity remap over survivors
    remap2 = store.apply_mutation(np.array([[1, 1, 1]], np.int32),
                                  np.empty(0, np.int64))
    assert np.array_equal(remap2, np.arange(3))
    assert store.n_triples == 4


def test_insert_delete_set_semantics():
    kg = _tiny_kg()
    existing = kg.store.triples[5].tolist()
    n0 = kg.store.n_triples
    # delete + re-insert the same triple in one batch: net no-op x2
    rep = kg.apply_write(WriteBatch(inserts=[existing], deletes=[existing]))
    assert not rep.effective and rep.n_redundant == 2
    assert kg.store.n_triples == n0 and kg.epoch == 0
    # delete + insert of an ABSENT triple: pure insert (insert wins)
    rep = kg.apply_write(WriteBatch(inserts=[[25, 1, 25]],
                                    deletes=[[25, 1, 25]]))
    assert rep.n_inserted == 1 and rep.n_deleted == 0
    assert kg.store.count(25, 1, 25) == 1
    # inserting it again is redundant; deleting it works
    rep = kg.apply_write(WriteBatch(inserts=[[25, 1, 25]]))
    assert not rep.effective
    rep = kg.apply_write(WriteBatch(deletes=[[25, 1, 25]]))
    assert rep.n_deleted == 1 and kg.store.count(25, 1, 25) == 0


def test_write_routes_by_primary_and_fans_out_to_replicas():
    d = Dictionary()
    for i in range(10):
        d.encode(f"t{i}")
    t = np.array([[0, 0, 1], [1, 0, 2], [2, 1, 3]], np.int32)
    store = build_store(t, d)
    space = FeatureSpace(store)
    f0, f1 = space.p_index(0), space.p_index(1)
    state = PartitionState(np.array([0, 1], np.int32),
                           space.feature_sizes(), 3)
    rmap = ReplicaMap.primary_only(state)
    rmap.add(f0, 2)                       # p=0 replicated onto shard 2
    kg = PartitionedKG(store, space, state, replicas=rmap)
    shards0 = [len(v.triples) for v in kg.shards]

    rep = kg.apply_write(WriteBatch(inserts=[[5, 0, 6]]))
    # routed to p=0's primary (shard 0) AND its replica holder (shard 2)
    assert rep.touched_shards == [0, 2]
    assert rep.fanout_copies == 1
    assert rep.fanout_bytes == migration.TRIPLE_BYTES
    assert rep.feature_writes == {f0: 1}
    shards1 = [len(v.triples) for v in kg.shards]
    assert shards1[0] == shards0[0] + 1          # primary copy
    assert shards1[2] == shards0[2] + 1          # replica copy
    assert shards1[1] == shards0[1]              # untouched shard kept
    # the copy is byte-identical on both holders
    assert kg.store.count(5, 0, 6) == 1
    rows_new = np.flatnonzero(
        (kg.store.triples == np.array([5, 0, 6], np.int32)).all(1))
    assert rows_new[0] in kg.shard_rows(0)
    assert rows_new[0] in kg.shard_rows(2)

    # deleting fans out the same way
    rep = kg.apply_write(WriteBatch(deletes=[[5, 0, 6]]))
    assert rep.touched_shards == [0, 2] and rep.fanout_copies == 1
    assert [len(v.triples) for v in kg.shards] == shards0


def test_untouched_shard_views_are_reused():
    kg = _tiny_kg(n_shards=3)
    _ = kg.shards                        # materialize all views
    rebuilds0 = kg.view_rebuilds
    row = kg.store.triples[0]
    f = int(kg.owners[0])
    home = int(kg.state.feature_to_shard[f])
    rep = kg.apply_write(WriteBatch(inserts=[[21, int(row[1]), 22]]))
    assert rep.touched_shards == [home]
    _ = kg.shards
    # exactly the touched shard re-materialized
    assert kg.view_rebuilds == rebuilds0 + 1


def test_new_predicate_creates_feature_least_loaded():
    kg = _tiny_kg(n_shards=3)
    nf0 = kg.space.n_features
    least = int(np.argmin(kg.shard_sizes()))
    rep = kg.apply_write(WriteBatch(inserts=[[1, 99, 2], [3, 99, 4]]))
    assert len(rep.new_features) == 1
    fid, key, shard = rep.new_features[0]
    assert fid == nf0 and key == ("P", 99) and shard == least
    assert len(kg.state.feature_to_shard) == kg.space.n_features
    assert int(kg.state.feature_sizes[fid]) == 2
    assert kg.replicas.n_features == kg.space.n_features
    # queries over the new feature serve correctly, rebuild agrees
    q = Query(name="newp", patterns=((var(0), 99, var(1)),))
    _assert_matches_rebuild(kg, [q], "new-predicate")


def test_new_type_class_splits_po_feature():
    ds_type = 2                          # treat p=2 as rdf:type
    d = Dictionary()
    for i in range(10):
        d.encode(f"t{i}")
    t = np.array([[0, 2, 5], [1, 2, 5], [3, 0, 4]], np.int32)
    store = build_store(t, d)
    space = FeatureSpace(store, type_predicate=ds_type)
    state = hash_partition(space.feature_sizes(), 2, 0)
    kg = PartitionedKG(store, space, state)
    parent = space.p_index(ds_type)
    # a never-seen class: tracked PO child on the parent P's shard
    rep = kg.apply_write(WriteBatch(inserts=[[7, 2, 9]]))
    assert len(rep.new_features) == 1
    fid, key, shard = rep.new_features[0]
    assert key == ("PO", 2, 9)
    assert shard == int(kg.state.feature_to_shard[parent])
    assert kg.space.po_index(2, 9) == fid
    q = Query(name="cls", patterns=((var(0), 2, 9),))
    _assert_matches_rebuild(kg, [q], "new-class")


def test_feature_sizes_stay_exact_under_writes():
    kg = _tiny_kg()
    rng = np.random.default_rng(3)
    for _ in range(5):
        ins = np.stack([rng.integers(0, 25, 7), rng.integers(0, 5, 7),
                        rng.integers(0, 25, 7)], axis=1).astype(np.int32)
        dels = kg.store.triples[rng.integers(0, kg.store.n_triples, 4)]
        kg.apply_write(WriteBatch(inserts=ins, deletes=dels))
        derived = kg.space.feature_sizes(kg.owners)
        assert np.array_equal(kg.state.feature_sizes, derived)
        assert int(kg.state.feature_sizes.sum()) == kg.store.n_triples
        assert sum(kg.shard_sizes()) == kg.store.n_triples


# --------------------------------------------------------------------------- #
# stale-cache hazard: every mutating path bumps the epoch first
# --------------------------------------------------------------------------- #

def test_write_between_query_and_cached_repeat(small_lubm):
    """Regression: a write landing between ``query`` and a cached repeat
    must invalidate the cached result — the repeat re-executes and sees
    the new rows."""
    svc = KGService.from_dataset(small_lubm, 4)
    kg = svc.bootstrap(small_lubm.base_workload())
    d = small_lubm.dictionary
    q = small_lubm.queries["Q1"]
    before, _ = svc.query(q)
    hits0 = kg.result_hits
    _, _ = svc.query(q)
    assert kg.result_hits == hits0 + 1           # served from cache

    take = d.lookup("ub:takesCourse")
    cls = d.lookup("ub:GraduateStudent")
    tp = d.lookup("rdf:type")
    s = int(svc.fresh_ids(1)[0])         # entity ids live past the dictionary
    rep = svc.insert([[s, tp, cls], [s, take, small_lubm.named.grad_course0]])
    assert rep.effective and kg.epoch > 0

    after, _ = svc.query(q)                      # cached repeat? no: epoch moved
    assert kg.result_hits == hits0 + 1           # re-executed, not served
    assert len(after[var(0)]) == len(before[var(0)]) + 1
    # deleting restores the original result (epoch bumps again)
    svc.delete([[s, take, small_lubm.named.grad_course0]])
    restored, _ = svc.query(q)
    assert canon_bindings(restored) == canon_bindings(before)


def test_every_mutating_path_bumps_epoch_before_cache_serves():
    kg = _tiny_kg(n_shards=3)
    q = Query(name="q", patterns=((var(0), 0, var(1)),))
    nx = qexec.NumpyExecutor()

    def serve():
        hit = kg.cached_result(q)
        if hit is None:
            hit = nx.run(kg.plan(q), kg)
            kg.store_result(q, *hit)
        return hit

    epochs = [kg.epoch]

    def mutated(ctx):
        assert kg.epoch > epochs[-1], f"{ctx} did not bump the epoch"
        epochs.append(kg.epoch)
        hits = kg.result_hits
        serve()
        assert kg.result_hits == hits, f"{ctx} served a stale result"

    serve()
    f = int(kg.owners[0])
    src = int(kg.state.feature_to_shard[f])
    dst = (src + 1) % 3
    kg.apply_chunk(migration.MigrationChunk(
        moves=[(f, src, dst)], n_triples=1, bytes=12))
    mutated("apply_chunk(move)")
    kg.apply_chunk(migration.MigrationChunk(
        moves=[], n_triples=0, bytes=0, replica_adds=[(f, dst, src)]))
    mutated("apply_chunk(replica add)")
    kg.apply_chunk(migration.MigrationChunk(
        moves=[], n_triples=0, bytes=0, replica_drops=[(f, src)]))
    mutated("apply_chunk(replica drop)")
    kg.apply_write(WriteBatch(inserts=[[26, 0, 27]]))
    mutated("apply_write(insert)")
    kg.apply_write(WriteBatch(deletes=[[26, 0, 27]]))
    mutated("apply_write(delete)")
    target = kg.state.copy()
    target.feature_to_shard[f] = src
    kg.commit(target)
    mutated("commit")


def test_stale_cache_tripwire_asserts():
    """The epoch tags are a tripwire: serving a cache entry after an
    un-invalidated epoch bump fails loudly instead of returning stale
    data. (Simulates a hypothetical buggy mutation path — every real path
    invalidates, as the test above proves.)"""
    kg = _tiny_kg()
    q = Query(name="q", patterns=((var(0), 0, var(1)),))
    res = qexec.NumpyExecutor().run(kg.plan(q), kg)
    kg.store_result(q, *res)
    kg.profile(q)                        # cache the profile at data_version 0
    kg.epoch += 1                        # buggy path: bump without invalidate
    with pytest.raises(AssertionError, match="stale result"):
        kg.cached_result(q)
    with pytest.raises(AssertionError, match="stale plan"):
        kg.plan(q)
    kg.data_version += 1                 # buggy write: no profile invalidate
    with pytest.raises(AssertionError, match="stale profile"):
        kg.profile(q)


# --------------------------------------------------------------------------- #
# write-aware adaptation: heat, fanout pricing, demotion
# --------------------------------------------------------------------------- #

def test_service_folds_writes_into_controller_window(small_lubm):
    svc = KGService.from_dataset(small_lubm, 4)
    svc.bootstrap(small_lubm.base_workload())
    ctrl = svc.controller
    d = small_lubm.dictionary
    take = d.lookup("ub:takesCourse")
    # the workload tracks PO(takesCourse, grad_course0) — writes to that
    # (p, o) pair are owned by (and heat) the tracked fine-grained feature
    f = svc.space.po_index(take, small_lubm.named.grad_course0)
    assert f is not None
    s = int(svc.fresh_ids(1)[0])
    rep = svc.insert([[s, take, small_lubm.named.grad_course0]])
    assert rep.feature_writes == {f: 1}
    assert ctrl.write_heat[f] == 1
    assert len(svc.write_log) == 1
    # new predicate: controller state grows with the facade's placement
    nf0 = len(ctrl.state.feature_to_shard)
    rep = svc.insert([[1, d.encode("ex:newPred"), 2]])
    fid, _, shard = rep.new_features[0]
    assert len(ctrl.state.feature_to_shard) == nf0 + 1
    assert int(ctrl.state.feature_to_shard[fid]) == shard
    assert ctrl.write_heat[fid] == 1
    # window restart clears write heat with exec times
    ctrl.clear_window()
    assert not ctrl.write_heat.any() and not ctrl.exec_times


def test_propose_replicas_write_penalty(small_lubm, space):
    workload = small_lubm.base_workload()
    space.track_workload(workload)
    state = hash_partition(space.feature_sizes(), 4, 0)
    base = propose_replicas(space, state, workload, 1 << 20)
    reps = base.replicated()
    assert len(reps)                     # read-hot features got copies
    # hammering every proposed feature with writes suppresses promotion
    wh = np.zeros(space.n_features)
    wh[reps] = 1e9
    hot = propose_replicas(space, state, workload, 1 << 20,
                           write_heat=wh)
    assert not set(hot.replicated().tolist()) & set(reps.tolist())
    # zero write heat: bit-identical to the read-only proposal
    cold = propose_replicas(space, state, workload, 1 << 20,
                            write_heat=np.zeros(space.n_features))
    assert cold == base


def test_guard_prices_write_fanout_and_demotes(small_lubm):
    """Flat measured objective isolates the fanout term: with write heat on
    every replicated feature, the round drops the copies (recurring fanout
    saving, free drops) — with write_cost_weight=0 it keeps them."""
    def run(weight):
        space = FeatureSpace(
            small_lubm.store,
            type_predicate=small_lubm.dictionary.lookup("rdf:type"))
        workload = small_lubm.base_workload()
        space.track_workload(workload)
        cfg = AdaptConfig(replica_budget=1 << 20, amortize_window=10,
                          write_cost_weight=weight)
        ctrl = AWAPartController(space, 4, cfg)
        state = ctrl.initial_partition(workload)
        replicas = propose_replicas(space, state, workload,
                                    cfg.replica_budget)
        assert replicas.has_replicas
        # pin the layout: the round may only touch replicas
        orig = ctrl._assign

        def assign_fixed(queries, base, cut=None):
            _new, stats, ncl = orig(queries, base, cut=cut)
            return base.copy(), stats, ncl
        ctrl._assign = assign_fixed
        ctrl.write_heat = np.zeros(space.n_features)
        ctrl.write_heat[replicas.replicated()] = 1e6
        _, report = ctrl.adapt(
            [], measure=lambda cand, replicas=None: 1.0,
            net=qexec.NetworkModel(), replicas=replicas)
        return replicas, report

    replicas, report = run(weight=1.0)
    assert report.accepted
    assert report.replicas is not None
    assert not (set(report.replicas.replicated().tolist())
                & set(replicas.replicated().tolist()))
    assert report.fanout_bytes == 0      # nothing hot-written stays copied
    assert report.plan.replica_drops     # the demotions ride the plan

    replicas0, report0 = run(weight=0.0)
    # fanout priced at zero: flat objective, nothing to gain -> rejected,
    # the served copies stay exactly as they were
    assert not report0.accepted and report0.replicas is None


def test_extend_state_places_writeborn_p_features_least_loaded():
    state = PartitionState(np.array([0, 0, 1], np.int32),
                           np.array([10, 10, 1], np.int64), 3)
    # one PO child of feature 1, one parentless (write-born P) feature
    grown = migration.extend_state(
        state, np.array([10, 7, 1, 3, 5], np.int64), [1, -1])
    assert int(grown.feature_to_shard[3]) == 0      # inherits parent's shard
    assert int(grown.feature_to_shard[4]) == 2      # least-loaded shard
    assert grown.n_shards == 3


# --------------------------------------------------------------------------- #
# the acceptance property: interleavings == rebuild-from-scratch
# --------------------------------------------------------------------------- #

def _random_batch(rng, kg):
    """Random mutation mix: fresh rows (sometimes new predicates), duplicate
    inserts, deletes of present and absent triples."""
    n_ins = int(rng.integers(0, 12))
    n_del = int(rng.integers(0, 8))
    ins = np.stack([rng.integers(0, 45, n_ins),
                    rng.integers(0, 8, n_ins),      # preds 6/7 are new
                    rng.integers(0, 45, n_ins)],
                   axis=1).astype(np.int32).reshape(-1, 3)
    if n_ins and rng.random() < 0.5:    # sprinkle redundant inserts
        ins = np.concatenate(
            [ins, kg.store.triples[rng.integers(0, kg.store.n_triples, 2)]])
    dels = kg.store.triples[
        rng.integers(0, kg.store.n_triples, n_del)].copy().reshape(-1, 3)
    if n_del and rng.random() < 0.5:    # sprinkle absent deletes
        dels = np.concatenate([dels, np.array([[99, 99, 99]], np.int32)])
    return WriteBatch(inserts=ins, deletes=dels)


@settings(max_examples=max_examples(5, 2), deadline=None)
@given(st.integers(0, 2 ** 20))
def test_interleaved_writes_queries_chunks_match_rebuild(seed):
    """THE acceptance property: random interleavings of inserts, deletes,
    queries and migration chunks (with replica ops in flight) serve
    byte-identically to a rebuild-from-scratch PartitionedKG at every
    epoch, on numpy, jax and jax-pallas."""
    rng = np.random.default_rng(seed)
    store, space = _random_dataset(rng, n_triples=300)
    sizes = space.feature_sizes()
    n_shards = 4
    state = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    target = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    kg = PartitionedKG(store, space, state.copy(),
                       replicas=_random_replicas(rng, state))
    target_replicas = _random_replicas(rng, target)
    queries = [_random_query(rng, store, name=f"R{i}") for i in range(3)]
    budget = max(int(sizes.sum()) * migration.TRIPLE_BYTES // 4, 1)
    session = MigrationSession(kg, target, bytes_budget=budget,
                               target_replicas=target_replicas)

    epochs = {kg.epoch}
    _assert_matches_rebuild(kg, queries, f"seed={seed} pre")
    for step in range(6):
        action = rng.random()
        if action < 0.55:
            kg.apply_write(_random_batch(rng, kg))
        elif not session.done:
            session.step()
        epochs.add(kg.epoch)
        _assert_matches_rebuild(kg, queries, f"seed={seed} step={step}")
    session.drain()                      # mid-write universe growth is fine
    _assert_matches_rebuild(kg, queries, f"seed={seed} drained")
    nf = len(target.feature_to_shard)
    assert np.array_equal(kg.state.feature_to_shard[:nf],
                          target.feature_to_shard)
    assert np.array_equal(
        kg.replicas.masks[:len(target_replicas.masks)],
        target_replicas.masks)
    assert len(kg.state.feature_to_shard) == kg.space.n_features


@pytest.mark.slow
def test_service_writes_during_drain(small_lubm):
    """Service-level: insert/delete interleaved with query_batch windows
    while a budgeted drain is in flight; post-write rows ride later chunks
    and the final layout equals the accepted target."""
    svc = KGService.from_dataset(small_lubm, 4, migration_budget=150_000,
                                 replica_budget=200_000)
    svc.bootstrap(small_lubm.base_workload())
    window = small_lubm.workload(["Q1", "Q2", "Q9", "EQ1", "EQ4"])
    svc.query_batch(window)
    report = svc.adapt(small_lubm.workload(
        [f"EQ{i}" for i in range(1, 11)]))
    assert report.accepted and svc.session is not None
    t = svc.store.triples
    rng = np.random.default_rng(1)
    inserted = 0
    while svc.session is not None:
        rows = t[rng.integers(0, len(t), 32)].copy()
        rows[:, 0] = svc.fresh_ids(len(rows)).astype(np.int32)
        inserted += svc.insert(rows).n_inserted
        svc.delete(rows[:8])
        svc.query_batch(window)          # drains one chunk per window
    assert inserted > 0
    assert svc.write_log.n_inserted - svc.write_log.n_deleted > 0
    _assert_matches_rebuild(svc.kg, window, "service-drain")


# --------------------------------------------------------------------------- #
# vectorized write routing (PR-7 satellite): batch lookups + scalar parity
# --------------------------------------------------------------------------- #

def test_feature_space_batch_index_lookups(space):
    """`p_index_batch` / `po_index_batch` agree with the scalar lookups on
    every tracked key and return -1 on misses."""
    keys = [(i, space.key(i)) for i in range(space.n_features)]
    p_keys = [(i, k[1]) for i, k in keys if k[0] == "P"]
    po_keys = [(i, k[1], k[2]) for i, k in keys if k[0] == "PO"]
    assert p_keys and po_keys

    p = np.array([k[1] for k in p_keys] + [10 ** 6], dtype=np.int64)
    got = space.p_index_batch(p)
    assert got.dtype == np.int32
    assert got.tolist() == [k[0] for k in p_keys] + [-1]

    pp = np.array([k[1] for k in po_keys] + [10 ** 6], dtype=np.int64)
    oo = np.array([k[2] for k in po_keys] + [10 ** 6], dtype=np.int64)
    got = space.po_index_batch(pp, oo)
    assert got.tolist() == [k[0] for k in po_keys] + [-1]
    # a tracked PO probed with a different object is a miss, not its parent
    assert space.po_index_batch(pp[:1], np.array([10 ** 6])).tolist() == [-1]
    # empty batch round-trips
    assert space.p_index_batch(np.empty(0, np.int64)).shape == (0,)


def _typed_kg(seed, n_shards=3):
    """Randomized typed store (p=2 is rdf:type): P and PO features, room
    for new predicates and never-seen classes."""
    d = Dictionary()
    for i in range(40):
        d.encode(f"t{i}")
    rng = np.random.default_rng(seed)
    t = np.stack([rng.integers(0, 30, 150), rng.integers(0, 5, 150),
                  rng.integers(0, 30, 150)], axis=1).astype(np.int32)
    store = build_store(t, d)
    space = FeatureSpace(store, type_predicate=2)
    state = hash_partition(space.feature_sizes(), n_shards, 0)
    return PartitionedKG(store, space, state)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_owner_features_vectorized_matches_scalar(seed):
    """THE routing-parity property: the vectorized `_owner_features` and
    the scalar oracle derive identical owners, identical feature birth
    order/placement, and identical state growth — on typed and untyped
    universes, with new predicates, never-seen classes (repeated within
    one batch), and known PO/P rows mixed."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 48))
    rows = np.stack([rng.integers(0, 40, n),
                     rng.integers(0, 9, n),     # preds 5..8 are new
                     rng.integers(0, 40, n)], axis=1).astype(np.int32)
    for build in (lambda: _typed_kg(seed), _tiny_kg):
        kg_v, kg_s = build(), build()
        ov, nv = kgwrite._owner_features(kg_v, rows)
        os_, ns = kgwrite._owner_features_scalar(kg_s, rows)
        assert np.array_equal(ov, os_), (seed, rows[ov != os_])
        assert nv == ns
        assert np.array_equal(kg_v.state.feature_to_shard,
                              kg_s.state.feature_to_shard)
        assert np.array_equal(kg_v.state.feature_sizes,
                              kg_s.state.feature_sizes)
        assert kg_v.space.n_features == kg_s.space.n_features
        assert [kg_v.space.key(i) for i in range(kg_v.space.n_features)] \
            == [kg_s.space.key(i) for i in range(kg_s.space.n_features)]
        assert np.array_equal(kg_v.replicas.masks, kg_s.replicas.masks)


# --------------------------------------------------------------------------- #
# write-drift adaptation trigger (PR-7 satellite)
# --------------------------------------------------------------------------- #

def test_write_drift_thresholds_controller_unit(space):
    cfg = AdaptConfig(write_drift_min_rows=64, write_drift_ratio=0.5)
    ctrl = AWAPartController(space, 4, cfg)
    assert not ctrl.write_drift()            # no partition state yet
    n = space.n_features
    ctrl.state = PartitionState(np.zeros(n, np.int32),
                                np.full(n, 1000, np.int64), 4)
    assert not ctrl.write_drift()            # no heat
    ctrl.write_heat[5] = 63.0
    assert not ctrl.write_drift()            # below the min-rows gate
    ctrl.write_heat[5] = 400.0
    assert not ctrl.write_drift()            # 400 < 0.5 * size: ratio gate
    ctrl.write_heat[5] = 600.0
    assert ctrl.write_drift() and ctrl.should_adapt()
    ctrl._drift_seen = ctrl.write_heat.copy()   # a round judged this heat
    assert not ctrl.write_drift()
    ctrl.write_heat[5] += 700.0              # fresh churn re-arms the trigger
    assert ctrl.write_drift()
    ctrl.clear_window()
    assert not ctrl.write_drift() and not ctrl.write_heat.any()
    # knob off: never fires
    off = AWAPartController(space, 4, AdaptConfig(write_drift_min_rows=0))
    off.state = ctrl.state
    off.write_heat[:] = 10_000.0
    assert not off.write_drift()


def test_write_drift_triggers_service_round(small_lubm):
    """Heavy churn on one feature fires `should_adapt()` with zero query
    degradation; a round (accepted or not) consumes the signal; sub-
    threshold churn never fires."""
    svc = KGService.from_dataset(small_lubm, 4)
    svc.bootstrap(small_lubm.base_workload())
    svc.query_batch(small_lubm.base_workload())
    svc.reset_baseline(svc.avg_execution_time())
    assert not svc.should_adapt()            # healthy tail, no churn

    d = small_lubm.dictionary
    p_hot = d.encode("ub:streamEdge")        # a write-born predicate

    def burst(k):
        s = svc.fresh_ids(k).astype(np.int32)
        return np.stack([s, np.full(k, p_hot, np.int32), s], axis=1)

    svc.insert(burst(32))                    # below write_drift_min_rows
    assert not svc.should_adapt()
    svc.insert(burst(100))                   # 132 fresh rows, size 132
    assert svc.controller.write_drift() and svc.should_adapt()

    svc.adapt(())                            # the round consumes the signal
    assert not svc.controller.write_drift() and not svc.should_adapt()
    svc.insert(burst(32))                    # fresh churn below the gate
    assert not svc.should_adapt()

    # relative gate: 70 rows into a feature thousands of rows deep
    take = d.lookup("ub:takesCourse")
    s = svc.fresh_ids(70).astype(np.int32)
    svc.insert(np.stack([s, np.full(70, take, np.int32), s], axis=1))
    assert not svc.controller.write_drift()
