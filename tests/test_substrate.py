"""Optimizer, data pipeline, checkpointing, resilience, compression."""
import shutil
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm)
from repro.optim.compression import compress, compressed_gradients, decompress
from repro.runtime.resilience import (StragglerMonitor, SupervisorConfig,
                                      TrainSupervisor)


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0]), "ids": jnp.arange(3)}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"], "ids": None}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert (np.asarray(params["ids"]) == np.arange(3)).all()  # ints untouched


def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= cfg.peak_lr + 1e-9
    assert abs(lrs[10] - cfg.peak_lr) < 1e-9
    assert abs(lrs[100] - cfg.peak_lr * 0.1) < 1e-6


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    big = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(params, big, state, cfg)
    assert float(metrics["grad_norm"]) > 1e6   # reported pre-clip


# --------------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------------- #

@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=32).astype(np.float32))
    q, scale = compress(x)
    err = jnp.abs(decompress(q, scale) - x).max()
    assert float(err) <= float(scale) / 2 + 1e-9
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates():
    """With error feedback, the sum of k quantized steps converges to the
    sum of the raw gradients (residual carries over)."""
    g = {"w": jnp.full(8, 0.3, jnp.float32)}
    state = None
    total = jnp.zeros(8)
    for _ in range(50):
        deq, state = compressed_gradients(g, state)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total), 0.3 * 50, rtol=0.05)


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #

def test_stream_deterministic_and_host_sharded():
    cfg = DataConfig(seed=3, global_batch=8, seq_len=32)
    s1 = TokenStream(cfg, vocab_size=100)
    s2 = TokenStream(cfg, vocab_size=100)
    np.testing.assert_array_equal(s1.batch(7), s2.batch(7))
    # host sharding is a partition of the global batch
    import dataclasses
    parts = []
    for host in range(4):
        c = dataclasses.replace(cfg, host_id=host, n_hosts=4)
        parts.append(TokenStream(c, 100).host_batch(7)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), s1.batch(7))


def test_prefetcher_orders_batches():
    cfg = DataConfig(seed=1, global_batch=2, seq_len=8)
    stream = TokenStream(cfg, vocab_size=50)
    pf = Prefetcher(stream)
    steps = [next(pf)[0] for _ in range(5)]
    pf.close()
    assert steps == [0, 1, 2, 3, 4]


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32), "d": None}}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    out = ckpt.restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), [1, 2])
    assert out["b"]["d"] is None


def test_checkpoint_keep_last(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for step in (1, 2, 3, 4):
        ckpt.save(tmp_path, step, tree, keep_last=2)
    assert ckpt.available_steps(tmp_path) == [3, 4]


def test_async_checkpointer(tmp_path):
    ac = ckpt.AsyncCheckpointer(tmp_path, keep_last=2)
    ac.save(1, {"x": jnp.ones(3)})
    ac.wait()
    out = ckpt.restore(tmp_path, 1, {"x": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(out["x"]), 1.0)


def test_restore_with_new_sharding(tmp_path):
    """Elastic restore: same bytes, different placement spec."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt.save(tmp_path, 0, tree)
    # axis_types / AxisType only exist on newer jax
    kwargs = ({"axis_types": (jax.sharding.AxisType.Auto,)}
              if hasattr(jax.sharding, "AxisType") else {})
    mesh = jax.make_mesh((1,), ("data",), **kwargs)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out = ckpt.restore(tmp_path, 0, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))


# --------------------------------------------------------------------------- #
# resilience
# --------------------------------------------------------------------------- #

def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor()
    for step in range(10):
        mon.record(step, 0.1)
    assert mon.record(10, 0.5, host_times={0: 0.1, 3: 0.5})
    assert mon.flagged[-1]["host"] == 3


def test_supervisor_recovers_from_failure(tmp_path):
    calls = {"n": 0, "failed": False}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 7 and not calls["failed"]:
            calls["failed"] = True
            raise RuntimeError("injected")
        return dict(state, value=state["value"] + 1)

    sup = TrainSupervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=3,
                         max_failures=2),
        step_fn,
        state_to_tree=lambda s: {"value": jnp.asarray(float(s["value"]))},
        tree_to_state=lambda tree, s: dict(s, value=float(tree["value"])),
    )
    final = sup.run({"value": 0.0}, 12)
    assert sup.failures == 1
    assert sup.restores == 1
    # ckpt after steps 2 and 5; failure at 7 -> restore value 6, resume at 6
    assert final["value"] == 12.0


def test_supervisor_gives_up_after_max_failures(tmp_path):
    def step_fn(state, step):
        raise RuntimeError("always broken")

    sup = TrainSupervisor(
        SupervisorConfig(ckpt_dir=str(tmp_path), max_failures=2),
        step_fn, state_to_tree=lambda s: {}, tree_to_state=lambda t, s: s)
    with pytest.raises(RuntimeError):
        sup.run({}, 5)
