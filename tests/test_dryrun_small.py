"""Small-mesh dry-run: lower + compile reduced cells on 8 fake devices.

Runs in a subprocess because the placeholder device count must be set before
jax initializes (the main test process keeps the single real CPU device)."""
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro import compat
import repro.configs as configs
from repro.launch import hlo_analysis, sharding
from repro.launch.mesh import dp_axes, make_host_mesh
from repro.models import lm, transformer
from repro.models.moe import ShardCtx
from repro.optim import AdamWConfig, adamw_init

mesh = make_host_mesh(data=2, model=4)
for arch in ("smollm-360m", "olmoe-1b-7b", "rwkv6-3b", "zamba2-7b"):
    cfg = dataclasses.replace(
        configs.get(arch).reduced(),
        d_model=128, d_ff=256,
        n_heads=4 if configs.get(arch).n_heads else 0,
        n_kv_heads=4 if configs.get(arch).n_kv_heads else 0,
        head_dim=0)
    ctx = ShardCtx(mesh=mesh, dp_axes=dp_axes(mesh))
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda: transformer.init_params(key, cfg)[0])
    _, axes = transformer.init_params(key, cfg)
    p_sh = sharding.tree_shardings(axes, params_sds, mesh, kind="param")
    opt_sds = jax.eval_shape(adamw_init, params_sds)
    o_sh = sharding.opt_state_shardings(axes, params_sds, opt_sds, mesh)
    batch_sds = {"tokens": jax.ShapeDtypeStruct((4, 32), jax.numpy.int32)}
    if cfg.embedding_inputs:
        continue
    b_sh = sharding.batch_specs(batch_sds, mesh)
    opt_cfg = AdamWConfig()

    def step(params, opt_state, batch, cfg=cfg, ctx=ctx):
        return lm.train_step(params, opt_state, batch, cfg, ctx, opt_cfg)

    with compat.set_mesh(mesh):
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
            params_sds, opt_sds, batch_sds)
        compiled = lowered.compile()
    cost = compat.cost_analysis(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    assert cost.get("flops", 0) > 0, arch
    assert coll["total_count"] > 0, arch    # DP grads must sync
    assert compiled.memory_analysis() is not None or True
    print(f"{arch}: OK flops={cost['flops']:.2e} "
          f"coll={coll['total_bytes']:.2e}")
print("DRYRUN-SMALL-OK")
"""


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert "DRYRUN-SMALL-OK" in res.stdout, (res.stdout[-1000:],
                                             res.stderr[-2000:])
