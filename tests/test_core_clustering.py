"""Jaccard distance + HAC: kernel vs oracle vs scipy, hypothesis properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from scipy.cluster.hierarchy import fcluster
from scipy.cluster.hierarchy import linkage as scipy_linkage
from scipy.spatial.distance import squareform

from repro.core import hac
from repro.kernels.jaccard import kernel as jk
from repro.kernels.jaccard import ops as jops
from repro.kernels.jaccard import ref as jref


def _bitmaps(rng, n, words):
    return rng.integers(0, 2 ** 32, size=(n, words), dtype=np.uint32)


@pytest.mark.parametrize("n,words", [(4, 1), (14, 2), (24, 4), (64, 8),
                                     (130, 3)])
def test_jaccard_kernel_matches_ref(rng, n, words):
    a = _bitmaps(rng, n, words)
    d_ref = np.asarray(jref.jaccard_distance(jnp.asarray(a), jnp.asarray(a)))
    d_ker = np.asarray(jk.jaccard_distance_pallas(
        jnp.asarray(a), jnp.asarray(a), block_q=32, block_k=32,
        interpret=True))
    np.testing.assert_allclose(d_ref, d_ker, atol=1e-6)


def test_jaccard_against_numpy_popcount(rng):
    a = _bitmaps(rng, 10, 3)
    d = np.asarray(jops.jaccard_distance(a, use_kernel=False))
    for i in range(10):
        for j in range(10):
            inter = np.bitwise_count(a[i] & a[j]).sum()
            union = np.bitwise_count(a[i] | a[j]).sum()
            expect = 1 - inter / union if union else 0.0
            assert abs(d[i, j] - expect) < 1e-6


@given(st.integers(2, 24), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_jaccard_properties(n, words, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2 ** 32, size=(n, words), dtype=np.uint32)
    d = np.asarray(jops.jaccard_distance(a, use_kernel=False))
    assert (d >= -1e-6).all() and (d <= 1 + 1e-6).all()
    np.testing.assert_allclose(d, d.T, atol=1e-6)          # symmetry
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-6)  # identity


@pytest.mark.parametrize("linkage", ["single", "complete", "average"])
@pytest.mark.parametrize("n", [5, 14, 30])
def test_hac_matches_scipy(rng, linkage, n):
    pts = rng.random((n, 3))
    dist = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1))
    z_np = hac.hac_numpy(dist, linkage)
    z_jx = np.asarray(hac.hac_jax(dist.astype(np.float32), linkage))
    np.testing.assert_allclose(z_np[:, 2], z_jx[:, 2], atol=1e-5)
    z_sp = scipy_linkage(squareform(dist, checks=False), method=linkage)

    def canon(lbl):
        return {tuple(sorted(np.where(lbl == v)[0])) for v in set(lbl)}

    for thr in (0.3, 0.6, 0.9):
        mine = hac.cut(z_np, thr)
        theirs = fcluster(z_sp, t=thr, criterion="distance")
        assert canon(mine) == canon(theirs)


@given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_hac_cut_is_partition(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n))
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0)
    z = hac.hac_numpy(d, "average")
    for thr in (0.0, 0.5, 2.0):
        labels = hac.cut(z, thr)
        assert labels.shape == (n,)
        assert labels.min() >= 0
    # at threshold >= max distance everything merges
    assert len(set(hac.cut(z, d.max() + 1).tolist())) == 1
