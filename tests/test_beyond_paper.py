"""Beyond-paper extensions: guard-selected HAC cut, sharded-safe CE."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaptConfig, AWAPartController
from repro.core.features import FeatureSpace
from repro.models.lm import _cross_entropy


def test_onehot_ce_matches_take_along(rng):
    logits = jnp.asarray(rng.normal(size=(2, 8, 32)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32)
    ours = _cross_entropy(logits, tgt)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ref = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), atol=1e-5)


def test_adapt_reports_chosen_cut(small_lubm):
    space = FeatureSpace(small_lubm.store,
                         type_predicate=small_lubm.dictionary.lookup("rdf:type"))
    cfg = AdaptConfig(cut_candidates=(0.5, 0.75))
    ctrl = AWAPartController(space, n_shards=4, config=cfg)
    base = small_lubm.base_workload()
    space.track_workload(base)
    ctrl.initial_partition(base)
    _, report = ctrl.adapt(small_lubm.workload(["EQ1", "EQ2", "EQ3"]))
    assert report.chosen_cut in cfg.cut_candidates
    # the report carries the real cluster count of the winning cut
    assert 0 < report.n_clusters <= len(ctrl.workload)


def test_adapt_single_cut_fallback(small_lubm):
    """Empty candidate tuple -> the paper's fixed manual cut."""
    space = FeatureSpace(small_lubm.store,
                         type_predicate=small_lubm.dictionary.lookup("rdf:type"))
    cfg = AdaptConfig(cut_candidates=(), cut_distance=0.7)
    ctrl = AWAPartController(space, n_shards=4, config=cfg)
    base = small_lubm.base_workload()
    space.track_workload(base)
    ctrl.initial_partition(base)
    _, report = ctrl.adapt(small_lubm.workload(["EQ1"]))
    assert report.chosen_cut == 0.7


def test_guard_never_regresses_objective(lubm3):
    """Whatever cut wins, the accept/revert guard keeps dj monotone."""
    from repro.query import exec as qexec
    from repro.query.engine import ShardedStore
    space = FeatureSpace(lubm3.store,
                         type_predicate=lubm3.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=8)
    base = lubm3.base_workload()
    space.track_workload(base)
    ctrl.initial_partition(base)

    def measure(cand):
        sh = ShardedStore(lubm3.store, space, cand)
        return qexec.workload_average_time(list(ctrl.workload.values()), sh)

    _, rep = ctrl.adapt(lubm3.workload([f"EQ{i}" for i in range(1, 11)]),
                        measure=measure)
    if rep.accepted:
        assert rep.t_new < rep.t_base
    else:
        assert rep.plan.n_moves == 0
