"""repro.replicate: ReplicaMap mechanics, replica-aware planning/execution
equivalence (numpy == jax == jax-pallas, including mid-drain epochs),
nearest-replica federation accounting, budgeted promotion/demotion, and the
result-cache / mid-drain-guard satellites."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings, max_examples
from test_executors import _random_dataset, _random_query

from repro.api import KGService, MigrationSession, PartitionedKG, ReplicaMap
from repro.core import migration
from repro.core.partition import PartitionState, hash_partition
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.pattern import Query, var
from repro.replicate import propose_replicas


# --------------------------------------------------------------------------- #
# ReplicaMap mechanics
# --------------------------------------------------------------------------- #

def _state(f2s, sizes=None, n_shards=None):
    f2s = np.asarray(f2s, np.int32)
    sizes = (np.ones(len(f2s), np.int64) if sizes is None
             else np.asarray(sizes, np.int64))
    return PartitionState(f2s, sizes,
                          n_shards or int(f2s.max()) + 1)


def test_replica_map_basics():
    state = _state([0, 1, 2], sizes=[10, 20, 30], n_shards=3)
    rmap = ReplicaMap.primary_only(state)
    assert not rmap.has_replicas
    assert rmap.holders(1) == [1]
    assert np.array_equal(rmap.n_copies(), [1, 1, 1])
    assert rmap.replica_bytes(state.feature_sizes) == 0

    rmap.add(0, 2)
    rmap.add(0, 0)                       # primary bit: no-op
    assert rmap.has_replicas and rmap.has(0, 2)
    assert rmap.holders(0) == [0, 2]
    assert np.array_equal(rmap.replicated(), [0])
    assert rmap.replica_bytes(state.feature_sizes) == \
        10 * migration.TRIPLE_BYTES

    rmap.move_primary(0, 0, 1)           # copy leaves 0, lands on 1
    assert rmap.holders(0) == [1, 2]
    rmap.remove(0, 2)
    assert not rmap.has_replicas

    rmap.extend(np.array([1, 1, 2, 0], np.int32))
    assert rmap.n_features == 4 and rmap.holders(3) == [0]


def test_primary_only_votes_match_replica_free_ppn(small_lubm, space):
    """A primary-only map must leave every PPN vote unchanged — the seed
    behaviour of every facade plan."""
    space.track_workload(small_lubm.base_workload())
    state = hash_partition(space.feature_sizes(), 4, seed=0)
    rmap = ReplicaMap.primary_only(state)
    for q in small_lubm.extended_workload():
        assert qplan.primary_shard(q, space, state) == \
            qplan.primary_shard(q, space, state, rmap)


# --------------------------------------------------------------------------- #
# replica-aware migration plans and chunks
# --------------------------------------------------------------------------- #

def test_plan_with_replica_delta_adds_drops_and_bytes():
    sizes = np.array([5, 7, 11], np.int64)
    old = _state([0, 1, 2], sizes, 3)
    new = _state([1, 1, 2], sizes, 3)    # feature 0 moves 0 -> 1
    r_old = ReplicaMap.primary_only(old)
    r_old.add(1, 0)                      # a replica that will fall cold
    r_new = ReplicaMap.primary_only(new)
    r_new.add(0, 0)                      # keep a copy at 0's old primary
    r_new.add(2, 1)                      # fresh copy: real traffic

    plan = migration.plan(old, new, r_old, r_new)
    assert plan.moves == [(0, 0, 1)]
    # the retained old-primary copy ships nothing (src == dst marks local)
    assert (0, 0, 0) in plan.replica_adds
    assert (2, 2, 1) in plan.replica_adds
    assert plan.replica_drops == [(1, 0)]
    assert plan.n_triples == 5 + 11      # move + the one real copy
    assert plan.bytes == (5 + 11) * migration.TRIPLE_BYTES

    chunks = migration.chunk_plan(plan, sizes, bytes_budget=1)
    assert sum(c.bytes for c in chunks) == plan.bytes
    assert sorted(m for c in chunks for m in c.moves) == sorted(plan.moves)
    assert sorted(a for c in chunks for a in c.replica_adds) == \
        sorted(plan.replica_adds)
    assert sorted(d for c in chunks for d in c.replica_drops) == \
        sorted(plan.replica_drops)
    # feature 0's move and its retained-copy add are atomic: same chunk
    for c in chunks:
        assert ((0, 0, 1) in c.moves) == ((0, 0, 0) in c.replica_adds)


def test_apply_chunk_with_replica_ops_updates_views_and_epoch(small_lubm):
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    f = int(np.argmax(kg.state.feature_sizes))
    src = int(kg.state.feature_to_shard[f])
    dst = (src + 1) % kg.n_shards

    epoch0, n_rows0 = kg.epoch, sum(len(v.triples) for v in kg.shards)
    chunk = migration.MigrationChunk(moves=[], n_triples=0, bytes=0,
                                     replica_adds=[(f, src, dst)])
    kg.apply_chunk(chunk)
    assert kg.epoch == epoch0 + 1
    assert kg.replicas.has(f, dst)
    # the copy is materialized in dst's view (and only there)
    extra = int(kg.state.feature_sizes[f])
    assert sum(len(v.triples) for v in kg.shards) == n_rows0 + extra
    assert kg.shard_sizes() == [len(r) for r in kg._rows]   # primaries only

    # read layout: the feature's triples read locally at dst, else primary
    rows_f = np.flatnonzero(kg.owners == f)
    assert (kg.read_shard(dst)[rows_f] == dst).all()
    other = (dst + 1) % kg.n_shards
    assert (kg.read_shard(other)[rows_f] == src).all()

    # dropping the copy restores the original layout (new epoch again)
    kg.apply_chunk(migration.MigrationChunk(
        moves=[], n_triples=0, bytes=0, replica_drops=[(f, dst)]))
    assert kg.epoch == epoch0 + 2
    assert not kg.replicas.has_replicas
    assert sum(len(v.triples) for v in kg.shards) == n_rows0


# --------------------------------------------------------------------------- #
# executor equivalence on replicated layouts (the acceptance property)
# --------------------------------------------------------------------------- #

def _random_replicas(rng, state):
    rmap = ReplicaMap.primary_only(state)
    for f in range(len(state.feature_to_shard)):
        if rng.random() < 0.4:
            rmap.add(f, int(rng.integers(state.n_shards)))
    return rmap


def _assert_all_backends_match(kg, queries, refs=None):
    """numpy == jax == jax-pallas bindings and ExecStats on ``kg``; when
    ``refs`` (committed-layout results) are given, bindings and row counts
    must match those too."""
    execs = [qexec.NumpyExecutor(), qexec.JaxExecutor(),
             qexec.JaxExecutor(pallas=True, probe_kernel=True),
             qexec.JaxExecutor(pallas=True)]
    plans = [kg.plan(q) for q in queries]
    all_res = [ex.run_batch(plans, kg) for ex in execs]
    for qi, q in enumerate(queries):
        ref_b, ref_s = all_res[0][qi]
        for ex, res in zip(execs[1:], all_res[1:]):
            b, s = res[qi]
            assert canon_bindings(b) == canon_bindings(ref_b), \
                (q.name, ex.name, kg.epoch)
            for f in qexec.ExecStats.COMPARABLE:
                assert getattr(s, f) == getattr(ref_s, f), \
                    (q.name, ex.name, f, kg.epoch)
        if refs is not None:
            rb, rs = refs[qi]
            assert canon_bindings(ref_b) == canon_bindings(rb), \
                (q.name, kg.epoch)
            assert ref_s.rows == rs.rows
        # nearest-replica re-accounting from the layout-invariant profile
        # reproduces the executed federation stats exactly
        est = qplan.stats_from_profile(q, kg.profile(q), kg.space, kg.state,
                                       kg.triple_shard,
                                       replicas=kg.replicas, owners=kg.owners)
        for f in qexec.ExecStats.COMPARABLE:
            assert getattr(est, f) == getattr(ref_s, f), \
                (q.name, "profile", f, kg.epoch)


@settings(max_examples=max_examples(10, 4), deadline=None)
@given(st.integers(0, 2 ** 20))
def test_backends_and_profile_agree_on_random_replicated_layouts(seed):
    """Property: on random stores, BGPs, layouts AND replica sets, every
    backend produces identical bindings/stats, and stats_from_profile's
    nearest-replica accounting reproduces them exactly."""
    rng = np.random.default_rng(seed)
    store, space = _random_dataset(rng)
    state = hash_partition(space.feature_sizes(),
                           int(rng.integers(2, 7)), seed=seed % 17)
    kg = PartitionedKG(store, space, state,
                       replicas=_random_replicas(rng, state))
    queries = [_random_query(rng, store, name=f"R{i}") for i in range(3)]
    _assert_all_backends_match(kg, queries)


@settings(max_examples=max_examples(6, 3), deadline=None)
@given(st.integers(0, 2 ** 20))
def test_mid_drain_epochs_with_replica_ops_serve_identically(seed):
    """At EVERY epoch of a drain that moves features AND promotes/demotes
    replicas, all backends agree with each other and with the committed
    layout's bindings."""
    rng = np.random.default_rng(seed)
    store, space = _random_dataset(rng)
    sizes = space.feature_sizes()
    n_shards = 4
    state = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    target = hash_partition(sizes, n_shards, seed=int(rng.integers(1 << 16)))
    kg = PartitionedKG(store, space, state.copy(),
                       replicas=_random_replicas(rng, state))
    target_replicas = _random_replicas(rng, target)
    ref_kg = PartitionedKG(store, space, target.copy(),
                           replicas=target_replicas.copy())
    queries = [_random_query(rng, store, name=f"R{i}") for i in range(3)]
    refs = [qexec.NumpyExecutor().run(ref_kg.plan(q), ref_kg)
            for q in queries]

    budget = max(int(sizes.sum()) * migration.TRIPLE_BYTES // 5, 1)
    session = MigrationSession(kg, target, bytes_budget=budget,
                               target_replicas=target_replicas)
    epochs = []
    while True:                          # includes the pre-drain epoch
        epochs.append(kg.epoch)
        _assert_all_backends_match(kg, queries, refs=refs)
        if session.step() is None:
            break
    assert np.array_equal(kg.state.feature_to_shard,
                          target.feature_to_shard)
    assert kg.replicas == target_replicas
    assert len(set(epochs)) == len(epochs)


def test_nearest_replica_accounting_unit():
    """Hand-built 2-shard layout: a small feature homed off-PPN ships its
    matches — until a replica lands on the PPN, which zeroes the shipping
    and re-homes the scan."""
    from repro.graph.triples import Dictionary, build_store
    from repro.core.features import FeatureSpace

    d = Dictionary()
    for i in range(40):
        d.encode(f"t{i}")
    p_big, p_small = 1, 2
    rows = [[i + 3, p_big, 30] for i in range(20)] \
        + [[i + 3, p_small, 31] for i in range(4)]
    store = build_store(np.array(rows, np.int32), d)
    space = FeatureSpace(store)
    f_big = space.p_index(p_big)
    f_small = space.p_index(p_small)
    f2s = np.zeros(space.n_features, np.int32)
    f2s[f_big], f2s[f_small] = 1, 0
    state = PartitionState(f2s, space.feature_sizes(), 2)

    x = var(0)
    q = Query(name="near", patterns=((x, p_big, 30), (x, p_small, 31)))

    kg0 = PartitionedKG(store, space, state.copy())
    plan0 = kg0.plan(q)
    assert plan0.ppn == 1                # the big feature wins the vote
    _, s0 = qexec.NumpyExecutor().run(plan0, kg0)
    assert s0.rows_shipped == 4          # p_small matches shipped from 0
    assert s0.bytes_shipped == 4 * migration.TRIPLE_BYTES

    rmap = ReplicaMap.primary_only(state)
    rmap.add(f_small, 1)                 # copy beside the PPN
    kg1 = PartitionedKG(store, space, state.copy(), replicas=rmap)
    plan1 = kg1.plan(q)
    assert plan1.ppn == 1
    assert all(not op.service for op in plan1.ops)   # both ops now local
    _, s1 = qexec.NumpyExecutor().run(plan1, kg1)
    assert s1.rows_shipped == 0 and s1.bytes_shipped == 0
    assert s1.messages == 0 and s1.distributed_joins == 0
    assert canon_bindings(qexec.NumpyExecutor().run(plan0, kg0)[0]) == \
        canon_bindings(qexec.NumpyExecutor().run(plan1, kg1)[0])

    est = qplan.stats_from_profile(q, kg1.profile(q), space, kg1.state,
                                   kg1.triple_shard, replicas=rmap,
                                   owners=kg1.owners)
    assert est.bytes_shipped == 0 and est.rows_shipped == 0


def test_drain_retains_copy_at_old_primary(small_lubm):
    """A move whose target map keeps a read copy at the feature's OLD
    primary must land with that copy intact (the add applies with post-move
    semantics — the move clears the bit, the add restores it)."""
    svc = KGService.from_dataset(small_lubm, n_shards=4)
    kg = svc.bootstrap(small_lubm.base_workload())
    f = int(np.argmax(kg.state.feature_sizes))
    s0 = int(kg.state.feature_to_shard[f])
    s1 = (s0 + 1) % kg.n_shards
    target = kg.state.copy()
    target.feature_to_shard[f] = s1
    target_rep = kg.replicas.copy()
    target_rep.move_primary(f, s0, s1)
    target_rep.add(f, s0)

    session = MigrationSession(kg, target, bytes_budget=1,
                               target_replicas=target_rep)
    adds = [a for c in session.chunks for a in c.replica_adds]
    assert (f, s0, s0) in adds               # zero-traffic retained copy
    session.drain()
    assert kg.replicas == target_rep
    assert kg.replicas.has(f, s0)
    rows_f = np.flatnonzero(kg.owners == f)
    assert (kg.read_shard(s0)[rows_f] == s0).all()
    assert (kg.triple_shard[rows_f] == s1).all()


def test_move_onto_existing_replica_ships_nothing():
    """A primary move whose destination already holds a replica copy is a
    re-designation, not a transfer: zero bytes, zero pairs, and the chunk
    budget is not consumed by phantom traffic."""
    sizes = np.array([5], np.int64)
    old, new = _state([0], sizes, 2), _state([1], sizes, 2)
    r_old = ReplicaMap.primary_only(old)
    r_old.add(0, 1)
    plan = migration.plan(old, new, r_old, ReplicaMap.primary_only(new))
    assert plan.moves == [(0, 0, 1)] and plan.local_moves == [0]
    assert plan.bytes == 0 and plan.n_triples == 0
    net = qexec.NetworkModel(latency_s=0.1, bandwidth_Bps=1000.0)
    assert migration.migration_seconds(plan, net) == 0.0
    chunks = migration.chunk_plan(plan, sizes, bytes_budget=1)
    assert sum(c.bytes for c in chunks) == 0
    assert [m for c in chunks for m in c.moves] == plan.moves


def test_replica_unaware_custom_measure_disables_replication():
    """A custom objective without a ``replicas`` parameter must neither
    crash nor silently receive a ReplicaMap: the round runs primary-only.
    One with a keyword-only ``replicas`` opts in."""
    from repro.core.adaptive import _accepts_replicas

    assert not _accepts_replicas(lambda cand: 0.0)
    assert not _accepts_replicas(lambda cand, scale=1.0: 0.0)
    assert _accepts_replicas(lambda cand, replicas=None: 0.0)
    assert _accepts_replicas(lambda cand, *, replicas=None: 0.0)
    assert _accepts_replicas(lambda cand, **kw: 0.0)


# --------------------------------------------------------------------------- #
# promotion/demotion policy under a byte budget
# --------------------------------------------------------------------------- #

def _policy_fixture():
    from repro.graph.triples import Dictionary, build_store
    from repro.core.features import FeatureSpace

    d = Dictionary()
    for i in range(60):
        d.encode(f"t{i}")
    p_anchor, p_hot, p_cool = 1, 2, 3
    rows = [[i + 4, p_anchor, 40] for i in range(30)] \
        + [[i + 4, p_hot, 41] for i in range(6)] \
        + [[i + 4, p_cool, 42] for i in range(6)]
    store = build_store(np.array(rows, np.int32), d)
    space = FeatureSpace(store)
    f2s = np.zeros(space.n_features, np.int32)
    f2s[space.p_index(p_anchor)] = 1     # queries home on shard 1
    state = PartitionState(f2s, space.feature_sizes(), 2)
    x = var(0)
    hot = Query(name="hot", frequency=9.0,
                patterns=((x, p_anchor, 40), (x, p_hot, 41)))
    cool = Query(name="cool", frequency=1.0,
                 patterns=((x, p_anchor, 40), (x, p_cool, 42)))
    return space, state, [hot, cool], p_hot, p_cool


def test_propose_replicas_promotes_hottest_within_budget():
    space, state, queries, p_hot, p_cool = _policy_fixture()
    f_hot, f_cool = space.p_index(p_hot), space.p_index(p_cool)
    one_copy = int(state.feature_sizes[f_hot]) * migration.TRIPLE_BYTES

    assert not propose_replicas(space, state, queries, 0).has_replicas
    assert not propose_replicas(space, state, queries,
                                one_copy - 1).has_replicas

    tight = propose_replicas(space, state, queries, one_copy)
    assert tight.has(f_hot, 1)           # hottest feature promoted to PPN
    assert not tight.has(f_cool, 1)      # the cold one did not fit
    assert tight.replica_bytes(state.feature_sizes) <= one_copy

    roomy = propose_replicas(space, state, queries, 4 * one_copy)
    assert roomy.has(f_hot, 1) and roomy.has(f_cool, 1)
    assert roomy.replica_bytes(state.feature_sizes) <= 4 * one_copy


def test_cold_replicas_are_demoted_via_plan_delta():
    space, state, queries, p_hot, p_cool = _policy_fixture()
    f_hot, f_cool = space.p_index(p_hot), space.p_index(p_cool)
    current = ReplicaMap.primary_only(state)
    current.add(f_cool, 1)               # stale copy from an older workload
    one_copy = int(state.feature_sizes[f_hot]) * migration.TRIPLE_BYTES

    proposed = propose_replicas(space, state, queries, one_copy)
    plan = migration.plan(state, state, current, proposed)
    assert plan.moves == []
    assert (f_hot, 0, 1) in plan.replica_adds       # promotion ships from 0
    assert (f_cool, 1) in plan.replica_drops        # demotion
    assert plan.bytes == one_copy                   # drops are free


# --------------------------------------------------------------------------- #
# service loop: replica_budget knob, drain, guard + result-cache satellites
# --------------------------------------------------------------------------- #

def test_service_replica_round_reduces_bytes_and_drains(small_lubm):
    """replica_budget > 0 threads end to end: the accepted round promotes
    copies through a chunked MigrationSession, the drained layout serves
    strictly fewer shipped bytes than its primary-only twin, and
    should_adapt stays False mid-drain."""
    window = small_lubm.extended_workload()
    new10 = small_lubm.workload([f"EQ{i}" for i in range(1, 11)])

    base = KGService.from_dataset(small_lubm, n_shards=4)
    base.bootstrap(small_lubm.base_workload())
    base.query_batch(window)
    rep0 = base.adapt(new10)
    assert rep0.accepted and not base.kg.replicas.has_replicas

    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 migration_budget=120_000,
                                 replica_budget=256_000)
    assert svc.controller is None       # config carried by the partitioner
    svc.bootstrap(small_lubm.base_workload())
    assert svc.controller.config.replica_budget == 256_000
    svc.query_batch(window)
    report = svc.adapt(new10)
    assert report.accepted
    assert report.replicas is not None and report.replicas.has_replicas
    assert report.plan.replica_adds
    assert report.replica_bytes <= 256_000
    assert svc.session is not None

    while svc.session is not None:
        assert not svc.should_adapt()   # mid-drain guard satellite
        svc.query_batch(window)
    assert svc.kg.replicas == report.replicas

    bytes_plain = sum(st.bytes_shipped
                      for _, st in base.query_batch(window))
    bytes_repl = sum(st.bytes_shipped
                     for _, st in svc.query_batch(window))
    assert bytes_repl < bytes_plain


def test_result_cache_skips_reexecution_and_invalidates_on_epoch(small_lubm):
    """Satellite: a repeated (query, epoch) pair is served without touching
    the executor; any epoch bump (here: a replica promotion) invalidates."""
    class CountingExecutor(qexec.NumpyExecutor):
        calls = 0

        def run_batch(self, plans, kg):
            CountingExecutor.calls += len(plans)
            return super().run_batch(plans, kg)

    svc = KGService.from_dataset(small_lubm, n_shards=4,
                                 executor=CountingExecutor())
    kg = svc.bootstrap(small_lubm.base_workload())
    window = small_lubm.extended_workload()

    first = svc.query_batch(window)
    assert CountingExecutor.calls == len(window)
    # mutating a returned result must not corrupt later hits
    for b, _ in first:
        for c in b.values():
            c[:] = -1
    again = svc.query_batch(window)                  # same epoch: all hits
    assert CountingExecutor.calls == len(window)
    assert kg.result_hits == len(window)
    for (b0, s0), (b1, s1) in zip(first, again):
        assert s1 == s0 and s1 is not s0             # stats snapshot, too
        assert all((c != -1).all() for c in b1.values() if len(c))

    f = int(np.argmax(kg.state.feature_sizes))
    src = int(kg.state.feature_to_shard[f])
    kg.apply_chunk(migration.MigrationChunk(
        moves=[], n_triples=0, bytes=0,
        replica_adds=[(f, src, (src + 1) % kg.n_shards)]))
    svc.query_batch(window)                          # new epoch: re-executed
    assert CountingExecutor.calls == 2 * len(window)