"""Minimal deterministic stand-in for the ``hypothesis`` library.

Loaded only when the real package is unavailable (see ``conftest.py``):
property tests degrade to seeded example sweeps — every ``@given`` test runs
``max_examples`` deterministic cases (boundary values first, then seeded
randoms) instead of being skipped, so the tier-1 suite keeps its coverage on
machines without dev dependencies.

Only the surface this repo uses is implemented: ``given``, ``settings``
(``max_examples`` / ``deadline``), ``assume``, ``note``,
``strategies.integers`` and ``strategies.sampled_from``.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, List

import numpy as np

__version__ = "0.0-shim"
_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    """A value source: boundary examples first, then seeded randoms."""

    def __init__(self, boundaries: List[Any], sample: Callable):
        self._boundaries = boundaries
        self._sample = sample

    def example(self, rng: np.random.Generator, case: int) -> Any:
        if case < len(self._boundaries):
            return self._boundaries[case]
        return self._sample(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        boundaries=[int(min_value), int(max_value)],
        sample=lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements) -> _Strategy:
    elems = list(elements)
    assert elems, "sampled_from() needs a non-empty collection"
    return _Strategy(
        boundaries=list(dict.fromkeys([elems[0], elems[-1]])),
        sample=lambda rng: elems[int(rng.integers(len(elems)))])


class _StrategiesNamespace:
    integers = staticmethod(_integers)
    sampled_from = staticmethod(_sampled_from)


strategies = _StrategiesNamespace()


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition: bool) -> bool:
    if not condition:
        raise UnsatisfiedAssumption
    return True


def note(message: str) -> None:   # pragma: no cover - debugging aid
    print(message)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored) -> Callable:
    def decorate(fn: Callable) -> Callable:
        fn._shim_max_examples = max_examples
        return fn
    return decorate


def given(*gstrats: _Strategy) -> Callable:
    """Fill the test's rightmost parameters from the given strategies
    (matching hypothesis semantics); remaining parameters stay visible to
    pytest as fixtures."""

    def decorate(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        assert len(params) >= len(gstrats), \
            f"{fn.__name__}: more strategies than parameters"
        fixture_params = params[:len(params) - len(gstrats)]
        # hypothesis fills the RIGHTMOST parameters; bind them by name so
        # pytest-supplied fixture kwargs (the leftmost params) can coexist
        gen_names = [p.name for p in params[len(params) - len(gstrats):]]

        def wrapper(*args, **kwargs):
            n = int(getattr(wrapper, "_shim_max_examples",
                            _DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(0xAE5_0000 + len(gstrats))
            for case in range(n):
                vals = {name: s.example(rng, case)
                        for name, s in zip(gen_names, gstrats)}
                try:
                    fn(*args, **kwargs, **vals)
                except UnsatisfiedAssumption:
                    continue

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # pytest reads this signature for fixture injection; the generated
        # parameters must not look like fixtures
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        wrapper._shim_max_examples = getattr(fn, "_shim_max_examples",
                                             _DEFAULT_MAX_EXAMPLES)
        return wrapper

    return decorate
