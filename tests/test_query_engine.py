"""Planner + executors: correctness vs single-shard oracle, plan-IR sanity,
and invariance of results under repartitioning (the system's core
correctness property)."""
import numpy as np
import pytest

from conftest import canon_bindings
from repro.core.adaptive import AWAPartController
from repro.core.features import FeatureSpace
from repro.core.partition import hash_partition
from repro.query import rewrite
from repro.query import exec as qexec
from repro.query import plan as qplan
from repro.query.engine import ShardedStore
from repro.query.pattern import is_var



def _run(q, sharded):
    return qexec.NumpyExecutor().run(qplan.plan(q, sharded), sharded)


@pytest.fixture()
def sharded8(small_lubm, space):
    space.track_workload(small_lubm.base_workload())
    sizes = space.feature_sizes()
    state = hash_partition(sizes, 8, seed=0)
    return ShardedStore(small_lubm.store, space, state)


@pytest.fixture()
def single(small_lubm, space):
    space.track_workload(small_lubm.base_workload())
    sizes = space.feature_sizes()
    state = hash_partition(sizes, 1, seed=0)
    return ShardedStore(small_lubm.store, space, state)


@pytest.mark.parametrize("qname", [f"Q{i}" for i in range(1, 15)]
                         + [f"EQ{i}" for i in range(1, 11)])
def test_all_queries_match_single_shard_oracle(small_lubm, sharded8, single,
                                               qname):
    q = small_lubm.queries[qname]
    r8, s8 = _run(q, sharded8)
    r1, s1 = _run(q, single)
    assert canon_bindings(r8) == canon_bindings(r1)
    assert s1.distributed_joins == 0          # single shard: no federation


def test_plan_ir_well_formed(small_lubm, sharded8):
    """Plan invariants: one op per pattern, counts match the store, the
    greedy order starts at the most selective pattern and stays connected."""
    for qname in ("Q2", "Q9", "EQ4"):
        q = small_lubm.queries[qname]
        p = qplan.plan(q, sharded8)
        assert len(p.ops) == len(q.patterns)
        assert sorted(op.pattern for op in p.ops) == sorted(q.patterns)
        assert 0 <= p.ppn < sharded8.n_shards
        for op in p.ops:
            s_, p_, o_ = op.pattern
            assert op.est_rows == small_lubm.store.count(
                None if is_var(s_) else s_, None if is_var(p_) else p_,
                None if is_var(o_) else o_)
            assert op.selectivity == pytest.approx(
                op.est_rows / small_lubm.store.n_triples)
        # first op is the globally most selective pattern
        assert p.ops[0].est_rows == min(op.est_rows for op in p.ops)
        # every later op either joins on an already-bound var or is flagged
        bound = set(p.ops[0].new_vars)
        for op in p.ops[1:]:
            assert bool(op.join_vars) != op.cartesian
            assert set(op.join_vars) <= bound
            bound |= set(op.new_vars)
        assert q.name in p.explain()


def test_q6_counts_students(small_lubm, single):
    d = small_lubm.dictionary
    n = small_lubm.store.count(None, d.lookup("rdf:type"),
                               d.lookup("ub:Student"))
    r, _ = _run(small_lubm.queries["Q6"], single)
    assert len(next(iter(r.values()))) == n


def test_results_invariant_under_adaptation(small_lubm):
    """Migration must never change query answers (only their cost)."""
    space = FeatureSpace(small_lubm.store,
                         type_predicate=small_lubm.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=6)
    base = small_lubm.base_workload()
    space.track_workload(base)
    state0 = ctrl.initial_partition(base)
    sh0 = ShardedStore(small_lubm.store, space, state0)
    results0 = {q.name: canon_bindings(_run(q, sh0)[0])
                for q in small_lubm.extended_workload()}

    state1, report = ctrl.adapt(
        small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    sh1 = ShardedStore(small_lubm.store, space, state1)
    for q in small_lubm.extended_workload():
        assert canon_bindings(_run(q, sh1)[0]) == results0[q.name], q.name
    # shards still hold every triple exactly once
    assert sum(sh1.shard_sizes()) == small_lubm.store.n_triples


def test_federated_rewrite_mentions_service(small_lubm, space, sharded8):
    q = small_lubm.queries["Q9"]
    txt = rewrite.federated_sparql(q, space, sharded8.state,
                                   small_lubm.dictionary)
    assert "SELECT" in txt and "WHERE" in txt
    counts = rewrite.service_counts(q, space, sharded8.state)
    assert counts["local"] + counts["service"] == len(q.patterns)
    # the plan's federation annotations agree with the rewriter
    p = qplan.plan(q, sharded8)
    assert p.ppn == counts["ppn"]
    assert sum(op.service for op in p.ops) == counts["service"]


def test_adaptation_reduces_distributed_joins(lubm3):
    space = FeatureSpace(lubm3.store,
                         type_predicate=lubm3.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=8)
    base = lubm3.base_workload()
    space.track_workload(base)
    ctrl.initial_partition(base)
    _, report = ctrl.adapt(lubm3.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.dj_after <= report.dj_before
