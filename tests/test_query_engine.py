"""Distributed query engine: correctness vs single-shard oracle + invariance
of results under repartitioning (the system's core correctness property)."""
import numpy as np
import pytest

from repro.core.adaptive import AWAPartController
from repro.core.features import FeatureSpace
from repro.core.partition import hash_partition
from repro.query import engine, rewrite


def _canon(bindings):
    if not bindings:
        return []
    keys = sorted(bindings)
    return sorted(map(tuple, np.stack([bindings[k] for k in keys],
                                      axis=1).tolist()))


@pytest.fixture()
def sharded8(small_lubm, space):
    space.track_workload(small_lubm.base_workload())
    sizes = space.feature_sizes()
    state = hash_partition(sizes, 8, seed=0)
    return engine.ShardedStore(small_lubm.store, space, state)


@pytest.fixture()
def single(small_lubm, space):
    space.track_workload(small_lubm.base_workload())
    sizes = space.feature_sizes()
    state = hash_partition(sizes, 1, seed=0)
    return engine.ShardedStore(small_lubm.store, space, state)


@pytest.mark.parametrize("qname", [f"Q{i}" for i in range(1, 15)]
                         + [f"EQ{i}" for i in range(1, 11)])
def test_all_queries_match_single_shard_oracle(small_lubm, sharded8, single,
                                               qname):
    q = small_lubm.queries[qname]
    r8, s8 = engine.execute(q, sharded8)
    r1, s1 = engine.execute(q, single)
    assert _canon(r8) == _canon(r1)
    assert s1.distributed_joins == 0          # single shard: no federation


def test_q6_counts_students(small_lubm, single):
    d = small_lubm.dictionary
    n = small_lubm.store.count(None, d.lookup("rdf:type"),
                               d.lookup("ub:Student"))
    r, _ = engine.execute(small_lubm.queries["Q6"], single)
    assert len(next(iter(r.values()))) == n


def test_results_invariant_under_adaptation(small_lubm):
    """Migration must never change query answers (only their cost)."""
    space = FeatureSpace(small_lubm.store,
                         type_predicate=small_lubm.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=6)
    base = small_lubm.base_workload()
    space.track_workload(base)
    state0 = ctrl.initial_partition(base)
    sh0 = engine.ShardedStore(small_lubm.store, space, state0)
    results0 = {q.name: _canon(engine.execute(q, sh0)[0])
                for q in small_lubm.extended_workload()}

    state1, report = ctrl.adapt(
        small_lubm.workload([f"EQ{i}" for i in range(1, 11)]))
    sh1 = engine.ShardedStore(small_lubm.store, space, state1)
    for q in small_lubm.extended_workload():
        assert _canon(engine.execute(q, sh1)[0]) == results0[q.name], q.name
    # shards still hold every triple exactly once
    assert sum(sh1.shard_sizes()) == small_lubm.store.n_triples


def test_federated_rewrite_mentions_service(small_lubm, space, sharded8):
    q = small_lubm.queries["Q9"]
    txt = rewrite.federated_sparql(q, space, sharded8.state,
                                   small_lubm.dictionary)
    assert "SELECT" in txt and "WHERE" in txt
    counts = rewrite.service_counts(q, space, sharded8.state)
    assert counts["local"] + counts["service"] == len(q.patterns)


def test_adaptation_reduces_distributed_joins(lubm3):
    space = FeatureSpace(lubm3.store,
                         type_predicate=lubm3.dictionary.lookup("rdf:type"))
    ctrl = AWAPartController(space, n_shards=8)
    base = lubm3.base_workload()
    space.track_workload(base)
    ctrl.initial_partition(base)
    _, report = ctrl.adapt(lubm3.workload([f"EQ{i}" for i in range(1, 11)]))
    assert report.dj_after <= report.dj_before
