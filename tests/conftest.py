import os
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # fall back to the deterministic shim so property tests still collect
    # and run on machines without the dev dependencies (tests/_compat/)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))

import numpy as np
import pytest

from repro.core.features import FeatureSpace
from repro.graph import lubm

# scripts/ci.sh exports REPRO_FULL_TESTS=1: @slow tests run and property
# tests use their full example budgets. A default `pytest -x -q` skips
# @slow and runs the reduced profiles, keeping tier-1 well under 10 min.
FULL_PROFILES = os.environ.get("REPRO_FULL_TESTS") == "1"


def max_examples(full, fast):
    """Hypothesis example budget for a property test: ``full`` under
    scripts/ci.sh, the reduced ``fast`` count on a default run."""
    return full if FULL_PROFILES else fast


def pytest_collection_modifyitems(config, items):
    if FULL_PROFILES:
        return
    skip = pytest.mark.skip(
        reason="slow: run under REPRO_FULL_TESTS=1 (scripts/ci.sh)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


def canon_bindings(bindings):
    """Canonical form of an executor's bindings ({var: column}) for
    order-insensitive equality across backends/layouts."""
    if not bindings:
        return []
    keys = sorted(bindings)
    return sorted(map(tuple, np.stack([bindings[k] for k in keys],
                                      axis=1).tolist()))


@pytest.fixture(scope="session")
def small_lubm():
    """LUBM(1): ~150k triples — shared across tests."""
    return lubm.load(1, seed=0)


@pytest.fixture(scope="session")
def lubm3():
    """LUBM(3): ~0.5M triples — system-level tests."""
    return lubm.load(3, seed=0)


@pytest.fixture()
def space(small_lubm):
    return FeatureSpace(small_lubm.store,
                        type_predicate=small_lubm.dictionary.lookup("rdf:type"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
