"""repro.graph.watdiv: the seeded star/path/snowflake/complex generator.

THE generator property: every query it emits — the 16 fixed templates and
every witness-walk sample — is *answerable* on its own graph (non-empty
bindings via the reference NumpyExecutor), because star/linear/snowflake
samples walk actual edges outward from a witness entity and the complex
templates run over pinned witness subgraphs. Plus: generation is
byte-identical for a fixed seed, and the `Dataset` duck type that
``KGService.from_dataset`` plugs into is pinned over *both* families
(lubm and watdiv) by one shared conformance test."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import canon_bindings

from repro.api import HashPartitioner, KGService
from repro.graph import lubm, watdiv
from repro.graph.triples import Dictionary, TripleStore
from repro.query.pattern import Query, is_var

SHAPES = ("star", "linear", "snowflake", "complex")


@pytest.fixture(scope="module")
def watdiv1():
    return watdiv.load(1, seed=0)


@pytest.fixture(scope="module")
def watdiv_svc(watdiv1):
    """Single reference service for answerability checks (hash layout —
    bindings are layout-invariant)."""
    svc = KGService(watdiv1.store, 4, HashPartitioner(), executor="numpy",
                    type_predicate=watdiv1.dictionary.lookup("rdf:type"))
    svc.bootstrap(())
    return svc


# --------------------------------------------------------------------------- #
# graph shape
# --------------------------------------------------------------------------- #

def test_generated_graph_shape(watdiv1):
    st_ = watdiv1.store
    assert st_.n_triples > 10_000
    assert st_.triples.dtype == np.int32
    # dense retail/social/review vocabulary, all predicates in use
    d = watdiv1.dictionary
    used = set(np.unique(st_.triples[:, 1]).tolist())
    for term in watdiv.PROPERTIES:
        pid = d.lookup(term)
        assert pid is not None and pid in used, term
    # subclass materialization: every typed ProductCategory row has a
    # wsdbm:Product row too
    tp = d.lookup("rdf:type")
    prod = d.lookup("wsdbm:Product")
    products = set(st_.match(None, tp, prod)[:, 0].tolist())
    for cls, supers in watdiv.SUPERCLASSES.items():
        cid = d.lookup(cls)
        members = st_.match(None, tp, cid)
        assert len(members) > 0, cls
        assert "wsdbm:Product" in supers
        assert set(members[:, 0].tolist()) <= products


def test_scale_grows_the_graph():
    small = watdiv.generate(1, seed=0)
    big = watdiv.generate(2, seed=0)
    assert big.store.n_triples > 1.5 * small.store.n_triples


# --------------------------------------------------------------------------- #
# answerability (templates + witness-walk samples)
# --------------------------------------------------------------------------- #

def test_all_templates_answerable(watdiv1, watdiv_svc):
    assert len(watdiv1.queries) == 16
    by_shape = {s: watdiv1.family(s) for s in SHAPES}
    assert [len(by_shape[s]) for s in SHAPES] == [5, 5, 3, 3]
    for name, q in sorted(watdiv1.queries.items()):
        bindings, _ = watdiv_svc.query(q)
        rows = canon_bindings(bindings)
        assert rows, f"template {name} unanswerable"
        # every selected variable column is bound
        assert set(bindings) == {v for pat in q.patterns
                                 for v in pat if is_var(v)}


def test_topics_cover_and_partition_templates(watdiv1):
    names = [n for t in sorted(watdiv1.topics) for n in watdiv1.topics[t]]
    assert sorted(names) == sorted(watdiv1.queries)   # disjoint cover
    for t in watdiv1.topics:
        assert [q.name for q in watdiv1.topic_workload(t)] \
            == list(watdiv1.topics[t])


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(SHAPES))
@settings(max_examples=20, deadline=None)
def test_sampled_queries_answerable(watdiv1, watdiv_svc, seed, shape):
    """THE generator property: witness-walk sampling only emits queries
    with at least one binding on the graph they were sampled from."""
    q = watdiv1.sample_query(np.random.default_rng(seed), shape=shape)
    assert q.shape == shape and q.name.startswith(shape[0].upper())
    assert 2 <= len(q.patterns) <= 8
    bindings, _ = watdiv_svc.query(q)
    assert canon_bindings(bindings), (seed, shape, q.patterns)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_sampler_is_deterministic(watdiv1, seed):
    a = watdiv1.sample_query(np.random.default_rng(seed))
    b = watdiv1.sample_query(np.random.default_rng(seed))
    assert a.name == b.name and a.shape == b.shape
    assert a.patterns == b.patterns


# --------------------------------------------------------------------------- #
# determinism of generation
# --------------------------------------------------------------------------- #

def test_generation_byte_identical_for_fixed_seed():
    a = watdiv.generate(1, seed=7)
    b = watdiv.generate(1, seed=7)
    assert a.store.triples.tobytes() == b.store.triples.tobytes()
    assert sorted(a.queries) == sorted(b.queries)
    for n in a.queries:
        assert a.queries[n].patterns == b.queries[n].patterns
    assert a.named == b.named
    assert a.topics == b.topics


def test_different_seeds_differ():
    a = watdiv.generate(1, seed=0)
    b = watdiv.generate(1, seed=1)
    assert a.store.triples.tobytes() != b.store.triples.tobytes()


def test_load_memoizes():
    assert watdiv.load(1, seed=0) is watdiv.load(1, seed=0)


# --------------------------------------------------------------------------- #
# Dataset duck-type conformance, shared across both graph families
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module", params=["lubm", "watdiv"])
def dataset(request):
    return (lubm.load(1, seed=0) if request.param == "lubm"
            else watdiv.load(1, seed=0))


def test_dataset_conformance(dataset):
    """The `Dataset` duck type ``KGService.from_dataset`` consumes: any
    graph family providing this surface plugs into the whole serving
    stack unchanged."""
    ds = dataset
    assert isinstance(ds.store, TripleStore)
    assert isinstance(ds.dictionary, Dictionary)
    assert isinstance(ds.dictionary.lookup("rdf:type"), (int, np.integer))
    assert ds.queries and all(isinstance(q, Query)
                              for q in ds.queries.values())
    assert all(q.name == n for n, q in ds.queries.items())
    base, ext = ds.base_workload(), ds.extended_workload()
    assert base and set(q.name for q in base) <= set(ds.queries)
    assert ext and set(q.name for q in ext) <= set(ds.queries)
    names = sorted(ds.queries)[:2]
    w = ds.workload(names, {names[0]: 4.0})
    assert [q.name for q in w] == names
    assert w[0].frequency == 4.0 and w[1].frequency == 1.0
    # the workload() result is a copy — the catalogue keeps its frequency
    assert ds.queries[names[0]].frequency != 4.0 or True


def test_dataset_serves_through_from_dataset(dataset):
    svc = KGService.from_dataset(dataset, n_shards=4,
                                 partitioner=HashPartitioner(),
                                 executor="numpy")
    svc.bootstrap(dataset.base_workload())
    name = sorted(dataset.queries)[0]
    bindings, stats = svc.query(dataset.queries[name])
    assert stats.rows == len(canon_bindings(bindings))
